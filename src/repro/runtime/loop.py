"""Training loop with checkpoint/restart, retries, stragglers, redeploy.

``TrainLoop`` is the single-process embodiment of the multi-pod runtime:
the same step function the dry-run lowers for 512 chips runs here on the
local mesh, with the full production control plane around it:

* resume from the latest checkpoint on construction (crash -> restart is a
  no-op in user code);
* bounded per-step retries with checkpoint restore between attempts
  (FaultPolicy);
* straggler watchdog (StragglerPolicy) with a spare-swap callback;
* periodic crossbar *redeployment pricing* (the paper integrated into the
  training loop): every ``redeploy_every`` steps the loop prices
  reprogramming the deployed crossbars from the previous snapshot to the
  current weights via ``core.redeploy.delta_cost`` — with/without SWS —
  so EXPERIMENTS.md can report the training-time reprogramming savings.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.core.planner import CrossbarSpec, PlannerConfig
from repro.core.pool import CrossbarPool
from repro.core.redeploy import delta_cost
from repro.data import SyntheticLMDataset
from repro.runtime.fault import FaultPolicy, StragglerPolicy, run_with_retries


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    log_every: int = 10
    redeploy_every: int = 0  # 0 = off; else price crossbar redeploy every k steps
    redeploy_tensors: int = 2  # how many (largest) tensors to price


class TrainLoop:
    def __init__(
        self,
        cfg: ArchConfig,
        loop_cfg: TrainLoopConfig,
        *,
        train_step: Callable,  # (params, opt_state, batch) -> (params, opt_state, metrics)
        init_state: Callable[[], tuple[Any, Any]],  # () -> (params, opt_state)
        dataset: SyntheticLMDataset,
        fault: FaultPolicy = FaultPolicy(),
        straggler: Optional[StragglerPolicy] = None,
        crossbar_spec: CrossbarSpec = CrossbarSpec(),
        planner_cfg: PlannerConfig = PlannerConfig(),
        host: int = 0,
        n_hosts: int = 1,
    ):
        self.cfg = cfg
        self.loop_cfg = loop_cfg
        self.train_step = train_step
        self.dataset = dataset
        self.fault = fault
        self.straggler = straggler or StragglerPolicy()
        self.crossbar_spec = crossbar_spec
        self.planner_cfg = planner_cfg
        # one persistent CrossbarPool per priced tensor (each deployed tensor
        # is resident on its own physical crossbars): checkpoint refreshes
        # reprogram the same cells the previous checkpoint left behind, and
        # per-cell wear accumulates over the whole training run
        self.pools: dict[str, CrossbarPool] = {}
        self.host, self.n_hosts = host, n_hosts
        self.ckpt = CheckpointManager(
            loop_cfg.checkpoint_dir, keep=loop_cfg.keep_checkpoints, async_write=True
        )
        self.metrics_log: list[dict] = []
        self.redeploy_log: list[dict] = []
        self._deployed_snapshot: Optional[dict[str, jax.Array]] = None

        # resume-or-init
        params, opt_state = init_state()
        latest = self.ckpt.latest()
        if latest is not None:
            params, opt_state = self.ckpt.restore(latest, (params, opt_state))
            self.start_step = latest
        else:
            self.start_step = 0
        self.params, self.opt_state = params, opt_state

    # -- redeploy pricing ------------------------------------------------------

    def _largest_weights(self) -> dict[str, jax.Array]:
        flat, _ = jax.tree_util.tree_flatten_with_path(self.params)
        mats = [
            ("/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p), l)
            for p, l in flat
            if hasattr(l, "ndim") and l.ndim >= 2 and "embed" not in str(p).lower()
        ]
        mats.sort(key=lambda kv: -int(np.prod(kv[1].shape)))
        return dict(mats[: self.loop_cfg.redeploy_tensors])

    def _pool_for(self, name: str) -> CrossbarPool:
        if name not in self.pools:
            self.pools[name] = CrossbarPool(
                self.crossbar_spec,
                self.planner_cfg.crossbars,
                leveling=self.planner_cfg.pool_leveling or "none",
            )
        return self.pools[name]

    def _price_redeploy(self, step: int) -> None:
        current = self._largest_weights()
        if self._deployed_snapshot is not None:
            for name, w_new in current.items():
                w_old = self._deployed_snapshot.get(name)
                if w_old is None or w_old.shape != w_new.shape:
                    continue
                pool = self._pool_for(name)
                rep = delta_cost(
                    w_old, w_new, self.crossbar_spec, self.planner_cfg,
                    name=name, pool=pool,
                )
                stats = pool.stats()
                self.redeploy_log.append(
                    {
                        "step": step,
                        "tensor": name,
                        "transitions_natural": rep.transitions_natural,
                        "transitions_sws": rep.transitions_sws,
                        "chain_stale_sws": rep.chain_stale_sws,
                        "chain_fresh_sws": rep.chain_fresh_sws,
                        "chain_pool": rep.chain_pool,
                        "stale_sort_speedup": rep.stale_sort_speedup,
                        "sws_delta_speedup": rep.sws_delta_speedup,
                        "n_bits": rep.n_bits,
                        "pool_max_cell_writes": stats.max_cell_writes,
                        "pool_total_writes": stats.total_writes,
                    }
                )
        self._deployed_snapshot = {k: jax.device_get(v) for k, v in current.items()}

    # -- main loop ---------------------------------------------------------------

    def run(self) -> dict:
        lc = self.loop_cfg
        for step in range(self.start_step, lc.total_steps):
            batch = self.dataset.batch_at(step, self.host, self.n_hosts)

            def attempt():
                return self.train_step(self.params, self.opt_state, batch)

            def on_failure(att: int, err: BaseException) -> None:
                if self.fault.restore_on_failure:
                    latest = self.ckpt.latest()
                    if latest is not None:
                        self.params, self.opt_state = self.ckpt.restore(
                            latest, (self.params, self.opt_state)
                        )

            t0 = time.time()
            self.params, self.opt_state, metrics = run_with_retries(
                attempt, self.fault, on_failure=on_failure
            )
            jax.block_until_ready(metrics["loss"])
            wall = time.time() - t0
            self.straggler.observe(step, wall)

            if (step + 1) % lc.log_every == 0 or step == lc.total_steps - 1:
                rec = {
                    "step": step + 1,
                    "wall_s": round(wall, 4),
                    **{k: float(v) for k, v in metrics.items()},
                }
                self.metrics_log.append(rec)
            if lc.checkpoint_every and (step + 1) % lc.checkpoint_every == 0:
                self.ckpt.save(step + 1, (self.params, self.opt_state))
            if lc.redeploy_every and (step + 1) % lc.redeploy_every == 0:
                self._price_redeploy(step + 1)

        self.ckpt.save(lc.total_steps, (self.params, self.opt_state))
        self.ckpt.wait()
        return {
            "final_metrics": self.metrics_log[-1] if self.metrics_log else {},
            "metrics_log": self.metrics_log,
            "redeploy_log": self.redeploy_log,
            "straggler_events": self.straggler.events,
            "pool_wear": {name: p.stats().to_dict() for name, p in self.pools.items()},
        }
