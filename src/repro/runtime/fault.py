"""Fault tolerance and straggler mitigation policies.

At 1000+-node scale the framework must survive (a) hard node failures —
checkpoint/restart, (b) transient step failures — bounded retry, and (c)
stragglers — the synchronous-with-spares policy below.  On real TPU pods
(a) is signalled by the runtime (jax.distributed heartbeats / NCCL-style
timeouts); this container has one process, so tests inject failures via the
``failure_hook`` and assert the recovery behaviour (tests/test_runtime.py).

``StragglerPolicy`` implements the standard large-scale recipe:

* per-step wall-time EWMA; a step slower than ``ewma * tolerance`` marks
  the step (and in a multi-host run, the slow host) as straggling;
* after ``demote_after`` consecutive marks, the policy asks the cluster
  layer to swap the slow host for a hot spare (callback; here recorded in
  ``events``) and the data pipeline's (step, host) keying makes the swap
  bit-exact — the replacement replays the same shard.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Callable, Optional, Tuple, Type


@dataclasses.dataclass
class FaultPolicy:
    """Bounded-retry policy.  ``backoff_s`` is the exponential base between
    attempts; ``jitter`` spreads each sleep to ``backoff_s * 2**attempt *
    (1 + uniform(0, jitter))`` from a PRNG seeded with ``seed`` — N
    replicas retrying a shared dependency (checkpoint store, pool
    reprogramming) must not thunder-herd back in lockstep, while a fixed
    seed keeps every trace reproducible."""

    max_retries: int = 3
    backoff_s: float = 0.0  # exponential base; 0 for tests
    restore_on_failure: bool = True  # reload last checkpoint before retrying
    jitter: float = 0.0  # uniform backoff spread fraction (0 = deterministic)
    seed: int = 0

    def __post_init__(self):
        if self.jitter < 0:
            raise ValueError(f"jitter must be >= 0, got {self.jitter}")


def backoff_delay(
    policy: FaultPolicy, attempt: int, rng: Optional[random.Random] = None
) -> float:
    """The jittered exponential delay before retry ``attempt`` (0-based
    failure count): ``backoff_s * 2**attempt * (1 + uniform(0, jitter))``.

    One formula for both retry styles: :func:`run_with_retries` sleeps it
    inline, while the fleet router turns it into a not-before timestamp on
    its admission queue (a router must keep serving other replicas while a
    failed request waits out its backoff)."""
    if not policy.backoff_s:
        return 0.0
    spread = 1.0
    if policy.jitter:
        spread += (rng or random.Random(policy.seed)).uniform(0.0, policy.jitter)
    return policy.backoff_s * (2**attempt) * spread


def run_with_retries(
    fn: Callable[[], Any],
    policy: FaultPolicy,
    *,
    on_failure: Optional[Callable[[int, BaseException], None]] = None,
    retry_on: Tuple[Type[BaseException], ...] = (Exception,),
) -> Any:
    """Run ``fn`` with bounded retries; ``on_failure(attempt, err)`` between tries.

    ``KeyboardInterrupt``/``SystemExit`` always propagate immediately — a
    retry boundary must never swallow a shutdown request.  ``retry_on``
    narrows which exceptions are retried: anything outside it re-raises
    unchanged on the first occurrence.  The backoff sleep only runs when
    another attempt follows (never after the final failure) and is
    jittered per ``policy.jitter`` (seeded — deterministic per call), and
    the terminal ``RuntimeError`` chains the last underlying exception.
    """
    last: Optional[BaseException] = None
    rng = random.Random(policy.seed) if policy.jitter else None
    for attempt in range(policy.max_retries + 1):
        try:
            return fn()
        except (KeyboardInterrupt, SystemExit):
            raise
        except BaseException as e:  # noqa: BLE001 — deliberate retry boundary
            if not isinstance(e, retry_on):
                raise
            last = e
            if attempt == policy.max_retries:
                break  # no backoff after the final attempt
            if on_failure is not None:
                on_failure(attempt, e)
            if policy.backoff_s:
                time.sleep(backoff_delay(policy, attempt, rng))
    raise RuntimeError(f"step failed after {policy.max_retries + 1} attempts") from last


@dataclasses.dataclass
class StragglerPolicy:
    tolerance: float = 2.0  # step slower than ewma * tolerance => straggling
    ewma_alpha: float = 0.1
    demote_after: int = 3  # consecutive marks before requesting a swap
    warmup_steps: int = 5  # ignore compile/first-touch steps

    def __post_init__(self):
        self._ewma: Optional[float] = None
        self._marks = 0
        self._seen = 0
        self.events: list[dict] = []

    def reset_ewma(self) -> None:
        """Forget the wall-time baseline (and any pending marks).

        Called automatically after a swap is requested — the replacement
        host's step time must not be judged against the dead host's EWMA —
        and available to callers after any event that shifts the baseline
        (hot param redeploy, topology change).  The next observed step
        re-seeds the EWMA, exactly like the first post-warmup step.
        """
        self._ewma = None
        self._marks = 0

    def observe(self, step: int, wall_s: float, *, swap_fn: Optional[Callable[[], None]] = None) -> bool:
        """Record a step time; returns True if this step was marked straggling."""
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return False
        if self._ewma is None:
            self._ewma = wall_s
            return False
        straggling = wall_s > self._ewma * self.tolerance
        if straggling:
            self._marks += 1
            self.events.append({"step": step, "wall_s": wall_s, "ewma": self._ewma})
            if self._marks >= self.demote_after:
                self.events.append({"step": step, "action": "request_spare_swap"})
                if swap_fn is not None:
                    swap_fn()
                self.reset_ewma()  # recalibrate against the replacement host
        else:
            self._marks = 0  # marks must be *consecutive* to demote
            self._ewma = (1 - self.ewma_alpha) * self._ewma + self.ewma_alpha * wall_s
        return straggling
