from repro.runtime.loop import TrainLoop, TrainLoopConfig
from repro.runtime.fault import FaultPolicy, StragglerPolicy, run_with_retries

__all__ = [
    "TrainLoop",
    "TrainLoopConfig",
    "FaultPolicy",
    "StragglerPolicy",
    "run_with_retries",
]
