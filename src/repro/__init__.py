"""repro: JAX/Pallas reproduction of 'Efficient Reprogramming of Memristive
Crossbars for DNNs: Weight Sorting and Bit Stucking' (Farias & Kung, 2024),
built as a multi-pod training/serving framework with crossbar deployment as a
first-class backend.  See DESIGN.md for the system map."""

__version__ = "0.1.0"
