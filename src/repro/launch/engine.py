"""Continuous-batching CIM serving engine: paged KV + slot scheduler.

Serves heterogeneous, streaming requests from one shared paged KV pool
(``launch.paged_cache``) through shape-bucketed jitted dispatches:

  * **Admission** — waiting requests enter freed decode slots mid-flight as
    soon as a slot and enough KV blocks for their first prefill chunk are
    available (FIFO in arrival order).  Blocks are allocated *lazily* as a
    request grows — admission never reserves the worst-case
    prompt+max_new_tokens footprint up front.
  * **Fused prefill+decode** (default, ``EngineConfig.fused``) — each cycle
    runs ONE bucketed dispatch (``steps.make_fused_step``) in which prefill
    rows advance a chunk (query extent = chunk length) and decode rows
    advance a full quantum (query extent 1) *in the same batch*: the view
    gather, the mixed-extent chunk step, a ``lax.scan`` decode quantum, and
    the write-back scatter all happen in one XLA computation, one host
    round-trip.  A row that finishes its prompt mid-batch samples its first
    token in-graph and decodes the rest of the quantum inside the same
    dispatch — no cycle of dead time between prefill and decode.  With
    ``fused=False`` the engine keeps the split discipline (one chunked
    prefill dispatch + one decode-quantum dispatch per cycle) — the
    benchmark baseline.
  * **Preemption** (``EngineConfig.preempt``) — when the free list cannot
    serve a growing request, the lowest-priority (latest-arrival) slot is
    preempted: ``"swap"`` snapshots its live KV cells to host memory
    (``paged_cache.swap_out``) and restores them byte-identical on
    re-admission; ``"recompute"`` drops the cells and re-prefills
    prompt+generated on re-admission (teacher-forced — already-emitted
    tokens are never re-sampled).  Preempted requests re-enter the waiting
    queue in arrival order (FIFO) and re-admit as soon as a slot and blocks
    free up.  Decode slots are preferred as victims; the highest-priority
    request can always evict every later arrival, so the engine admits
    over-committed traces (more concurrent demand than blocks) instead of
    stalling.
  * **Retirement** — EOS / max-new-tokens ends a request; its blocks return
    to the free list and its slot admits the next queued request.

Shape bucketing keeps the dispatch count compile-friendly: row counts and
page counts are padded to powers of two (dummy rows write to the reserved
dummy page), so the number of compiled variants is O(log(max_slots) *
log(max_pages)) per dispatch kind rather than one per ragged shape.

Token parity: each request's stream is bit-identical to a solo
``launch.serve.generate`` run with the same PRNG seed — through fused and
split dispatches, mid-flight admission, and preemption/re-admission, for
all three materializations (dense / packed / planes_int8), pinned in
tests/test_engine.py.

See docs/architecture.md for how the engine sits on the planner → pool →
packed-serving stack, and docs/benchmarks.md for the BENCH_engine.json
fields the throughput benchmark derives from ``Engine.stats``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch import paged_cache, steps
from repro.launch.paged_cache import PagedCacheConfig, PagedKVCache
from repro.models import api
from repro.parallel import tp as tp_mod


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival_time`` is seconds relative to
    ``Engine.run`` start (0.0 = available immediately).

    ``deadline_s`` (seconds after arrival) bounds the request's total
    latency: once exceeded, the engine retires it with
    ``status="timeout"`` — partial tokens returned, blocks freed — instead
    of decoding forever.  ``priority_class`` is the SLO tier consumed by
    preemption victim-key policies (0 = most important; see
    :func:`priority_class_victim_key`) and by fleet placement.
    """

    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    greedy: bool = True
    seed: int = 0
    eos_id: Optional[int] = None
    arrival_time: float = 0.0
    deadline_s: Optional[float] = None
    priority_class: int = 0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be positive (or None)")


@dataclasses.dataclass
class RequestResult:
    """Outcome of one request: its token stream plus the latency breakdown
    (all times seconds relative to ``Engine.run`` start).

    ``status``: ``"ok"`` (EOS / max-new-tokens), ``"timeout"`` (deadline
    expired — ``tokens`` holds whatever was emitted in time), or
    ``"cancelled"`` (:meth:`Engine.cancel`, e.g. a fleet killing the losing
    copy of a hedged dispatch)."""

    rid: int
    tokens: list[int]
    t_arrival: float
    t_admitted: float
    t_first_token: float
    t_done: float
    status: str = "ok"

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_arrival


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Shape + scheduling policy for the engine.

    ``max_seq_len`` bounds prompt+generated per request; ``num_blocks``
    sizes the shared pool (default: enough for every slot's worst case —
    shrink it to exercise preemption / serve over-committed traffic).
    ``fused`` selects the fused prefill+decode dispatch (one batched
    dispatch per cycle) vs the split prefill-then-decode discipline;
    ``preempt`` selects what happens to a victim's KV under block pressure:
    ``"swap"`` (host snapshot, byte-identical restore) or ``"recompute"``
    (drop + teacher-forced re-prefill on re-admission).

    ``victim_key`` makes the preemption order pluggable: a callable from
    :class:`SlotView` to ``(protect, prefer)`` tuples — ``protect`` is the
    total priority order (larger = lower priority; a slot may only evict
    slots whose ``protect`` is strictly larger than its own, which is what
    makes preemption deadlock-free), ``prefer`` breaks ties among evictable
    candidates (largest wins).  ``None`` keeps the FCFS default
    (:func:`fcfs_victim_key`: latest arrival evicted first, decode slots
    preferred); :func:`priority_class_victim_key` is the SLO-tier example
    the fleet router uses.
    """

    max_slots: int = 8
    page_size: int = 16
    max_seq_len: int = 512  # upper bound on prompt + generated per request
    prefill_chunk: int = 32  # max prompt tokens per prefill dispatch
    decode_quantum: int = 8  # decode steps per dispatch
    num_blocks: Optional[int] = None  # default: dummy + max_slots * max_pages
    fused: bool = True  # fused prefill+decode dispatch per cycle
    preempt: str = "swap"  # "swap" | "recompute"
    victim_key: Optional[Callable[["SlotView"], tuple]] = None

    def __post_init__(self):
        for field in ("max_slots", "page_size", "max_seq_len",
                      "prefill_chunk", "decode_quantum"):
            if getattr(self, field) < 1:
                raise ValueError(f"{field} must be positive, got {getattr(self, field)}")
        if self.num_blocks is not None and self.num_blocks < 2:
            raise ValueError(
                f"num_blocks must be >= 2 (dummy page + one usable block), "
                f"got {self.num_blocks}"
            )
        if self.preempt not in ("swap", "recompute"):
            raise ValueError(
                f"unknown preemption mode {self.preempt!r}; "
                f"choose 'swap' or 'recompute'"
            )
        if self.victim_key is not None and not callable(self.victim_key):
            raise ValueError("victim_key must be callable (SlotView -> tuple) or None")


_WAITING, _PREFILL, _DECODE = "waiting", "prefill", "decode"


@dataclasses.dataclass(frozen=True)
class SlotView:
    """What a ``victim_key`` policy may observe of an occupied slot —
    deliberately host-only scheduling facts, never device state."""

    rid: int
    arrival_time: float
    priority_class: int
    decoding: bool  # prompt finished, emitting tokens
    generated: int  # tokens emitted so far
    deadline_s: Optional[float]


def fcfs_victim_key(v: SlotView) -> tuple:
    """Default preemption order: strict FCFS protection (latest arrival is
    evicted first), decode slots preferred among candidates (a mid-prompt
    victim wastes its partial prefill)."""
    return ((v.arrival_time, v.rid), (v.decoding,))


def priority_class_victim_key(v: SlotView) -> tuple:
    """SLO-tier preemption: a lower ``priority_class`` (more important
    request) may evict any higher class regardless of arrival order; FCFS
    within a class; decode slots preferred among candidates.  The fleet
    router's lever for keeping interactive traffic live while batch-tier
    work absorbs block pressure."""
    return ((v.priority_class, v.arrival_time, v.rid), (v.decoding,))


class _Slot:
    """Host state of one occupied decode slot."""

    def __init__(self, req: Request, t_admitted: float, epoch: int = 0):
        self.req = req
        self.epoch = epoch  # param epoch this request is pinned to (hot swap)
        self.state = _PREFILL
        self.prefill_done = 0  # target tokens already written to the pool
        self.pos = 0  # next decode write position (= tokens in cache)
        self.generated: list[int] = []
        self.tok_next = -1  # last emitted token (next decode input)
        self.pf_deferred = False  # lone-prefill batching: deferred one cycle
        self.key = np.asarray(jax.random.PRNGKey(req.seed))
        self.t_admitted = t_admitted
        self.t_first_token = 0.0
        # recompute re-admission: the sequence being re-prefilled
        # (prompt + already-generated tokens) and the pending token that was
        # emitted before preemption — adopted instead of a fresh sample when
        # the replay completes (its sampling already happened once)
        self.replay: Optional[np.ndarray] = None
        self.saved_tok = -1

    @property
    def target(self) -> np.ndarray:
        """The token sequence prefill is walking: the prompt, or the
        teacher-forced prompt+generated replay after a recompute preemption."""
        return self.replay if self.replay is not None else self.req.prompt

    @property
    def view(self) -> SlotView:
        return SlotView(
            rid=self.req.rid,
            arrival_time=self.req.arrival_time,
            priority_class=self.req.priority_class,
            decoding=self.state == _DECODE,
            generated=len(self.generated),
            deadline_s=self.req.deadline_s,
        )


@dataclasses.dataclass
class ResumeState:
    """Everything needed to continue a request on *an* engine — the one it
    left (preemption requeue) or a different replica (failover / hedging).

    ``n_live`` live cells ([0, n_live)) were either snapshotted to host
    (``snapshot`` pytree, swap mode) or dropped (recompute mode / a crash
    that lost device state).  Re-admission derives everything else from the
    *prefix* the cache must hold — prompt + generated[:-1] — so every
    resume point (mid-prompt, mid-replay, steady decode) readmits through
    one rule: restore what was snapshotted, then prefill the rest of the
    prefix teacher-forced, then resume decode with ``tok_next`` (already
    emitted — never re-sampled).  Because both the snapshot and the prefix
    are keyed by logical position, the record is portable across engines
    with different block layouts and param epochs (:meth:`Engine.resume`
    re-pins ``epoch`` to the adopting engine).
    """

    req: Request
    n_live: int
    generated: list[int]
    tok_next: int
    key: np.ndarray
    snapshot: Any  # host pytree (swap) or None (recompute)
    t_admitted: float
    t_first_token: float
    epoch: int = 0  # param epoch the request stays pinned to across eviction

    @property
    def arrival_time(self) -> float:
        return self.req.arrival_time


def _bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n, clamped to cap — the one bucketing rule
    for dispatch rows AND page counts, so the prewarm grid generators below
    can never drift from the shapes the scheduler actually dispatches."""
    b = 1
    while b < n and b < cap:
        b *= 2
    return min(b, cap)


def _buckets_upto(cap: int) -> list[int]:
    """Every value ``_bucket`` can return for caps up to ``cap``."""
    out, b = [], 1
    while b < cap:
        out.append(b)
        b *= 2
    out.append(cap)
    return out


class Engine:
    """Continuous-batching serving engine over a paged KV pool.

    ``params`` may be any ``deploy_params`` materialization (or plain fp
    weights); they are prepared once (``steps.prepare_serving_params``) so
    non-TPU backends decompress packed operands a single time per deployment.

    Public surface: :meth:`submit` / :meth:`step` for external event loops,
    :meth:`run` for a self-clocked trace, :meth:`prewarm` to compile every
    bucketed dispatch variant up front; ``stats`` accumulates dispatch and
    preemption counters across the engine's lifetime (the throughput
    benchmark reads per-pass deltas from it).
    """

    def __init__(self, cfg: ArchConfig, params: Any, ecfg: EngineConfig = EngineConfig(),
                 *, dispatch_from: Optional["Engine"] = None, tp: int = 1,
                 tp_devices: Optional[list] = None):
        if not api.supports_paged(cfg):
            raise NotImplementedError(
                f"{cfg.name}: the paged engine serves pure-attention decoder stacks"
            )
        self.cfg = cfg
        self.ecfg = ecfg
        # tensor parallelism: tp > 1 splits this replica over a "model" axis
        # (parallel/tp.py) — params are sharded + stacked on a leading shard
        # axis, the paged KV pools partition on the head axis (one shared
        # slot schedule / block table), and every dispatch runs the same
        # step functions SPMD (vmap-emulated on one device, or shard_map
        # over ``tp_devices`` when a real N-device group is supplied).  The
        # host scheduler below is untouched: wrapped steps return tokens /
        # keys reduced to shard 0 (they are replicated across shards).
        if tp_devices is not None and tp == 1:
            tp = len(tp_devices)
        if tp > 1:
            # plan against packed constraints even for dense trees: a hot
            # redeploy may swap a packed materialization in later, and the
            # shard layout must not change across epochs
            self._tp = tp_mod.plan_tp(cfg, tp, packed=True)
            devs = tuple(tp_devices) if tp_devices is not None else None
            if devs is not None and len(set(devs)) != tp:
                devs = None  # repeated devices = 1-device emulation -> vmap
            self._tp_devices = devs
            self.cfg_local = tp_mod.local_config(cfg, self._tp)
        else:
            self._tp = None
            self._tp_devices = None
            self.cfg_local = cfg
        # serving params are versioned by *epoch* so a hot redeploy
        # (``hot_swap``) can swap in a new tree between dispatches while
        # every in-flight request keeps computing on the tree it was
        # admitted under — its whole token stream sees ONE param version,
        # which is what makes streams bit-identical across a swap
        self.params_epoch = 0
        self._params: dict[int, Any] = {0: self._prepare(params)}

        # a slot's dispatches may address up to a fused window (one padded
        # prefill chunk + one decode quantum) past max_seq_len; writes beyond
        # its allocation land in the dummy page, but the bucketed page view
        # must be wide enough to address them
        overhang = ecfg.prefill_chunk + ecfg.decode_quantum
        max_pages = -(-(ecfg.max_seq_len + overhang) // ecfg.page_size)
        num_blocks = ecfg.num_blocks or 1 + ecfg.max_slots * max_pages
        self.pcfg = PagedCacheConfig(
            page_size=ecfg.page_size,
            num_blocks=num_blocks,
            max_slots=ecfg.max_slots,
            max_pages=max_pages,
        )
        self.kv = PagedKVCache(self.pcfg)
        # per-shard pools: each shard's wk/wv slice only produces its own
        # n_kv_heads/N heads, so the pool partition is the local-config pool
        # stacked on a leading shard axis — ONE block table / slot schedule
        self.pools = api.init_paged_pools(self.cfg_local, self.pcfg.num_tokens)
        if self._tp is not None:
            self.pools = jax.tree.map(
                lambda x: jnp.zeros((self._tp.n, *x.shape), x.dtype), self.pools
            )

        # two compiled quantum lengths: the full quantum for steady decoding
        # and a short one for when most live rows sit near retirement —
        # heavy-tailed traffic would otherwise overrun every short request
        # by most of a full quantum (or, with a min-remaining policy, drag
        # every long row down to one-token dispatches)
        self._quanta = sorted({max(2, ecfg.decode_quantum // 4), ecfg.decode_quantum})
        if dispatch_from is not None:
            # data-parallel replicas of one fleet serve the same model with
            # the same dispatch shapes — sharing the jitted callables means
            # a shape bucket compiles once per fleet, not once per replica
            src = dispatch_from
            if (src.cfg is not cfg
                    or src.ecfg.page_size != ecfg.page_size
                    or src.ecfg.decode_quantum != ecfg.decode_quantum
                    or src.ecfg.prefill_chunk != ecfg.prefill_chunk
                    or bool(src._fused_steps) != ecfg.fused
                    or src._tp != self._tp
                    or src._tp_devices != self._tp_devices):
                raise ValueError(
                    "dispatch_from requires an engine with the same model "
                    "config, dispatch shapes (page_size, decode_quantum, "
                    "prefill_chunk, fused), and tensor-parallel layout"
                )
            self._decode_loops = src._decode_loops
            self._prefill_step = src._prefill_step
            self._fused_steps = src._fused_steps
        else:
            donate = steps.cache_donation()
            self._decode_loops = {
                q: jax.jit(
                    self._tp_wrap(
                        steps.make_paged_decode_loop(self.cfg_local, q, ecfg.page_size),
                        (True, True, False, False, False), (False, True, False),
                    ),
                    donate_argnums=donate,
                )
                for q in self._quanta
            }
            self._prefill_step = jax.jit(
                self._tp_wrap(
                    steps.make_prefill_chunk_step(self.cfg_local, ecfg.page_size),
                    (True, True, False, False, False, False), (False, False, True),
                ),
                donate_argnums=donate,
            )
            self._fused_steps = {
                q: jax.jit(
                    self._tp_wrap(
                        steps.make_fused_step(self.cfg_local, q, ecfg.page_size),
                        (True, True) + (False,) * 8, (False, False, False, True),
                    ),
                    donate_argnums=donate,
                )
                for q in self._quanta
            } if ecfg.fused else {}

        self.waiting: deque[Union[Request, ResumeState]] = deque()
        self.slots: list[Optional[_Slot]] = [None] * ecfg.max_slots
        self.results: dict[int, RequestResult] = {}
        self._shapes_seen: set[tuple] = set()
        self.stats = {
            "decode_dispatches": 0,
            "prefill_dispatches": 0,
            "fused_dispatches": 0,
            "decode_rows_live": 0,
            "decode_rows_padded": 0,
            "tokens_emitted": 0,
            "tokens_overrun": 0,
            "preemptions": 0,
            "preempt_swap": 0,
            "preempt_recompute": 0,
            "swap_ins": 0,
            "readmissions": 0,
            "hot_swaps": 0,
            "swap_rollbacks": 0,
            "epochs_retired": 0,
            "timeouts": 0,
            "cancels": 0,
            "scrub_rounds": 0,
            "scrub_tiles": 0,
            "scrub_detections": 0,
            "scrub_repairs": 0,
            "scrub_refreshes": 0,
        }
        self._scrub_mgr = None
        self._scrub_refresh = None
        self._scrub_every = 1
        self._scrub_cycles = 0

    # -- tensor parallelism -------------------------------------------------

    def _prepare(self, params: Any) -> Any:
        """Serving-ready tree: prepared solo, or sharded+stacked under TP."""
        if self._tp is None:
            return steps.prepare_serving_params(params)
        return tp_mod.prepare_tp_params(params, self._tp)

    def _tp_wrap(self, fn, stacked_in, stacked_out):
        """SPMD-wrap a step under TP (identity when unsharded)."""
        if self._tp is None:
            return fn
        return tp_mod.tp_step(fn, self._tp, stacked_in, stacked_out, self._tp_devices)

    # -- public API ---------------------------------------------------------

    @property
    def params(self) -> Any:
        """The current-epoch serving params (what new admissions use)."""
        return self._params[self.params_epoch]

    def hot_swap(self, params: Any, *, policy=None) -> bool:
        """Atomically swap in new serving params between dispatches.

        ``params`` is either a ready param tree (any ``deploy_params``
        materialization) or a zero-argument callable producing one — e.g.
        "program the next checkpoint into the pool's spare capacity" — run
        under ``runtime.fault.run_with_retries`` with ``policy`` (default:
        no retries).  On failure the swap **rolls back**: the old params
        keep serving, ``stats["swap_rollbacks"]`` increments, and False is
        returned.  On success requests admitted from now on use the new
        epoch while in-flight requests finish on the epoch they started
        under (bit-identical streams across the swap); old epochs are
        garbage-collected once their last request drains.
        """
        from repro.runtime.fault import FaultPolicy, run_with_retries

        if callable(params):
            try:
                params = run_with_retries(params, policy or FaultPolicy(max_retries=0))
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception:
                self.stats["swap_rollbacks"] += 1
                return False
        self.params_epoch += 1
        self._params[self.params_epoch] = self._prepare(params)
        self.stats["hot_swaps"] += 1
        return True

    def attach_scrub(self, manager, *, refresh=None, every: int = 1) -> None:
        """Run a budgeted integrity scrub between dispatch rounds.

        ``manager`` is a ``core.integrity.IntegrityManager`` (duck-typed:
        anything with ``scrub_round()``/``pending_faults()``); one round —
        at most ``manager.cfg.scrub_tiles`` tile verifications — runs every
        ``every``-th scheduler cycle, after the cycle's dispatches, so the
        added serving latency is bounded by the tile budget.  When a round
        performs repairs and the manager comes back clean, ``refresh`` (a
        zero-arg callable producing repaired serving params — typically
        ``deploy_params`` over ``manager.rebuild_plan``) is swapped in
        atomically via :meth:`hot_swap`: in-flight requests finish on the
        epoch they started under, new admissions read the repaired planes.
        """
        if every < 1:
            raise ValueError(f"scrub interval must be >= 1, got {every}")
        self._scrub_mgr = manager
        self._scrub_refresh = refresh
        self._scrub_every = int(every)
        self._scrub_cycles = 0

    def _scrub_tick(self) -> None:
        if self._scrub_mgr is None:
            return
        self._scrub_cycles += 1
        if self._scrub_cycles % self._scrub_every:
            return
        rep = self._scrub_mgr.scrub_round()
        self.stats["scrub_rounds"] += 1
        self.stats["scrub_tiles"] += rep.tiles_scanned
        self.stats["scrub_detections"] += rep.detections
        repairs = rep.rewrites + rep.remaps + rep.migrations
        self.stats["scrub_repairs"] += repairs
        if (repairs and self._scrub_refresh is not None
                and self._scrub_mgr.pending_faults() == 0):
            if self.hot_swap(self._scrub_refresh):
                self.stats["scrub_refreshes"] += 1

    def _gc_params(self) -> None:
        """Drop param epochs no live or queued-preempted request references."""
        live = {self.params_epoch}
        live.update(s.epoch for s in self.slots if s is not None)
        live.update(
            w.epoch for w in self.waiting if isinstance(w, ResumeState)
        )
        for ep in [e for e in self._params if e not in live]:
            del self._params[ep]
            self.stats["epochs_retired"] += 1

    def _row_buckets(self) -> list[int]:
        return _buckets_upto(self.ecfg.max_slots)

    def _page_buckets(self) -> list[int]:
        return _buckets_upto(self.pcfg.max_pages)

    def prewarm(self) -> int:
        """Compile bucketed dispatch variants up front with dummy dispatches
        aimed at the dummy page (slot state untouched; the pool only absorbs
        garbage into block 0).  Without this, a bucket first seen mid-serve
        pays its XLA compile inside a request's latency.  The decode and
        prefill grids are covered exhaustively; fused variants cover the
        dominant sub-batch combinations (see the inline note).  Returns the
        number of variants compiled."""
        n = 0
        chunk = self.ecfg.prefill_chunk
        page = self.ecfg.page_size
        for q, loop in self._decode_loops.items():
            for rows in self._row_buckets():
                for pages in self._page_buckets():
                    _, self.pools, _ = loop(
                        self.params, self.pools,
                        np.zeros((rows, pages), np.int32),
                        np.zeros((rows, 3), np.int32),
                        np.zeros((rows, 2), np.uint32),
                    )
                    self._shapes_seen.add(("decode", q, rows, pages))
                    n += 1
        min_pf_pages = -(-chunk // page)  # view must fit a chunk
        for rows in self._row_buckets():
            for pages in self._page_buckets():
                if pages < min_pf_pages:
                    continue
                meta = np.zeros((rows, 4), np.int32)
                meta[:, 1] = 1
                _, _, self.pools = self._prefill_step(
                    self.params, self.pools,
                    np.zeros((rows, pages), np.int32),
                    np.zeros((rows, chunk), np.int32),
                    meta,
                    np.zeros((rows, 2), np.uint32),
                )
                self._shapes_seen.add(("prefill", rows, pages))
                n += 1
        # fused variants: the chunk and scan sub-batches bucket
        # independently, so the full (q, c, bp, rows, pages) product is too
        # large to compile eagerly.  Warm the dominant combinations — full
        # chunk width with a lone-admission chunk row (bp=1, the steady-state
        # shape) and an all-prefill chunk (bp=rows, the cold-start shape);
        # rarer widths compile on first use and best-of-N measurement passes
        # absorb them.
        for q, step in self._fused_steps.items():
            for rows in self._row_buckets():
                for pages in self._page_buckets():
                    if pages < min_pf_pages:
                        continue
                    for bp in {1, rows}:
                        pf_meta = np.zeros((bp, 5), np.int32)
                        pf_meta[:, 1] = 1  # pad rows: kv_len 1
                        state = np.zeros((rows, 5), np.int32)
                        state[:, 2] = 1  # greedy: no PRNG consumption
                        _, _, _, self.pools = step(
                            self.params, self.pools,
                            np.zeros((bp, pages), np.int32),
                            np.zeros((bp, chunk), np.int32),
                            pf_meta,
                            np.zeros((bp, 2), np.uint32),
                            np.zeros((rows, pages), np.int32),
                            state,
                            np.zeros((rows, 2), np.uint32),
                            np.full((rows,), -1, np.int32),
                        )
                        self._shapes_seen.add(("fused", q, chunk, bp, rows, pages))
                        n += 1
        jax.block_until_ready(jax.tree.leaves(self.pools))
        return n

    def _cap_tokens(self, req: Request) -> int:
        """Deepest cell a request ever reads: positions [0, prompt +
        max_new - 1).  Dispatch overrun past this lands in allocated page
        tails or the dummy page and is never read — so allocation requests
        clamp here, and this is the footprint ``submit`` checks against the
        pool."""
        return req.prompt.size + req.max_new_tokens - 1

    def submit(self, req: Request) -> None:
        """Queue a request.  Rejects requests that could never complete:
        longer than ``max_seq_len``, or needing more KV blocks than the
        whole pool holds even with every other request preempted."""
        if req.prompt.size + req.max_new_tokens > self.ecfg.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new "
                f"{req.prompt.size + req.max_new_tokens} > max_seq_len "
                f"{self.ecfg.max_seq_len}"
            )
        need = -(-self._cap_tokens(req) // self.ecfg.page_size)
        if need > self.pcfg.usable_blocks:
            raise ValueError(
                f"request {req.rid}: needs {need} KV blocks > pool's "
                f"{self.pcfg.usable_blocks} usable blocks"
            )
        self.waiting.append(req)

    def step(self, now: float) -> bool:
        """One scheduler cycle.  Returns True if any dispatch ran.

        Fused mode: admit, then ONE dispatch advancing every occupied slot —
        prefill rows one chunk, decode rows one quantum, rows finishing
        their prompt rolling straight into decode in-graph.  Split mode:
        admit, one chunked-prefill dispatch over prefilling slots, one
        decode-quantum dispatch over decoding slots (the PR4 discipline,
        kept as the fused path's benchmark baseline).

        After a hot swap the occupied slots may span several param epochs;
        each epoch gets its own dispatch round (same compiled variants —
        only the traced param argument differs), normally exactly one
        extra round for the handful of cycles the old epoch drains."""
        self._expire(now)
        self._admit(now)
        epochs = sorted({s.epoch for s in self.slots if s is not None})
        did = False
        for ep in epochs:
            if self.ecfg.fused:
                did = self._fused_round(now, ep) or did
            else:
                did = self._prefill_round(now, ep) or did
                did = self._decode(now, ep) or did
        self._scrub_tick()
        self._gc_params()
        return did

    def run(self, requests: list[Request]) -> list[RequestResult]:
        """Serve ``requests`` to completion (wall-clock arrival times).

        Admission is FIFO in *arrival* order — the queue is sorted by
        ``arrival_time`` so a late-submitted early arrival can't wedge
        behind a not-yet-arrived head (``_admit`` only inspects the head).
        """
        for r in sorted(requests, key=lambda r: r.arrival_time):
            self.submit(r)
        t0 = time.perf_counter()
        while self.waiting or any(s is not None for s in self.slots):
            now = time.perf_counter() - t0
            if not self.step(now):
                if any(s is not None for s in self.slots):
                    continue  # admission blocked on blocks about to free
                nxt = min(r.arrival_time for r in self.waiting)
                if nxt <= now:
                    raise RuntimeError(
                        "scheduler stalled: request exceeds pool capacity"
                    )
                time.sleep(min(nxt - now, 0.05))
        self.stats["compiled_variants"] = len(self._shapes_seen)
        return [self.results[r.rid] for r in requests]

    # -- deadlines / cancellation / cross-replica records --------------------

    def _finish_waiting(self, item: Union[Request, ResumeState], now: float,
                        status: str) -> None:
        """Record a result for a request that ends while still queued —
        deadline expiry or cancellation before (re-)admission."""
        if isinstance(item, ResumeState):
            req, tokens = item.req, list(item.generated)
            t_admitted, t_first = item.t_admitted, item.t_first_token
        else:
            req, tokens = item, []
            t_admitted = t_first = now
        self.results[req.rid] = RequestResult(
            rid=req.rid, tokens=tokens, t_arrival=req.arrival_time,
            t_admitted=t_admitted, t_first_token=t_first, t_done=now,
            status=status,
        )
        self.stats["timeouts" if status == "timeout" else "cancels"] += 1
        self.stats["tokens_emitted"] += len(tokens)

    def _expire(self, now: float) -> None:
        """Retire everything past its deadline (``arrival_time +
        deadline_s``): occupied slots return their partial tokens and free
        their blocks; queued requests (fresh or preempted) retire in place.
        A deadlined request can never decode forever or wedge the queue."""

        def expired(req: Request) -> bool:
            return req.deadline_s is not None and (
                now >= req.arrival_time + req.deadline_s
            )

        for i, s in enumerate(self.slots):
            if s is not None and expired(s.req):
                self._retire(i, now, status="timeout")
        if any(expired(w.req if isinstance(w, ResumeState) else w)
               for w in self.waiting):
            keep: deque[Union[Request, ResumeState]] = deque()
            for w in self.waiting:
                if expired(w.req if isinstance(w, ResumeState) else w):
                    self._finish_waiting(w, now, "timeout")
                else:
                    keep.append(w)
            self.waiting = keep

    def cancel(self, rid: int, *, now: float = 0.0, status: str = "cancelled") -> bool:
        """Abort request ``rid`` wherever it is — occupied slot (partial
        tokens returned, blocks freed) or waiting queue.  Returns False if
        the request is unknown or already finished.  The fleet router uses
        this to kill the losing copy of a hedged dispatch and to tear down
        draining replicas."""
        for i, s in enumerate(self.slots):
            if s is not None and s.req.rid == rid:
                self._retire(i, now, status=status)
                return True
        for j, w in enumerate(self.waiting):
            if (w.req if isinstance(w, ResumeState) else w).rid == rid:
                del self.waiting[j]
                self._finish_waiting(w, now, status)
                return True
        return False

    def export_state(self, rid: int) -> Optional[ResumeState]:
        """Host-side copy of ``rid``'s progress WITHOUT disturbing this
        engine — no eviction, no device snapshot.  The hedged-dispatch
        primitive: another replica can :meth:`resume` the copy (teacher-
        forced replay of the recorded prefix) while this one keeps running;
        both compute the identical stream, first to finish wins.  None if
        the request is unknown or already finished."""
        for s in self.slots:
            if s is not None and s.req.rid == rid:
                return ResumeState(
                    req=s.req,
                    n_live=0,
                    generated=list(s.generated),
                    tok_next=s.saved_tok if s.replay is not None else s.tok_next,
                    key=np.array(s.key),
                    snapshot=None,
                    t_admitted=s.t_admitted,
                    t_first_token=s.t_first_token,
                )
        for w in self.waiting:
            if isinstance(w, ResumeState) and w.req.rid == rid:
                return dataclasses.replace(w, generated=list(w.generated),
                                           n_live=0, snapshot=None)
            if isinstance(w, Request) and w.rid == rid:
                return ResumeState(
                    req=w, n_live=0, generated=[], tok_next=-1,
                    key=np.asarray(jax.random.PRNGKey(w.seed)), snapshot=None,
                    t_admitted=0.0, t_first_token=0.0,
                )
        return None

    def evict(self, rid: int, *, snapshot: bool = False) -> Optional[ResumeState]:
        """Remove ``rid`` from this engine and return the record another
        replica needs to finish it (drain / migrate).  ``snapshot=True``
        adds the device KV snapshot — byte-identical restore on the
        adopting engine even across different block layouts; without it the
        adopter replays the recorded prefix teacher-forced.  None if the
        request is unknown or already finished."""
        for i, s in enumerate(self.slots):
            if s is not None and s.req.rid == rid:
                return self._evict_record(i, want_snapshot=snapshot)
        for j, w in enumerate(self.waiting):
            if (w.req if isinstance(w, ResumeState) else w).rid == rid:
                del self.waiting[j]
                if isinstance(w, ResumeState):
                    return w
                return ResumeState(
                    req=w, n_live=0, generated=[], tok_next=-1,
                    key=np.asarray(jax.random.PRNGKey(w.seed)), snapshot=None,
                    t_admitted=0.0, t_first_token=0.0,
                )
        return None

    def resume(self, rec: ResumeState) -> None:
        """Adopt a record exported by another engine replica (failover /
        hedging / drain).  The record is re-pinned to THIS engine's current
        param epoch — the parity contract demands all replicas serve
        identical params for the stream to stay bit-identical to solo
        generation — and enqueued FIFO by its original arrival time."""
        req = rec.req
        if req.prompt.size + req.max_new_tokens > self.ecfg.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new "
                f"{req.prompt.size + req.max_new_tokens} > max_seq_len "
                f"{self.ecfg.max_seq_len}"
            )
        need = -(-self._cap_tokens(req) // self.ecfg.page_size)
        if need > self.pcfg.usable_blocks:
            raise ValueError(
                f"request {req.rid}: needs {need} KV blocks > pool's "
                f"{self.pcfg.usable_blocks} usable blocks"
            )
        rec.epoch = self.params_epoch
        self._reinsert(rec)

    # -- admission / preemption ---------------------------------------------

    def _admit(self, now: float) -> None:
        """FIFO admission of the waiting head into free slots.  Fresh
        requests only need blocks for their first prefill chunk (growth is
        lazy); preempted requests restore their snapshot (swap) or start a
        teacher-forced replay (recompute).  Admission itself never preempts
        — new arrivals are the lowest-priority work in the system."""
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.waiting:
                continue
            head = self.waiting[0]
            if head.arrival_time > now:
                break  # FIFO: later arrivals wait behind the head
            if isinstance(head, ResumeState):
                if not self._readmit(i, head):
                    break  # out of blocks until a retirement frees some
            else:
                first = min(self.ecfg.prefill_chunk, head.prompt.size)
                if not self.kv.ensure_capacity(i, first):
                    break
                self.slots[i] = _Slot(head, now, epoch=self.params_epoch)
            self.waiting.popleft()

    def _readmit(self, idx: int, rec: ResumeState) -> bool:
        """Seat a preempted request back into slot ``idx``; False if the
        free list can't yet hold its live cells plus its next prefill chunk.
        The whole block need is secured *before* the device-side snapshot
        restore, so a failed attempt allocates and restores nothing — the
        record stays at the queue head and retries on the next admission
        pass."""
        gen = rec.generated
        prefix = (
            np.concatenate([rec.req.prompt, np.asarray(gen[:-1], np.int32)])
            if gen else rec.req.prompt
        )
        restored = rec.n_live if rec.snapshot is not None else 0
        decode_ready = bool(gen) and restored == prefix.size
        need = restored if decode_ready else (
            restored + min(self.ecfg.prefill_chunk, prefix.size - restored)
        )
        if not self.kv.ensure_capacity(idx, need):
            return False
        if rec.snapshot is not None:
            self.pools = paged_cache.swap_in(self.pools, self.kv, idx, rec.snapshot)
            self.stats["swap_ins"] += 1
        slot = _Slot(rec.req, rec.t_admitted, epoch=rec.epoch)
        slot.key = rec.key
        slot.generated = gen
        slot.t_first_token = rec.t_first_token
        if decode_ready:
            # the whole prefix is back in the cache: resume steady decode
            slot.state = _DECODE
            slot.pos = restored
            slot.tok_next = rec.tok_next
        else:
            # (re-)prefill the rest of the prefix; a request with emitted
            # tokens replays teacher-forced and adopts its pending token
            # instead of sampling when the replay completes
            slot.prefill_done = restored
            if gen:
                slot.replay = prefix
                slot.saved_tok = rec.tok_next
        self.slots[idx] = slot
        self.stats["readmissions"] += 1
        return True

    def _wkey(self, item: Union[Request, ResumeState]) -> tuple[float, int]:
        r = item if isinstance(item, Request) else item.req
        return (r.arrival_time, r.rid)

    def _reinsert(self, rec: ResumeState) -> None:
        """Put a preempted request back into the waiting queue in arrival
        order (every waiting request arrived at or after any running one, so
        this lands at/near the front — FIFO re-admission)."""
        key = self._wkey(rec)
        at = len(self.waiting)
        for j, w in enumerate(self.waiting):
            if self._wkey(w) > key:
                at = j
                break
        self.waiting.insert(at, rec)

    def _vkey(self, slot: _Slot) -> tuple:
        """(protect, prefer) of a slot under the configured victim policy."""
        return (self.ecfg.victim_key or fcfs_victim_key)(slot.view)

    def _pick_victim(self, exclude: int, than: tuple) -> Optional[int]:
        """The most evictable slot whose ``protect`` key is strictly above
        ``than`` (the requester's — strict ordering keeps preemption
        deadlock-free), or None.  Among candidates the policy's ``prefer``
        key picks first (FCFS default: decode slots — a mid-prompt victim
        wastes its partial prefill), ``protect`` breaks ties."""
        best, best_key = None, None
        for i, s in enumerate(self.slots):
            if s is None or i == exclude:
                continue
            protect, prefer = self._vkey(s)
            if protect <= than:
                continue
            key = (prefer, protect)
            if best_key is None or key > best_key:
                best, best_key = i, key
        return best

    def _evict_record(self, idx: int, *, want_snapshot: bool) -> ResumeState:
        """Remove slot ``idx`` and return the record that continues it —
        here (preemption requeue) or on another replica (failover)."""
        slot = self.slots[idx]
        n_live = slot.pos if slot.state == _DECODE else slot.prefill_done
        snapshot = None
        if want_snapshot and n_live:
            snapshot = paged_cache.swap_out(self.pools, self.kv, idx, n_live)
        if not want_snapshot:
            n_live = 0  # drop the cells, replay the prefix on re-admission
        self.kv.release(idx)
        self.slots[idx] = None
        return ResumeState(
            req=slot.req,
            n_live=n_live,
            generated=slot.generated,
            # a mid-replay victim's pending token is its saved one — either
            # way this is the token decode resumes with after the prefix
            tok_next=slot.saved_tok if slot.replay is not None else slot.tok_next,
            key=slot.key,
            snapshot=snapshot,
            t_admitted=slot.t_admitted,
            t_first_token=slot.t_first_token,
            epoch=slot.epoch,
        )

    def _preempt(self, idx: int) -> None:
        """Evict slot ``idx`` under block pressure: snapshot (swap) or drop
        (recompute) its live cells, free its blocks, and requeue it FIFO."""
        want = self.ecfg.preempt == "swap"
        # counted per policy even when there is nothing to snapshot yet
        # (a just-admitted victim) — the stats split swap/recompute by
        # the configured mode, not by whether cells happened to exist
        self.stats["preempt_swap" if want else "preempt_recompute"] += 1
        self.stats["preemptions"] += 1
        self._reinsert(self._evict_record(idx, want_snapshot=want))

    def _ensure_blocks(self, idx: int, n_tokens: int) -> bool:
        """Grow slot ``idx`` to ``n_tokens`` cells, preempting lower-priority
        slots while the free list is short.  False if the slot must skip this
        cycle (it is itself among the lowest-priority work)."""
        protect = self._vkey(self.slots[idx])[0]
        while not self.kv.ensure_capacity(idx, n_tokens):
            victim = self._pick_victim(exclude=idx, than=protect)
            if victim is None:
                return False
            self._preempt(victim)
        return True

    def _secure_rows(self, rows: list[int], need_fn) -> list[int]:
        """Secure each row's block need in priority order (so a starving
        high-priority row evicts low-priority ones, never the reverse) and
        return the sorted survivors.  A row may be preempted out from under
        us by an earlier (higher-priority) row's ensure — its slot is None
        by the time we reach it — or skip the cycle if it cannot get blocks.
        Shared by the fused, prefill, and decode rounds so all three
        dispatch kinds apply one securing policy."""
        kept = []
        for i in sorted(rows, key=lambda i: self._vkey(self.slots[i])[0]):
            s = self.slots[i]
            if s is None:
                continue
            if self._ensure_blocks(i, need_fn(s)):
                kept.append(i)
        return sorted(kept)

    # -- retirement ---------------------------------------------------------

    def _retire(self, idx: int, now: float, status: str = "ok") -> None:
        slot = self.slots[idx]
        self.kv.release(idx)
        self.slots[idx] = None
        self.results[slot.req.rid] = RequestResult(
            rid=slot.req.rid,
            tokens=slot.generated,
            t_arrival=slot.req.arrival_time,
            t_admitted=slot.t_admitted,
            t_first_token=slot.t_first_token,
            t_done=now,
            status=status,
        )
        if status == "timeout":
            self.stats["timeouts"] += 1
        elif status == "cancelled":
            self.stats["cancels"] += 1
        self.stats["tokens_emitted"] += len(slot.generated)

    def _append_token(self, idx: int, tok: int, now: float) -> bool:
        """Append one emitted token; True if the request retired."""
        slot = self.slots[idx]
        slot.generated.append(tok)
        req = slot.req
        if (req.eos_id is not None and tok == req.eos_id) or len(
            slot.generated
        ) >= req.max_new_tokens:
            self._retire(idx, now)
            return True
        return False

    def _choose_quantum(self, remaining: list[int]) -> int:
        """Pick the compiled quantum with the best useful-tokens-per-cost.
        A row contributes min(q, remaining) useful tokens; cost is q steps
        for every row plus a fixed per-dispatch overhead (~2.5
        step-equivalents: scheduling, gather/write-back, host sync).  This
        retires clusters of near-done rows with the short quantum without
        dragging long rows down to one-token dispatches."""
        return max(
            self._quanta,
            key=lambda qq: sum(min(qq, x) for x in remaining) / (qq + 2.5),
        )

    # -- fused dispatch ------------------------------------------------------

    def _fused_round(self, now: float, epoch: int = 0) -> bool:
        """ONE dispatch advancing every occupied slot of ``epoch``: prefill rows a chunk,
        decode rows a quantum, prompt-finishing rows both (first token
        sampled in-graph, then a full decode quantum inside the same
        dispatch).  The dispatch holds two sub-batches — the chunk stage
        bucketed to the prefill rows only, the decode scan to decode +
        finishing rows — so neither side pays for the other's width.
        Degenerate mixes route to the dedicated dispatches: all-decode uses
        the pure decode loop (no dead chunk stage), all-mid-prompt the pure
        chunk step (no dead scan)."""
        occupied = [
            i for i, s in enumerate(self.slots)
            if s is not None and s.epoch == epoch
        ]
        if not occupied:
            return False

        def c_true(s: _Slot) -> int:
            return min(self.ecfg.prefill_chunk, s.target.size - s.prefill_done)

        def finishing(s: _Slot) -> bool:
            return s.prefill_done + c_true(s) == s.target.size

        dec = [i for i in occupied if self.slots[i].state == _DECODE]
        pf = [i for i in occupied if self.slots[i].state == _PREFILL]
        if not pf:
            return self._decode(now, epoch)
        active0 = dec + [i for i in pf if finishing(self.slots[i])]
        if not active0:
            return self._prefill_round(now, epoch)
        # lone-prefill batching (same lever as the split path's deferral): a
        # single fresh admission still pays a whole chunk stage; with more
        # requests queued, waiting one cycle lets the next retirement's
        # admission share it, halving the chunk-stage bill when short
        # requests churn through the slots
        if (
            len(pf) == 1
            and self.waiting
            and not self.slots[pf[0]].pf_deferred
            and len(dec) >= max(2, self.ecfg.max_slots // 2)
        ):
            self.slots[pf[0]].pf_deferred = True
            return self._decode(now, epoch)

        # quantum from the decoding rows' remaining budgets
        rem = [
            self.slots[i].req.max_new_tokens - len(self.slots[i].generated)
            for i in active0
        ]
        q = self._choose_quantum(rem)

        def fused_need(s: _Slot) -> int:
            cap = self._cap_tokens(s.req)
            if s.state == _DECODE:
                return min(s.pos + q, cap)
            if finishing(s):
                return min(s.target.size + q, cap)
            return s.prefill_done + c_true(s)

        rows = self._secure_rows(occupied, fused_need)
        pf_rows = [i for i in rows if self.slots[i].state == _PREFILL]
        scan_rows = [
            i for i in rows
            if self.slots[i].state == _DECODE or finishing(self.slots[i])
        ]
        if not pf_rows:
            return self._decode(now, epoch) if scan_rows else False
        if not scan_rows:
            return self._prefill_round(now, epoch)

        page = self.ecfg.page_size
        c = _bucket(max(c_true(self.slots[i]) for i in pf_rows), self.ecfg.prefill_chunk)
        bp = _bucket(len(pf_rows), self.ecfg.max_slots)
        nb = _bucket(len(scan_rows), self.ecfg.max_slots)

        def scan_pos0(s: _Slot) -> int:
            return s.pos if s.state == _DECODE else s.target.size

        pages = _bucket(
            max(
                max(-(-(self.slots[i].prefill_done + c) // page) for i in pf_rows),
                max(-(-(scan_pos0(self.slots[i]) + q) // page) for i in scan_rows),
            ),
            self.pcfg.max_pages,
        )
        self._shapes_seen.add(("fused", q, c, bp, nb, pages))

        pf_tokens = np.zeros((bp, c), np.int32)
        pf_table = np.zeros((bp, pages), np.int32)
        pf_meta = np.zeros((bp, 5), np.int32)
        pf_meta[:, 1] = 1  # pad rows: kv_len 1 (any valid value)
        pf_keys = np.zeros((bp, 2), np.uint32)
        for m, i in enumerate(pf_rows):
            s = self.slots[i]
            ct = c_true(s)
            start = s.prefill_done
            pf_tokens[m, :ct] = s.target[start : start + ct]
            pf_table[m] = self.kv.table_rows([i], pages)[0]
            pf_keys[m] = s.key
            consume = finishing(s) and s.replay is None  # replays never re-sample
            pf_meta[m] = (start, start + ct, ct - 1, int(s.req.greedy), int(consume))

        table = np.zeros((nb, pages), np.int32)
        state = np.zeros((nb, 5), np.int32)
        state[:, 2] = 1  # pad rows: greedy (no PRNG consumption)
        keys = np.zeros((nb, 2), np.uint32)
        join = np.full((nb,), -1, np.int32)
        for r, i in enumerate(scan_rows):
            s = self.slots[i]
            table[r] = self.kv.table_rows([i], pages)[0]
            keys[r] = s.key
            if s.state == _DECODE:
                state[r] = (s.tok_next, s.pos, int(s.req.greedy), 0, 0)
            else:
                replay = s.replay is not None
                join[r] = pf_rows.index(i)
                state[r] = (
                    0, s.target.size, int(s.req.greedy),
                    s.saved_tok if replay else 0, int(replay),
                )

        pf_tok, toks, keys_out, self.pools = self._fused_steps[q](
            self._params[epoch], self.pools, pf_table, pf_tokens, pf_meta,
            pf_keys, table, state, keys, join,
        )
        pf_tok = np.asarray(pf_tok)
        toks = np.asarray(toks)
        keys_out = np.asarray(keys_out)
        self.stats["fused_dispatches"] += 1
        self.stats["decode_rows_live"] += len(
            [i for i in scan_rows if self.slots[i].state == _DECODE]
        )
        self.stats["decode_rows_padded"] += nb - len(scan_rows)

        for m, i in enumerate(pf_rows):
            self.slots[i].prefill_done += c_true(self.slots[i])
        for r, i in enumerate(scan_rows):
            s = self.slots[i]
            s.key = keys_out[r]
            if s.state == _DECODE:
                self._consume_quantum(i, toks[r, :q], s.pos + q, now)
                continue
            end_pos = s.target.size + q
            s.state = _DECODE
            if s.replay is not None:
                s.replay = None  # the first token was emitted pre-preemption
                self._consume_quantum(i, toks[r, :q], end_pos, now)
                continue
            s.t_first_token = now
            if self._append_token(i, int(pf_tok[join[r]]), now):
                self.stats["tokens_overrun"] += q  # retired on its 1st token
                continue
            self._consume_quantum(i, toks[r, :q], end_pos, now)
        return True

    def _consume_quantum(
        self, idx: int, emitted: np.ndarray, end_pos: int, now: float
    ) -> None:
        """Fold a dispatch's emitted tokens for one row into its slot:
        append until EOS/max-new retirement (counting the overrun), else
        adopt the last token as the next decode input and advance ``pos``
        to the dispatch's final write position."""
        slot = self.slots[idx]
        for j, tok in enumerate(emitted):
            if self._append_token(idx, int(tok), now):
                self.stats["tokens_overrun"] += len(emitted) - 1 - j
                return
        slot.tok_next = int(emitted[-1])
        slot.pos = end_pos

    # -- split prefill ------------------------------------------------------

    def _prefill_round(self, now: float, epoch: int = 0) -> bool:
        """ONE batched dispatch advancing every prefilling slot of
        ``epoch`` by one chunk
        (per-row start/kv_len/table — rows are independent requests).  A
        row's final chunk also samples its first token in-graph (adopted
        unless the row is a recompute replay, whose first token was emitted
        before its preemption)."""
        rows = [
            i for i, s in enumerate(self.slots)
            if s is not None and s.state == _PREFILL and s.epoch == epoch
        ]
        if not rows:
            return False
        # lone-prefill batching: with decode busy and more requests queued, a
        # single fresh admission waits one cycle so the next retirement's
        # admission can share its dispatch (single-row prefills dominate the
        # prefill bill in steady state otherwise).  Only relevant in split
        # mode — the fused path batches a lone prefill with decode anyway.
        if (
            not self.ecfg.fused
            and len(rows) == 1
            and self.waiting
            and not self.slots[rows[0]].pf_deferred
            and sum(
                1 for s in self.slots if s is not None and s.state == _DECODE
            ) >= max(2, self.ecfg.max_slots // 2)
        ):
            self.slots[rows[0]].pf_deferred = True
            return False
        c = self.ecfg.prefill_chunk
        page = self.ecfg.page_size

        rows = self._secure_rows(
            rows,
            lambda s: s.prefill_done + min(c, s.target.size - s.prefill_done),
        )
        if not rows:
            return False
        c_trues = [
            min(c, self.slots[i].target.size - self.slots[i].prefill_done)
            for i in rows
        ]
        nb = _bucket(len(rows), self.ecfg.max_slots)
        # the view must address the full PADDED chunk width [start, start+c):
        # pad-column write-backs beyond a slot's allocation land in the dummy
        # page via its dummy table entries, never clamp onto real cells
        pages = _bucket(
            max(-(-(self.slots[i].prefill_done + c) // page) for i in rows),
            self.pcfg.max_pages,
        )
        self._shapes_seen.add(("prefill", nb, pages))

        tokens = np.zeros((nb, c), np.int32)
        table = np.zeros((nb, pages), np.int32)
        meta = np.zeros((nb, 4), np.int32)
        meta[:, 1] = 1  # pad rows: kv_len 1 (any valid value)
        keys = np.zeros((nb, 2), np.uint32)
        for r, (i, ct) in enumerate(zip(rows, c_trues)):
            slot = self.slots[i]
            start = slot.prefill_done
            tokens[r, :ct] = slot.target[start : start + ct]
            table[r] = self.kv.table_rows([i], pages)[0]
            meta[r] = (start, start + ct, ct - 1, int(slot.req.greedy))
            keys[r] = slot.key

        toks, keys_out, self.pools = self._prefill_step(
            self._params[epoch], self.pools, table, tokens, meta, keys
        )
        self.stats["prefill_dispatches"] += 1
        done_rows = [
            (r, i) for r, (i, ct) in enumerate(zip(rows, c_trues))
            if self.slots[i].prefill_done + ct == self.slots[i].target.size
        ]
        toks_h = np.asarray(toks) if done_rows else None
        keys_h = np.asarray(keys_out) if done_rows else None
        for r, (i, ct) in enumerate(zip(rows, c_trues)):
            slot = self.slots[i]
            slot.prefill_done += ct
            if slot.prefill_done < slot.target.size:
                continue  # mid-prompt chunk: discard tok, keep the unsplit key
            if slot.replay is not None:
                # recompute replay complete: resume decode with the token
                # emitted before preemption — never re-sample it
                slot.pos = slot.replay.size
                slot.tok_next = slot.saved_tok
                slot.replay = None
                slot.state = _DECODE
                continue
            # prompt complete: the dispatch sampled the first token in-graph
            # with the same pick path + PRNG schedule as serve.generate
            slot.key = keys_h[r]
            slot.state = _DECODE
            slot.pos = slot.req.prompt.size
            slot.tok_next = int(toks_h[r])
            slot.t_first_token = now
            self._append_token(i, slot.tok_next, now)
        return True

    # -- split decode -------------------------------------------------------

    def _decode(self, now: float, epoch: int = 0) -> bool:
        """One decode-quantum dispatch over every decoding slot of ``epoch``
        (the pure path — also the fused round's degenerate all-decode case)."""
        rows = [
            i for i, s in enumerate(self.slots)
            if s is not None and s.state == _DECODE and s.epoch == epoch
        ]
        if not rows:
            return False
        rem = [
            self.slots[i].req.max_new_tokens - len(self.slots[i].generated)
            for i in rows
        ]
        q = self._choose_quantum(rem)

        rows = self._secure_rows(
            rows, lambda s: min(s.pos + q, self._cap_tokens(s.req))
        )
        if not rows:
            return False

        page = self.ecfg.page_size
        nb = _bucket(len(rows), self.ecfg.max_slots)
        pages = _bucket(
            max(-(-(self.slots[i].pos + q) // page) for i in rows), self.pcfg.max_pages
        )
        self._shapes_seen.add(("decode", q, nb, pages))

        table = np.zeros((nb, pages), np.int32)  # pad rows -> dummy page
        table[: len(rows)] = self.kv.table_rows(rows, pages)
        state = np.zeros((nb, 3), np.int32)  # [tok, pos, greedy] per row
        state[:, 2] = 1
        keys = np.zeros((nb, 2), np.uint32)
        for r, i in enumerate(rows):
            s = self.slots[i]
            state[r] = (s.tok_next, s.pos, int(s.req.greedy))
            keys[r] = s.key

        toks, self.pools, keys_out = self._decode_loops[q](
            self._params[epoch], self.pools, table, state, keys
        )
        toks = np.asarray(toks)
        keys_out = np.asarray(keys_out)
        self.stats["decode_dispatches"] += 1
        self.stats["decode_rows_live"] += len(rows)
        self.stats["decode_rows_padded"] += nb - len(rows)

        for r, i in enumerate(rows):
            slot = self.slots[i]
            slot.key = keys_out[r]
            self._consume_quantum(i, toks[r, :q], slot.pos + q, now)
        return True


# ---------------------------------------------------------------------------
# Health monitoring: degradation-triggered hot redeploy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Trigger thresholds for :class:`HealthMonitor`.

    ``kl_threshold`` bounds the shadow-batch logit KL of the serving params
    against a clean reference (``simulator.logit_kl`` — the same probe
    ``deploy_and_probe`` reports); ``min_horizon`` bounds the pool's
    ``PoolStats.exhaustion_horizon`` in units of "repeats of the observed
    programming history" under ``endurance`` writes per cell.  Crossing
    either recommends programming the next checkpoint into spare pool
    capacity and ``Engine.hot_swap``-ing it in.
    """

    kl_threshold: float = 0.05
    min_horizon: float = 1.0
    endurance: float = 1e8  # pool.DEFAULT_ENDURANCE (kept literal: no import cycle)
    # a redeploy (or a fleet kill) is expensive and a shadow batch is one
    # noisy sample — require this many *consecutive* breaches before
    # triggering, so one bad probe can't kill a healthy replica
    consecutive_breaches: int = 1

    def __post_init__(self):
        if self.consecutive_breaches < 1:
            raise ValueError(
                f"consecutive_breaches must be >= 1, got {self.consecutive_breaches}"
            )


class HealthMonitor:
    """Samples serving health against a clean reference on a shadow batch.

    The production loop (see docs/architecture.md, hot-redeploy state
    machine): ``check()`` every N cycles → on trigger, prepare replacement
    params (typically: program the next checkpoint through the wear-leveled
    pool) → ``Engine.hot_swap(prepare_fn)`` → in-flight requests drain on
    the old epoch, new admissions serve the new one; a failed prepare rolls
    back and the monitor keeps watching.
    """

    def __init__(self, cfg: ArchConfig, ref_params: Any, shadow_batch: Any,
                 hcfg: HealthConfig = HealthConfig()):
        self.cfg = cfg
        self.ref_params = ref_params
        self.shadow_batch = shadow_batch
        self.hcfg = hcfg
        self.history: list[dict] = []
        self.breaches = 0  # current run of consecutive breached probes

    def probe(self, params: Any) -> float:
        """Shadow-batch logit KL(reference || params) — degradation signal."""
        from repro.core import simulator  # local: engine has no core deps otherwise

        f = lambda p, b: api.forward(p, self.cfg, b)[0]  # noqa: E731
        return float(simulator.logit_kl(f, self.ref_params, params, self.shadow_batch))

    def check(self, params: Any, pool: Any = None) -> tuple[bool, dict]:
        """One health sample; returns (should_redeploy, record).

        ``pool`` (a ``core.pool.CrossbarPool``) adds the wear-endurance
        signal: a redeploy is recommended when logit KL exceeds the
        threshold **or** the pool's exhaustion horizon has dropped below
        ``min_horizon`` — the latter fires even while accuracy is still
        fine, which is the point (move off the worn cells *before* they
        die).

        A single breached probe does not trigger by itself unless
        ``consecutive_breaches == 1``: one bad shadow batch (or a transient
        read upset) is indistinguishable from real degradation on one
        sample, so the trigger requires the configured run of consecutive
        breaches; any healthy probe resets the run.
        """
        kl = self.probe(params)
        horizon = float("inf")
        if pool is not None:
            horizon = pool.stats().exhaustion_horizon(self.hcfg.endurance)
        breach = kl > self.hcfg.kl_threshold or horizon < self.hcfg.min_horizon
        self.breaches = self.breaches + 1 if breach else 0
        trigger = self.breaches >= self.hcfg.consecutive_breaches
        rec = {"kl": kl, "horizon": horizon, "breach": breach,
               "breaches": self.breaches, "trigger": trigger}
        self.history.append(rec)
        return trigger, rec
