"""Continuous-batching CIM serving engine: paged KV + slot scheduler.

Serves heterogeneous, streaming requests from one shared paged KV pool
(``launch.paged_cache``) through shape-bucketed jitted dispatches:

  * **Admission** — waiting requests enter freed decode slots mid-flight as
    soon as a slot and enough KV blocks are available (FIFO).
  * **Chunked prefill** — prompts are processed ``prefill_chunk`` tokens at
    a time; ONE batched dispatch per cycle advances every prefilling slot a
    chunk, so a long prompt never stalls decoding for more than one chunk
    and admissions share dispatches.
  * **Decode quantum** — all decoding slots advance several tokens in ONE
    donated-pool ``lax.scan`` dispatch (``steps.make_paged_decode_loop``),
    masked per-slot: every row has its own position, block-table row, PRNG
    key, and greedy flag.  The quantum length is chosen per dispatch by
    useful-tokens-per-cost from two compiled lengths.
  * **Retirement** — EOS / max-new-tokens ends a request; its blocks return
    to the free list and its slot admits the next queued request.

Shape bucketing keeps the dispatch count compile-friendly: row counts and
page counts are padded to powers of two (dummy rows write to the reserved
dummy page), so the number of compiled variants is O(log(max_slots) *
log(max_pages)) rather than one per ragged shape.

Token parity: each request's stream is bit-identical to a solo
``launch.serve.generate`` run with the same PRNG seed — all three
materializations (dense / packed / planes_int8) flow through
``models.layers.linear`` unchanged (pinned in tests/test_engine.py).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch import steps
from repro.launch.paged_cache import PagedCacheConfig, PagedKVCache
from repro.models import api


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival_time`` is seconds relative to
    ``Engine.run`` start (0.0 = available immediately)."""

    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    greedy: bool = True
    seed: int = 0
    eos_id: Optional[int] = None
    arrival_time: float = 0.0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: list[int]
    t_arrival: float
    t_admitted: float
    t_first_token: float
    t_done: float

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_arrival


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 8
    page_size: int = 16
    max_seq_len: int = 512  # upper bound on prompt + generated per request
    prefill_chunk: int = 32  # max prompt tokens per prefill dispatch
    decode_quantum: int = 8  # decode steps per dispatch
    num_blocks: Optional[int] = None  # default: dummy + max_slots * max_pages


_WAITING, _PREFILL, _DECODE = "waiting", "prefill", "decode"


class _Slot:
    """Host state of one occupied decode slot."""

    def __init__(self, req: Request, t_admitted: float):
        self.req = req
        self.state = _PREFILL
        self.prefill_done = 0  # prompt tokens already written to the pool
        self.pos = 0  # next decode write position (= tokens in cache)
        self.generated: list[int] = []
        self.tok_next = -1  # last emitted token (next decode input)
        self.pf_deferred = False  # lone-prefill batching: deferred one cycle
        self.key = np.asarray(jax.random.PRNGKey(req.seed))
        self.t_admitted = t_admitted
        self.t_first_token = 0.0


def _bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n, clamped to cap — the one bucketing rule
    for dispatch rows AND page counts, so the prewarm grid generators below
    can never drift from the shapes the scheduler actually dispatches."""
    b = 1
    while b < n and b < cap:
        b *= 2
    return min(b, cap)


def _buckets_upto(cap: int) -> list[int]:
    """Every value ``_bucket`` can return for caps up to ``cap``."""
    out, b = [], 1
    while b < cap:
        out.append(b)
        b *= 2
    out.append(cap)
    return out


class Engine:
    """Continuous-batching serving engine over a paged KV pool.

    ``params`` may be any ``deploy_params`` materialization (or plain fp
    weights); they are prepared once (``steps.prepare_serving_params``) so
    non-TPU backends decompress packed operands a single time per deployment.
    """

    def __init__(self, cfg: ArchConfig, params: Any, ecfg: EngineConfig = EngineConfig()):
        if not api.supports_paged(cfg):
            raise NotImplementedError(
                f"{cfg.name}: the paged engine serves pure-attention decoder stacks"
            )
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = steps.prepare_serving_params(params)

        # a slot's dispatches may address up to one decode quantum (decode
        # overrun) or one padded prefill chunk past max_seq_len; writes
        # beyond its allocation land in the dummy page, but the bucketed
        # page view must be wide enough to address them
        overhang = max(ecfg.decode_quantum, ecfg.prefill_chunk)
        max_pages = -(-(ecfg.max_seq_len + overhang) // ecfg.page_size)
        num_blocks = ecfg.num_blocks or 1 + ecfg.max_slots * max_pages
        self.pcfg = PagedCacheConfig(
            page_size=ecfg.page_size,
            num_blocks=num_blocks,
            max_slots=ecfg.max_slots,
            max_pages=max_pages,
        )
        self.kv = PagedKVCache(self.pcfg)
        self.pools = api.init_paged_pools(cfg, self.pcfg.num_tokens)

        donate = steps.cache_donation()
        # two compiled quantum lengths: the full quantum for steady decoding
        # and a short one for when most live rows sit near retirement —
        # heavy-tailed traffic would otherwise overrun every short request
        # by most of a full quantum (or, with a min-remaining policy, drag
        # every long row down to one-token dispatches)
        self._quanta = sorted({max(2, ecfg.decode_quantum // 4), ecfg.decode_quantum})
        self._decode_loops = {
            q: jax.jit(
                steps.make_paged_decode_loop(cfg, q, ecfg.page_size),
                donate_argnums=donate,
            )
            for q in self._quanta
        }
        self._prefill_step = jax.jit(
            steps.make_prefill_chunk_step(cfg, ecfg.page_size),
            donate_argnums=donate,
        )

        self.waiting: deque[Request] = deque()
        self.slots: list[Optional[_Slot]] = [None] * ecfg.max_slots
        self.results: dict[int, RequestResult] = {}
        self._shapes_seen: set[tuple] = set()
        self.stats = {
            "decode_dispatches": 0,
            "prefill_dispatches": 0,
            "decode_rows_live": 0,
            "decode_rows_padded": 0,
            "tokens_emitted": 0,
            "tokens_overrun": 0,
        }

    # -- public API ---------------------------------------------------------

    def _row_buckets(self) -> list[int]:
        return _buckets_upto(self.ecfg.max_slots)

    def _page_buckets(self) -> list[int]:
        return _buckets_upto(self.pcfg.max_pages)

    def prewarm(self) -> int:
        """Compile every bucketed dispatch variant up front with dummy
        dispatches aimed at the dummy page (slot state untouched; the pool
        only absorbs garbage into block 0).  Without this, a bucket first
        seen mid-serve pays its XLA compile inside a request's latency.
        Returns the number of variants compiled."""
        n = 0
        for q, loop in self._decode_loops.items():
            for rows in self._row_buckets():
                for pages in self._page_buckets():
                    _, self.pools, _ = loop(
                        self.params, self.pools,
                        np.zeros((rows, pages), np.int32),
                        np.zeros((rows, 3), np.int32),
                        np.zeros((rows, 2), np.uint32),
                    )
                    self._shapes_seen.add(("decode", q, rows, pages))
                    n += 1
        chunk = self.ecfg.prefill_chunk
        min_pf_pages = -(-chunk // self.ecfg.page_size)  # view must fit a chunk
        for rows in self._row_buckets():
            for pages in self._page_buckets():
                if pages < min_pf_pages:
                    continue
                meta = np.zeros((rows, 4), np.int32)
                meta[:, 1] = 1
                _, _, self.pools = self._prefill_step(
                    self.params, self.pools,
                    np.zeros((rows, pages), np.int32),
                    np.zeros((rows, chunk), np.int32),
                    meta,
                    np.zeros((rows, 2), np.uint32),
                )
                self._shapes_seen.add(("prefill", rows, pages))
                n += 1
        jax.block_until_ready(jax.tree.leaves(self.pools))
        return n

    def submit(self, req: Request) -> None:
        if req.prompt.size + req.max_new_tokens > self.ecfg.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new "
                f"{req.prompt.size + req.max_new_tokens} > max_seq_len "
                f"{self.ecfg.max_seq_len}"
            )
        self.waiting.append(req)

    def step(self, now: float) -> bool:
        """One scheduler cycle: admit, one prefill chunk per prefilling slot,
        one decode quantum over all decoding slots.  Returns True if any
        dispatch ran.

        Advancing *every* prefilling slot one chunk per cycle fills decode
        slots as fast as possible (denser decode batches) while still
        bounding the decode stall to max_slots chunk dispatches — the
        chunking exists so a long prompt can't monopolize the engine for
        its whole prefill."""
        self._admit(now)
        did = self._prefill_round(now)
        did = self._decode(now) or did
        return did

    def run(self, requests: list[Request]) -> list[RequestResult]:
        """Serve ``requests`` to completion (wall-clock arrival times).

        Admission is FIFO in *arrival* order — the queue is sorted by
        ``arrival_time`` so a late-submitted early arrival can't wedge
        behind a not-yet-arrived head (``_admit`` only inspects the head).
        """
        for r in sorted(requests, key=lambda r: r.arrival_time):
            self.submit(r)
        t0 = time.perf_counter()
        while self.waiting or any(s is not None for s in self.slots):
            now = time.perf_counter() - t0
            if not self.step(now):
                if any(s is not None for s in self.slots):
                    continue  # admission blocked on blocks about to free
                nxt = min(r.arrival_time for r in self.waiting)
                if nxt <= now:
                    raise RuntimeError(
                        "scheduler stalled: request exceeds pool capacity"
                    )
                time.sleep(min(nxt - now, 0.05))
        self.stats["compiled_variants"] = len(self._shapes_seen)
        return [self.results[r.rid] for r in requests]

    # -- scheduling ---------------------------------------------------------

    def _admit(self, now: float) -> None:
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.waiting:
                continue
            req = self.waiting[0]
            if req.arrival_time > now:
                break  # FIFO: later arrivals wait behind the head
            cap = req.prompt.size + req.max_new_tokens + self.ecfg.decode_quantum
            if not self.kv.ensure_capacity(i, cap):
                break  # out of blocks until a retirement frees some
            self.waiting.popleft()
            self.slots[i] = _Slot(req, now)

    def _retire(self, idx: int, now: float) -> None:
        slot = self.slots[idx]
        self.kv.release(idx)
        self.slots[idx] = None
        self.results[slot.req.rid] = RequestResult(
            rid=slot.req.rid,
            tokens=slot.generated,
            t_arrival=slot.req.arrival_time,
            t_admitted=slot.t_admitted,
            t_first_token=slot.t_first_token,
            t_done=now,
        )
        self.stats["tokens_emitted"] += len(slot.generated)

    def _append_token(self, idx: int, tok: int, now: float) -> bool:
        """Append one emitted token; True if the request retired."""
        slot = self.slots[idx]
        slot.generated.append(tok)
        req = slot.req
        if (req.eos_id is not None and tok == req.eos_id) or len(
            slot.generated
        ) >= req.max_new_tokens:
            self._retire(idx, now)
            return True
        return False

    # -- prefill ------------------------------------------------------------

    def _prefill_round(self, now: float) -> bool:
        """ONE batched dispatch advancing every prefilling slot by one chunk
        (per-row start/kv_len/table — rows are independent requests).  A
        row's final chunk also samples its first token in-graph."""
        rows = [
            i for i, s in enumerate(self.slots)
            if s is not None and s.state == _PREFILL
        ]
        if not rows:
            return False
        # lone-prefill batching: with decode busy and more requests queued, a
        # single fresh admission waits one cycle so the next retirement's
        # admission can share its dispatch (single-row prefills dominate the
        # prefill bill in steady state otherwise)
        if (
            len(rows) == 1
            and self.waiting
            and not self.slots[rows[0]].pf_deferred
            and sum(
                1 for s in self.slots if s is not None and s.state == _DECODE
            ) >= max(2, self.ecfg.max_slots // 2)
        ):
            self.slots[rows[0]].pf_deferred = True
            return False
        c = self.ecfg.prefill_chunk
        page = self.ecfg.page_size
        nb = _bucket(len(rows), self.ecfg.max_slots)
        c_trues = [
            min(c, self.slots[i].req.prompt.size - self.slots[i].prefill_done)
            for i in rows
        ]
        # the view must address the full PADDED chunk width [start, start+c):
        # pad-column write-backs beyond a slot's allocation land in the dummy
        # page via its dummy table entries, never clamp onto real cells
        pages = _bucket(
            max(-(-(self.slots[i].prefill_done + c) // page) for i in rows),
            self.pcfg.max_pages,
        )
        self._shapes_seen.add(("prefill", nb, pages))

        tokens = np.zeros((nb, c), np.int32)
        table = np.zeros((nb, pages), np.int32)
        meta = np.zeros((nb, 4), np.int32)
        meta[:, 1] = 1  # pad rows: kv_len 1 (any valid value)
        keys = np.zeros((nb, 2), np.uint32)
        for r, (i, ct) in enumerate(zip(rows, c_trues)):
            slot = self.slots[i]
            start = slot.prefill_done
            tokens[r, :ct] = slot.req.prompt[start : start + ct]
            table[r] = self.kv.table_rows([i], pages)[0]
            meta[r] = (start, start + ct, ct - 1, int(slot.req.greedy))
            keys[r] = slot.key

        toks, keys_out, self.pools = self._prefill_step(
            self.params, self.pools, table, tokens, meta, keys
        )
        self.stats["prefill_dispatches"] += 1
        done_rows = [
            (r, i) for r, (i, ct) in enumerate(zip(rows, c_trues))
            if self.slots[i].prefill_done + ct == self.slots[i].req.prompt.size
        ]
        toks_h = np.asarray(toks) if done_rows else None
        keys_h = np.asarray(keys_out) if done_rows else None
        for r, (i, ct) in enumerate(zip(rows, c_trues)):
            slot = self.slots[i]
            slot.prefill_done += ct
            if slot.prefill_done < slot.req.prompt.size:
                continue  # mid-prompt chunk: discard tok, keep the unsplit key
            # prompt complete: the dispatch sampled the first token in-graph
            # with the same pick path + PRNG schedule as serve.generate
            slot.key = keys_h[r]
            slot.state = _DECODE
            slot.pos = slot.req.prompt.size
            slot.tok_next = int(toks_h[r])
            slot.t_first_token = now
            self._append_token(i, slot.tok_next, now)
        return True

    # -- decode -------------------------------------------------------------

    def _decode(self, now: float) -> bool:
        rows = [i for i, s in enumerate(self.slots) if s is not None and s.state == _DECODE]
        if not rows:
            return False
        # quantum: pick the compiled length with the best useful-tokens-per-
        # cost.  A row contributes min(q, remaining) useful tokens; cost is
        # q steps for every row plus a fixed per-dispatch overhead (~2.5
        # step-equivalents: scheduling, gather/write-back, host sync).
        # This retires clusters of near-done rows with the short quantum
        # without dragging long rows down to one-token dispatches.
        rem = [
            self.slots[i].req.max_new_tokens - len(self.slots[i].generated)
            for i in rows
        ]
        q = max(
            self._quanta,
            key=lambda qq: sum(min(qq, x) for x in rem) / (qq + 2.5),
        )
        page = self.ecfg.page_size
        nb = _bucket(len(rows), self.ecfg.max_slots)
        pages = _bucket(
            max(-(-(self.slots[i].pos + q) // page) for i in rows), self.pcfg.max_pages
        )
        self._shapes_seen.add(("decode", q, nb, pages))

        table = np.zeros((nb, pages), np.int32)  # pad rows -> dummy page
        table[: len(rows)] = self.kv.table_rows(rows, pages)
        state = np.zeros((nb, 3), np.int32)  # [tok, pos, greedy] per row
        state[:, 2] = 1
        keys = np.zeros((nb, 2), np.uint32)
        for r, i in enumerate(rows):
            s = self.slots[i]
            state[r] = (s.tok_next, s.pos, int(s.req.greedy))
            keys[r] = s.key

        toks, self.pools, keys_out = self._decode_loops[q](
            self.params, self.pools, table, state, keys
        )
        toks = np.asarray(toks)
        keys_out = np.asarray(keys_out)
        self.stats["decode_dispatches"] += 1
        self.stats["decode_rows_live"] += len(rows)
        self.stats["decode_rows_padded"] += nb - len(rows)

        for r, i in enumerate(rows):
            slot = self.slots[i]
            retired = False
            for j in range(q):
                if self._append_token(i, int(toks[r, j]), now):
                    retired = True
                    self.stats["tokens_overrun"] += q - 1 - j
                    break
            if not retired:
                slot.tok_next = int(toks[r, -1])
                slot.key = keys_out[r]
                slot.pos += q
        return True
