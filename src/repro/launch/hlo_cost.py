"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` (HloCostAnalysis) counts each ``while`` body
ONCE, so any scan-over-layers model under-reports FLOPs/bytes by ~n_layers.
This analyzer re-derives per-device cost from the partitioned HLO text with
call-graph multiplicities:

  * while bodies/conditions weighted by ``known_trip_count`` from
    backend_config (present for all lax.scan loops);
  * fusion computations: FLOPs counted inside, bytes charged at the fusion
    call site (operands + result — XLA's own bytes-accessed model);
  * dot FLOPs = 2 * prod(result dims) * prod(lhs contracting dims);
  * bytes = operands + result for every non-free top-level op
    (parameter/constant/gte/tuple/bitcast are free);
  * collectives priced with ring factors and replica-group size, weighted by
    multiplicity (a collective inside the layer loop fires every layer).

Validated against analytic 6ND/8ND expectations in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "token", "partition-id", "replica-id", "iota",
}

_CONTROL_OPS = {"while", "conditional", "call", "fusion", "async-start", "async-done"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}\s]*?\S))\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\],{}/\* ]+?))(?:,|\)\s*->)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,\s]*?)\}")
_REF_RES = {
    "body": re.compile(r"body=%?([\w\.\-]+)"),
    "condition": re.compile(r"condition=%?([\w\.\-]+)"),
    "calls": re.compile(r"calls=%?([\w\.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w\.\-]+)"),
}
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = 0
    byts = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dtype]
    return elems, byts


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: list
    symtab: dict  # name -> type_str
    is_entry: bool = False


def _parse_computations(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for raw in hlo.splitlines():
        if raw and not raw[0].isspace() and "{" in raw and "(" in raw and "->" in raw:
            m = _COMP_HDR.match(raw)
            if m:
                cur = _Comp(m.group(2), [], {}, is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                hdr = raw[raw.index("(") :]
                for pname, ptype in _PARAM_RE.findall(hdr):
                    cur.symtab[pname] = ptype
                continue
        if cur is None:
            continue
        if raw.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(raw)
        if m:
            name, type_str, op = m.group(1), m.group(2), m.group(3)
            cur.symtab[name] = type_str
            cur.instrs.append(_Instr(name, type_str, op, raw))
    return comps


def _multiplicities(comps: dict[str, _Comp]) -> tuple[dict[str, float], set[str]]:
    """Comp name -> times executed; plus the set of fusion-internal comps."""
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: dict[str, float] = defaultdict(float)
    fusion_internal: set[str] = set()
    if entry is None:
        return mult, fusion_internal
    stack = [(entry, 1.0)]
    seen_guard = 0
    while stack:
        seen_guard += 1
        if seen_guard > 200_000:
            break
        cname, m = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        mult[cname] += m
        for ins in comp.instrs:
            if ins.op == "while":
                trip_m = _TRIP_RE.search(ins.line)
                trip = int(trip_m.group(1)) if trip_m else 1
                b = _REF_RES["body"].search(ins.line)
                c = _REF_RES["condition"].search(ins.line)
                if b:
                    stack.append((b.group(1), m * trip))
                if c:
                    stack.append((c.group(1), m * (trip + 1)))
            elif ins.op == "fusion":
                r = _REF_RES["calls"].search(ins.line)
                if r:
                    fusion_internal.add(r.group(1))
                    stack.append((r.group(1), m))
            elif ins.op in ("call", "custom-call", "async-start"):
                r = _REF_RES["calls"].search(ins.line) or _REF_RES["to_apply"].search(ins.line)
                if r:
                    stack.append((r.group(1), m))
            elif ins.op == "conditional":
                br = _BRANCHES_RE.search(ins.line)
                if br:
                    for b in _OPERANDS_RE.findall(br.group(1)):
                        stack.append((b, m))
            else:
                r = _REF_RES["to_apply"].search(ins.line)
                if r:
                    # reducer computations: scalar ops, negligible; still walk
                    stack.append((r.group(1), m))
    return mult, fusion_internal


def _dot_flops(ins: _Instr, symtab: dict) -> float:
    dims = _dims_of(ins.type_str)
    out = 1
    for d in dims:
        out *= d
    cm = _CONTRACT_RE.search(ins.line)
    contract = 1
    if cm:
        # first operand name
        ops = _OPERANDS_RE.findall(ins.line.split("(", 1)[1])
        if ops:
            lhs_type = symtab.get(ops[0], "")
            lhs_dims = _dims_of(lhs_type)
            idxs = [int(i) for i in cm.group(1).split(",")] if cm.group(1) else []
            for i in idxs:
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2.0 * out * contract


def _operand_types(ins: _Instr, symtab: dict) -> list[str]:
    paren = ins.line.split("(", 1)
    if len(paren) < 2:
        return []
    arglist = paren[1].split(")", 1)[0]
    return [symtab[o] for o in _OPERANDS_RE.findall(arglist) if o in symtab]


def _operand_names(ins: _Instr) -> list[str]:
    paren = ins.line.split("(", 1)
    if len(paren) < 2:
        return []
    arglist = paren[1].split(")", 1)[0]
    return _OPERANDS_RE.findall(arglist)


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _instr_bytes(ins: _Instr, symtab: dict, comps: dict | None = None) -> float:
    """Approximate HBM traffic of one op (XLA bytes-accessed flavoured).

    In-place updates are special-cased: XLA aliases the big operand of
    dynamic-update-slice (and of fusions whose root is one), so only the
    updated region moves — without this, a scan carrying a large stacked
    buffer looks like it rewrites the whole buffer every iteration.
    """
    _, rbytes = _shape_elems_bytes(ins.type_str)
    opnds = _operand_types(ins, symtab)

    if ins.op == "dynamic-update-slice":
        upd = _shape_elems_bytes(opnds[1])[1] if len(opnds) > 1 else rbytes
        return 2.0 * upd
    if ins.op in ("dynamic-slice", "slice"):
        return 2.0 * rbytes
    if ins.op == "fusion" and comps is not None:
        r = _REF_RES["calls"].search(ins.line)
        called = comps.get(r.group(1)) if r else None
        if called and called.instrs:
            return _fusion_bytes(called, opnds, rbytes)
    total = float(rbytes)
    for t in opnds:
        total += _shape_elems_bytes(t)[1]
    return total


def _fusion_bytes(called: _Comp, opnd_types: list[str], rbytes: int) -> float:
    """Bytes a fusion moves: DUS-aware outputs + slice-aware operands.

    Scan-body fusions typically ROOT in a tuple of dynamic-update-slices
    into loop-carried stacked buffers (remat saves, KV caches).  XLA aliases
    those buffers in place, so only the updated region moves — charging the
    full buffer every iteration inflates the memory term by the trip count.
    """
    insts = {i.name: i for i in called.instrs}
    root = called.instrs[-1]
    elems = _operand_names(root) if root.op == "tuple" else [root.name]

    out_bytes = 0.0
    aliased: set[str] = set()
    for name in elems:
        rt = insts.get(name)
        if rt is not None and rt.op == "dynamic-update-slice":
            types = _operand_types(rt, called.symtab)
            out_bytes += 2.0 * (_shape_elems_bytes(types[1])[1] if len(types) > 1 else 0)
            onames = _operand_names(rt)
            if onames:
                aliased.add(onames[0])  # the in-place big buffer
        elif rt is not None:
            out_bytes += _shape_elems_bytes(rt.type_str)[1]
        else:
            out_bytes += 0.0
    if root.op != "tuple" and root.op != "dynamic-update-slice":
        out_bytes = float(rbytes)

    # parameter index -> instr name (for operand attribution)
    param_name: dict[int, str] = {}
    for i in called.instrs:
        if i.op == "parameter":
            m = _PARAM_IDX_RE.search(i.line)
            if m:
                param_name[int(m.group(1))] = i.name

    in_bytes = 0.0
    for i, t in enumerate(opnd_types):
        full = _shape_elems_bytes(t)[1]
        pname = param_name.get(i)
        if pname is None:
            in_bytes += full
            continue
        if pname in aliased:
            continue  # in-place updated buffer: write side already charged
        consumers = [
            c for c in called.instrs
            if c.op != "parameter" and pname in _operand_names(c)
        ]
        if consumers and all(
            c.op in ("dynamic-slice", "gather", "slice") for c in consumers
        ):
            in_bytes += sum(_shape_elems_bytes(c.type_str)[1] for c in consumers)
        else:
            in_bytes += full
    return out_bytes + in_bytes


def _collective_wire(ins: _Instr) -> float:
    base = ins.op.removesuffix("-start")
    _, r = _shape_elems_bytes(ins.type_str)
    m = _IOTA_GROUPS_RE.search(ins.line)
    if m:
        g = int(m.group(2))
    else:
        m2 = _LIST_GROUPS_RE.search(ins.line)
        g = len(m2.group(1).split(",")) if (m2 and m2.group(1).strip()) else 2
    if g <= 1:
        return 0.0
    if base == "all-reduce":
        return 2.0 * r * (g - 1) / g
    if base == "all-gather":
        return r * (g - 1) / g
    if base == "reduce-scatter":
        return float(r) * (g - 1)
    if base == "all-to-all":
        return r * (g - 1) / g
    return float(r)  # collective-permute


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_accessed: float
    wire_bytes: float
    collective_counts: dict[str, float]
    collective_wire: dict[str, float]
    n_while: int
    max_trip: int


def analyze(hlo: str) -> HloCost:
    comps = _parse_computations(hlo)
    mult, fusion_internal = _multiplicities(comps)

    flops = 0.0
    byts = 0.0
    wire = 0.0
    coll_counts: dict[str, float] = defaultdict(float)
    coll_wire: dict[str, float] = defaultdict(float)
    n_while = 0
    max_trip = 1

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fusion_internal
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, comp.symtab)
            if ins.op == "while":
                n_while += 1
                t = _TRIP_RE.search(ins.line)
                if t:
                    max_trip = max(max_trip, int(t.group(1)))
            base = ins.op.removesuffix("-start")
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                w = _collective_wire(ins)
                wire += m * w
                coll_counts[base] += m
                coll_wire[base] += m * w
            if in_fusion:
                continue  # bytes charged at the fusion call site
            if ins.op in _FREE_OPS or ins.op in ("while", "conditional", "call"):
                continue
            byts += m * _instr_bytes(ins, comp.symtab, comps)
    return HloCost(
        flops=flops,
        bytes_accessed=byts,
        wire_bytes=wire,
        collective_counts=dict(coll_counts),
        collective_wire=dict(coll_wire),
        n_while=n_while,
        max_trip=max_trip,
    )
