"""Fault-tolerant serving fleet: a request router over N engine replicas.

One :class:`Engine` (launch/engine.py) is a single replica: a stalled
dispatch, a dead process, or a worn-out crossbar pool takes every request
on it down.  This module turns the per-replica signals the stack already
produces — queue depth, ``CrossbarPool`` endurance horizon, injected fault
state, ``StragglerPolicy`` step-time EWMA, ``HealthMonitor`` probes — into
fleet-level routing, failover, and graceful degradation:

  * **Placement** — each admitted request lands on the lowest-cost LIVE
    replica: ``w_queue * backlog + w_wear / endurance_horizon + w_fault *
    stuck_cell_fraction + w_straggler * consecutive_slow_marks`` (weights in
    :class:`FleetConfig`).  A wearing-out or fault-ridden replica keeps
    serving, it just attracts less new work — the paper's endurance
    accounting acting as a *routing* signal.
  * **Deadlines & retries** — requests carry ``deadline_s`` (enforced by
    the engines: expired work retires as ``status="timeout"`` with partial
    tokens, never hangs).  Work lost to a replica failure re-enters the
    fleet queue with a jittered exponential not-before timestamp
    (``runtime.fault.backoff_delay`` — the same formula
    ``run_with_retries`` sleeps, turned into queue time so the router keeps
    serving healthy replicas while the retry waits out its backoff).
  * **Failover** — a crashed replica's in-flight requests are salvaged from
    its host-side scheduler state (``Engine.export_state``: prompt +
    emitted tokens + pending token + PRNG key) and resumed on another
    replica as a teacher-forced replay — already-emitted tokens are never
    re-sampled, so the completed stream stays bit-identical to solo
    ``serve.generate``.  A crash that loses host state too
    (``lose_state=True``), or ``failover="restart"``, re-runs the request
    from scratch — generation is deterministic per seed, so the stream is
    *still* identical.  Draining a live replica migrates its work with
    device snapshots (``Engine.evict(snapshot=True)`` →
    ``paged_cache.swap_out`` → byte-identical ``swap_in`` on the adopter).
  * **Hedging** — a replica that stops making progress (wall-clock stall)
    or accumulates ``hedge_after_marks`` consecutive straggler marks gets
    its in-flight requests *duplicated* onto a healthy replica
    (``export_state`` → ``resume``); both copies compute the identical
    stream, the first to finish wins, and the loser is
    ``Engine.cancel``-ed.  Tail latency protection without ever forking
    the token stream.
  * **Admission control** — the fleet queue is bounded (``max_queue``;
    overflow is *shed* with ``status="shed"`` rather than queued forever),
    and above ``degrade_backlog`` the fleet enters degraded mode: new
    requests get their ``max_new_tokens`` clamped to ``degrade_cap`` —
    shorter answers for everyone beats no answers for some.
  * **Lifecycle** — replicas are health-checked (``HealthMonitor.probe``
    shadow-batch KL every ``health_every`` cycles; a failing probe kills
    the replica and fails its work over), drained (:meth:`Fleet.drain`),
    killed (:meth:`Fleet.kill`), and restored (:meth:`Fleet.restore` — a
    fresh engine sharing the fleet's compiled dispatches).

:class:`FaultInjector` drives deterministic chaos traces — crash-on-step-k
(with or without host state), stall-for-s, slow-by-factor, and
corrupt-health-probe — keyed on replica-local step counts so a trace
replays identically.  ``benchmarks/fleet_tolerance.py`` gates the whole
contract in CI: kill-one-of-4 and stall traces must complete 100% of
admitted requests with every completed stream bit-identical to solo
generation.

Replicas are data-parallel over ``launch.mesh.replica_submeshes`` (the
"data" axis; CPU development emulates the mesh with
``--xla_force_host_platform_device_count``).  With
``FleetConfig.shards_per_replica > 1`` each replica is additionally
tensor-parallel over its own contiguous "model"-axis device group
(``parallel/tp.py``) — shards-of-meshes.  All replicas serve the same
param tree — placement-, failover-, and hedge-routing never change any
request's tokens, only *where* and *whether* they are computed.
"""
from __future__ import annotations

import dataclasses
import random
import time
from collections import deque
from typing import Any, Optional, Union

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch.engine import (
    Engine,
    EngineConfig,
    HealthMonitor,
    Request,
    ResumeState,
)
from repro.launch.mesh import replica_submeshes
from repro.runtime.fault import FaultPolicy, StragglerPolicy, backoff_delay

LIVE, DRAINING, DOWN, DEAD = "live", "draining", "down", "dead"


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Routing + robustness policy for the fleet.

    ``max_queue`` bounds total fleet backlog (fleet queue + every engine's
    waiting line) — submissions beyond it are shed.  ``degrade_backlog``
    (default: half of ``max_queue``) triggers degraded mode.  ``retry``
    prices the jittered re-placement backoff after a replica failure
    (``backoff_s``/``jitter``/``seed``; ``max_retries`` bounds placements
    per request — a request that loses its replica more often than that is
    shed).  ``hedge_stall_s`` is the no-progress wall-clock bound before a
    replica's in-flight work is hedged; ``hedge_after_marks`` the
    consecutive straggler-mark bound (either triggers).
    """

    n_replicas: int = 2
    # tensor-parallel width of each replica: every replica's engine runs
    # its model sharded this many ways over a contiguous "model"-axis
    # device group (launch.mesh.replica_submeshes).  1 = the plain
    # single-device engine.
    shards_per_replica: int = 1
    max_queue: int = 64
    degrade_backlog: Optional[int] = None
    degrade_cap: int = 8
    default_deadline_s: Optional[float] = None
    retry: FaultPolicy = FaultPolicy(max_retries=3, backoff_s=0.0, jitter=0.5)
    failover: str = "resume"  # "resume" (recorded prefix) | "restart"
    hedge: bool = True
    hedge_stall_s: float = 0.5
    hedge_after_marks: int = 2
    straggler_tolerance: float = 3.0
    health_every: int = 0  # probe cadence in cycles; 0 = off
    w_queue: float = 1.0
    w_wear: float = 1.0
    w_fault: float = 100.0
    w_straggler: float = 1.0
    # scrub findings feed placement: every known-but-unrepaired fault on a
    # replica's pool (core/integrity.py pending backlog) costs this much, so
    # traffic routes around replicas mid-repair until their scrubber
    # converges (they are also excluded outright while healthy peers exist)
    w_scrub: float = 10.0

    def __post_init__(self):
        if self.n_replicas < 1:
            raise ValueError("need at least one replica")
        if self.shards_per_replica < 1:
            raise ValueError("need at least one shard per replica")
        if self.max_queue < 1:
            raise ValueError("max_queue must be positive")
        if self.failover not in ("resume", "restart"):
            raise ValueError(
                f"unknown failover mode {self.failover!r}; "
                f"choose 'resume' or 'restart'"
            )
        if self.hedge_stall_s <= 0 or self.hedge_after_marks < 1:
            raise ValueError("hedge_stall_s must be > 0, hedge_after_marks >= 1")

    @property
    def degrade_at(self) -> int:
        return self.degrade_backlog if self.degrade_backlog is not None else (
            self.max_queue // 2
        )


@dataclasses.dataclass
class ChaosEvent:
    """One deterministic chaos action, fired when ``replica`` reaches its
    ``at_step``-th scheduler cycle (replica-local count — traces replay
    identically regardless of wall clock)."""

    replica: int
    at_step: int
    kind: str  # "crash" | "stall" | "slow" | "corrupt_probe" | "storm"
    duration_s: float = 0.0  # stall: wall-clock seconds of no progress
    factor: float = 1.0  # slow: reported step-wall multiplier
    steps: int = 1  # slow: cycles affected; corrupt_probe: probes affected
    lose_state: bool = False  # crash: host scheduler state unrecoverable too
    corrupt: float = 0.0  # storm: stored-bit corruption rate
    stuck: float = 0.0  # storm: new hard stuck-at cell rate
    fired: bool = False


class FaultInjector:
    """Deterministic chaos plans for :class:`Fleet` traces.

    Events are armed per replica at a replica-local step count; the fleet
    consults :meth:`fire` before stepping each replica and applies whatever
    comes back.  ``log`` records every fired event (with the fleet clock)
    for the benchmark report.
    """

    def __init__(self):
        self.events: list[ChaosEvent] = []
        self.log: list[dict] = []

    def crash(self, replica: int, at_step: int, *, lose_state: bool = False) -> None:
        """Hard-kill ``replica`` at its ``at_step``-th cycle.  With
        ``lose_state`` even the host scheduler records are gone — failover
        must restart the lost requests from scratch."""
        self.events.append(ChaosEvent(replica, at_step, "crash", lose_state=lose_state))

    def stall(self, replica: int, at_step: int, duration_s: float) -> None:
        """Freeze ``replica`` for ``duration_s`` wall-clock seconds — its
        dispatches hang (no progress) but nothing is lost; the hedging path
        must cover its in-flight requests in the meantime."""
        self.events.append(ChaosEvent(replica, at_step, "stall", duration_s=duration_s))

    def slow(self, replica: int, at_step: int, factor: float, steps: int = 4) -> None:
        """Inflate ``replica``'s *reported* step wall by ``factor`` for
        ``steps`` cycles — the straggler-EWMA detection path, without
        actually sleeping the benchmark."""
        self.events.append(ChaosEvent(replica, at_step, "slow", factor=factor, steps=steps))

    def corrupt_probe(self, replica: int, at_step: int, probes: int = 1) -> None:
        """Make ``replica``'s next ``probes`` health probes return garbage
        (infinite KL) — the fleet kills a perfectly healthy replica and its
        failover path must still preserve every stream."""
        self.events.append(ChaosEvent(replica, at_step, "corrupt_probe", steps=probes))

    def storm(self, replica: int, at_step: int, *, corrupt: float = 1e-3,
              stuck: float = 1e-4) -> None:
        """Unleash a mid-trace fault storm on ``replica``'s crossbar pool:
        stored bits flip at ``corrupt`` and new hard stuck-at cells appear
        at ``stuck`` (``core.integrity.IntegrityManager.storm``).  Requires
        the replica's pool to have integrity enabled; the scrub/repair loop
        — not failover — is what must recover the replica."""
        self.events.append(
            ChaosEvent(replica, at_step, "storm", corrupt=corrupt, stuck=stuck)
        )

    def fire(self, replica: int, step: int, now: float) -> list[ChaosEvent]:
        """Pop (mark fired + log) every armed event for ``replica`` whose
        ``at_step`` has been reached."""
        out = []
        for ev in self.events:
            if ev.fired or ev.replica != replica or step < ev.at_step:
                continue
            ev.fired = True
            self.log.append({"t": now, "replica": replica, "step": step,
                             "kind": ev.kind})
            out.append(ev)
        return out


class Replica:
    """One engine replica plus the host-side signals the router scores."""

    def __init__(self, rid: int, cfg: ArchConfig, params: Any, ecfg: EngineConfig,
                 *, devices=None, pool=None, fcfg: FleetConfig,
                 dispatch_from: Optional[Engine] = None):
        self.id = rid
        # the replica's contiguous "model"-axis device group; devices[0]
        # hosts the engine's host-side state and any non-sharded compute
        self.devices = list(devices) if devices else None
        self.device = self.devices[0] if self.devices else None
        self.pool = pool  # Optional[CrossbarPool]: wear + fault signals
        self.state = LIVE
        if self.device is not None:
            params = jax.device_put(params, self.device)
        tp = fcfg.shards_per_replica
        self.engine = Engine(cfg, params, ecfg, dispatch_from=dispatch_from,
                             tp=tp, tp_devices=self.devices if tp > 1 else None)
        self.straggler = StragglerPolicy(
            tolerance=fcfg.straggler_tolerance, warmup_steps=2,
            demote_after=max(fcfg.hedge_after_marks, 1),
        )
        self.steps = 0  # scheduler cycles this incarnation has run
        self.marks = 0  # consecutive straggler marks (hedge trigger)
        self.stall_until = 0.0  # injected stall: frozen while now < this
        self.slow_factor = 1.0
        self.slow_left = 0
        self.probe_corrupt_left = 0
        self.probe_breaches = 0  # consecutive failed health probes
        self.last_progress = 0.0  # fleet clock of the last completed step
        self.reported: set[int] = set()  # rids whose engine result was collected

    @property
    def alive(self) -> bool:
        return self.state in (LIVE, DRAINING)

    def stalled(self, now: float) -> bool:
        return now < self.stall_until

    def backlog(self) -> int:
        """Requests this replica still owes: occupied slots + waiting line."""
        eng = self.engine
        return sum(s is not None for s in eng.slots) + len(eng.waiting)

    def inflight_rids(self) -> list[int]:
        """Every rid currently on this replica (slots first, then queue)."""
        eng = self.engine
        out = [s.req.rid for s in eng.slots if s is not None]
        out += [
            (w.req if isinstance(w, ResumeState) else w).rid for w in eng.waiting
        ]
        return out

    def mid_repair(self) -> bool:
        """The replica's scrubber has found faults it hasn't repaired yet."""
        return (
            self.pool is not None
            and self.pool.integrity is not None
            and self.pool.integrity.pending_faults() > 0
        )

    def score(self, fcfg: FleetConfig) -> float:
        """Placement cost — smaller attracts more work."""
        cost = fcfg.w_queue * self.backlog() + fcfg.w_straggler * self.marks
        if self.pool is not None:
            horizon = self.pool.stats().exhaustion_horizon()
            if np.isfinite(horizon):
                cost += fcfg.w_wear / max(horizon, 1e-9)
            if self.pool.faults is not None:
                frac = float(self.pool.faults.fault_cells().sum()) / max(
                    self.pool.wear.size, 1
                )
                cost += fcfg.w_fault * frac
            if self.pool.integrity is not None:
                # scrub findings: every pending (detected, unrepaired) fault
                # makes this replica less attractive until repair converges
                cost += fcfg.w_scrub * self.pool.integrity.pending_faults()
        return cost


@dataclasses.dataclass
class FleetResult:
    """Fleet-level outcome of one request.  ``status``: ``"ok"`` /
    ``"timeout"`` (deadline) / ``"shed"`` (admission refused — never
    placed).  ``replica`` is the replica whose stream was adopted (None for
    shed), ``attempts`` the number of placements (>1 = retried or hedged),
    ``hedged`` whether a duplicate dispatch ever ran."""

    rid: int
    tokens: list[int]
    status: str
    replica: Optional[int]
    attempts: int
    t_arrival: float
    t_done: float
    hedged: bool = False

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival


@dataclasses.dataclass
class _Pending:
    """A fleet-queue entry: a fresh request or a salvaged resume record,
    not placeable before ``not_before`` (retry backoff)."""

    item: Union[Request, ResumeState]
    attempts: int = 0
    not_before: float = 0.0

    @property
    def req(self) -> Request:
        return self.item.req if isinstance(self.item, ResumeState) else self.item


class Fleet:
    """Request router over ``FleetConfig.n_replicas`` engine replicas.

    ``params`` is one serving tree shared by every replica (device_put per
    replica along the data axis); ``pools`` optionally attaches each
    replica's ``CrossbarPool`` (wear/fault placement signals);
    ``monitor`` + ``FleetConfig.health_every`` enable shadow-batch health
    probes; ``injector`` arms deterministic chaos.  Drive it with
    :meth:`run` (self-clocked trace, like ``Engine.run``) or externally
    with :meth:`submit` + :meth:`step`.
    """

    def __init__(self, cfg: ArchConfig, params: Any,
                 fcfg: FleetConfig = FleetConfig(),
                 ecfg: EngineConfig = EngineConfig(), *,
                 pools: Optional[list] = None,
                 devices: Optional[list] = None,
                 monitor: Optional[HealthMonitor] = None,
                 injector: Optional[FaultInjector] = None):
        self.cfg = cfg
        self.fcfg = fcfg
        self.ecfg = ecfg
        self.params = params
        self.monitor = monitor
        self.injector = injector
        if pools is not None and len(pools) != fcfg.n_replicas:
            raise ValueError("pools must have one entry per replica")
        if devices is None:
            groups = replica_submeshes(fcfg.n_replicas, fcfg.shards_per_replica)
        else:
            # accept a flat device list (one device per replica, the PR 8
            # signature) or an explicit list of per-replica device groups
            groups = [d if isinstance(d, (list, tuple)) else [d] for d in devices]
        self.replicas: list[Replica] = []
        template: Optional[Engine] = None
        for i in range(fcfg.n_replicas):
            r = Replica(
                i, cfg, params, ecfg, devices=groups[i % len(groups)],
                pool=pools[i] if pools else None, fcfg=fcfg,
                dispatch_from=template,
            )
            template = template or r.engine
            self.replicas.append(r)
        # the compiled-dispatch donor outlives any replica that crashes —
        # restore() clones from it even if replica 0 is long dead
        self._dispatch_template = template
        self._rng = random.Random(fcfg.retry.seed)
        self.queue: deque[_Pending] = deque()
        self.results: dict[int, FleetResult] = {}
        self.requests: dict[int, Request] = {}  # originals, for clean restarts
        self.placements: dict[int, set[int]] = {}  # rid -> replica ids serving it
        self.attempts: dict[int, int] = {}
        self.hedged: set[int] = set()
        self.cycle = 0
        self._now = 0.0
        self.stats = {
            "submitted": 0, "admitted": 0, "shed": 0, "degraded": 0,
            "placements": 0, "retries": 0, "failovers": 0, "restarts": 0,
            "hedges": 0, "cancels": 0, "completed": 0, "timeouts": 0,
            "crashes": 0, "stalls": 0, "slows": 0, "kills": 0, "drains": 0,
            "restores": 0, "probes": 0, "probe_failures": 0, "storms": 0,
        }

    # -- admission -----------------------------------------------------------

    def backlog(self) -> int:
        """Total unserved demand: fleet queue + every live replica's line."""
        return len(self.queue) + sum(
            r.backlog() for r in self.replicas if r.alive
        )

    def submit(self, req: Request, now: Optional[float] = None) -> bool:
        """Admit (or shed) one request.  Applies the default deadline,
        degraded-mode clamping, and the bounded-queue shed policy; returns
        False (with a ``"shed"`` result recorded) when admission is
        refused.  Oversized requests raise, as ``Engine.submit`` would."""
        now = self._now if now is None else now
        self.stats["submitted"] += 1
        changed = {}
        if req.deadline_s is None and self.fcfg.default_deadline_s is not None:
            changed["deadline_s"] = self.fcfg.default_deadline_s
        backlog = self.backlog()
        if backlog >= self.fcfg.max_queue:
            self.stats["shed"] += 1
            self.results[req.rid] = FleetResult(
                rid=req.rid, tokens=[], status="shed", replica=None,
                attempts=0, t_arrival=req.arrival_time, t_done=now,
            )
            return False
        if backlog >= self.fcfg.degrade_at and (
            req.max_new_tokens > self.fcfg.degrade_cap
        ):
            # degraded mode: shorter answers for everyone beats shedding
            changed["max_new_tokens"] = self.fcfg.degrade_cap
            self.stats["degraded"] += 1
        if changed:
            req = dataclasses.replace(req, **changed)
        if req.prompt.size + req.max_new_tokens > self.ecfg.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new exceeds engine max_seq_len"
            )
        self.stats["admitted"] += 1
        self.requests[req.rid] = req
        self.attempts[req.rid] = 0
        self.queue.append(_Pending(req))
        return True

    # -- placement -----------------------------------------------------------

    def _best_replica(self, now: float, exclude: set[int] = frozenset()) -> Optional[Replica]:
        cands = [
            r for r in self.replicas
            if r.state == LIVE and not r.stalled(now) and r.id not in exclude
        ]
        if not cands:
            return None
        # route around replicas mid-repair (scrubber has pending faults) as
        # long as a healthy candidate exists; fall back rather than wedge
        healthy = [r for r in cands if not r.mid_repair()]
        return min(healthy or cands, key=lambda r: (r.score(self.fcfg), r.id))

    def _place(self, now: float) -> None:
        """Drain the fleet queue onto the cheapest live replicas, honouring
        arrival times and retry not-before stamps.  Requests the engines
        enforce deadlines on from here; queue-stuck requests expire in
        :meth:`_expire_queue`."""
        remaining: deque[_Pending] = deque()
        for p in self.queue:
            if p.req.arrival_time > now or p.not_before > now:
                remaining.append(p)
                continue
            r = self._best_replica(now)
            if r is None:
                remaining.append(p)
                continue
            if isinstance(p.item, ResumeState):
                r.engine.resume(p.item)
            else:
                r.engine.submit(p.item)
            rid = p.req.rid
            self.placements.setdefault(rid, set()).add(r.id)
            self.attempts[rid] = self.attempts.get(rid, 0) + 1
            self.stats["placements"] += 1
            if p.attempts:
                self.stats["retries"] += 1
        self.queue = remaining

    def _expire_queue(self, now: float) -> None:
        """Deadline-expire requests still stuck in the *fleet* queue (the
        engines handle everything placed on them)."""
        keep: deque[_Pending] = deque()
        for p in self.queue:
            req = p.req
            if req.deadline_s is not None and now >= req.arrival_time + req.deadline_s:
                gen = list(p.item.generated) if isinstance(p.item, ResumeState) else []
                self.results[req.rid] = FleetResult(
                    rid=req.rid, tokens=gen, status="timeout", replica=None,
                    attempts=self.attempts.get(req.rid, 0),
                    t_arrival=req.arrival_time, t_done=now,
                    hedged=req.rid in self.hedged,
                )
                self.stats["timeouts"] += 1
            else:
                keep.append(p)
        self.queue = keep

    # -- failure handling ----------------------------------------------------

    def _requeue(self, item: Union[Request, ResumeState], attempts: int,
                 now: float) -> None:
        """Put salvaged (or restarted) work back in the fleet queue behind a
        jittered backoff stamp; shed it once its retry budget is spent."""
        req = item.req if isinstance(item, ResumeState) else item
        if attempts > self.fcfg.retry.max_retries:
            self.stats["shed"] += 1
            self.results[req.rid] = FleetResult(
                rid=req.rid, tokens=[], status="shed", replica=None,
                attempts=attempts, t_arrival=req.arrival_time, t_done=now,
            )
            self.placements.pop(req.rid, None)
            return
        delay = backoff_delay(self.fcfg.retry, max(attempts - 1, 0), self._rng)
        self.queue.append(_Pending(item, attempts=attempts, not_before=now + delay))

    def _fail_replica(self, r: Replica, now: float, *, lose_state: bool,
                      reason: str) -> None:
        """Mark ``r`` dead and fail its work over.  With host state intact
        and ``failover="resume"``, each request resumes teacher-forced from
        its recorded prefix; otherwise it restarts from the original
        request.  Device snapshots are never used here — a dead replica's
        device memory is gone by definition."""
        r.state = DEAD
        self.stats["crashes" if reason == "crash" else "kills"] += 1
        salvage = not lose_state and self.fcfg.failover == "resume"
        for rid in r.inflight_rids():
            if rid in self.results:
                continue
            twins = self.placements.get(rid, set()) - {r.id}
            if any(self.replicas[t].alive for t in twins):
                self.placements[rid].discard(r.id)
                continue  # a hedged twin is still computing the stream
            attempts = self.attempts.get(rid, 1)
            rec = r.engine.export_state(rid) if salvage else None
            if rec is not None and rec.generated:
                self.stats["failovers"] += 1
                self._requeue(rec, attempts, now)
            else:
                # nothing emitted yet (or state lost): clean restart — the
                # stream is deterministic per seed, so it stays identical
                self.stats["restarts"] += 1
                self._requeue(self.requests[rid], attempts, now)
            self.placements.pop(rid, None)

    # -- hedging -------------------------------------------------------------

    def _maybe_hedge(self, r: Replica, now: float) -> None:
        """Duplicate a struggling replica's in-flight requests onto healthy
        replicas (first finisher wins)."""
        if not self.fcfg.hedge or not r.alive:
            return
        struggling = r.stalled(now) or (
            r.marks >= self.fcfg.hedge_after_marks
        ) or (
            r.backlog() > 0
            and now - r.last_progress > self.fcfg.hedge_stall_s
        )
        if not struggling:
            return
        for rid in r.inflight_rids():
            if rid in self.results or len(self.placements.get(rid, set())) > 1:
                continue
            target = self._best_replica(now, exclude={r.id})
            if target is None:
                return  # nowhere to hedge to; keep waiting
            rec = r.engine.export_state(rid)
            if rec is None:
                continue
            target.engine.resume(rec)
            self.placements.setdefault(rid, set()).add(target.id)
            self.attempts[rid] = self.attempts.get(rid, 0) + 1
            self.hedged.add(rid)
            self.stats["hedges"] += 1

    # -- result collection ---------------------------------------------------

    def _collect(self, r: Replica, now: float) -> None:
        """Adopt newly finished streams from ``r``; cancel losing twins."""
        for rid, res in list(r.engine.results.items()):
            if rid in r.reported:
                continue
            r.reported.add(rid)
            if res.status == "cancelled":
                continue  # our own cancel of a losing hedge copy
            if rid in self.results:
                continue  # a twin already won
            self.results[rid] = FleetResult(
                rid=rid, tokens=list(res.tokens), status=res.status,
                replica=r.id, attempts=self.attempts.get(rid, 1),
                t_arrival=res.t_arrival, t_done=now,
                hedged=rid in self.hedged,
            )
            self.stats["completed" if res.status == "ok" else "timeouts"] += 1
            for twin in self.placements.pop(rid, set()) - {r.id}:
                rep = self.replicas[twin]
                if rep.alive and rep.engine.cancel(rid, now=now):
                    self.stats["cancels"] += 1

    # -- health --------------------------------------------------------------

    def _check_health(self, now: float) -> None:
        if self.monitor is None or self.fcfg.health_every < 1:
            return
        if self.cycle % self.fcfg.health_every:
            return
        for r in self.replicas:
            if r.state != LIVE:
                continue
            self.stats["probes"] += 1
            if r.probe_corrupt_left > 0:
                r.probe_corrupt_left -= 1
                kl = float("inf")  # injected: the probe path itself lies
            else:
                kl = self.monitor.probe(r.engine.params)
            if kl > self.monitor.hcfg.kl_threshold:
                self.stats["probe_failures"] += 1
                # a kill is expensive and one shadow batch is one noisy
                # sample: require the monitor's configured run of
                # consecutive breaches before failing the replica
                r.probe_breaches += 1
                if r.probe_breaches >= self.monitor.hcfg.consecutive_breaches:
                    self._fail_replica(r, now, lose_state=False, reason="kill")
            else:
                r.probe_breaches = 0

    # -- lifecycle -----------------------------------------------------------

    def drain(self, replica: int, now: Optional[float] = None) -> None:
        """Gracefully take ``replica`` out of rotation: no new placements;
        its queued work migrates immediately (device snapshots — restored
        byte-identical on the adopters) and its occupied slots finish where
        they are.  Once empty it parks as ``"down"``."""
        now = self._now if now is None else now
        r = self.replicas[replica]
        if r.state != LIVE:
            return
        r.state = DRAINING
        self.stats["drains"] += 1
        # migrate the waiting line right away; slots drain by finishing
        for w in list(r.engine.waiting):
            rid = (w.req if isinstance(w, ResumeState) else w).rid
            rec = r.engine.evict(rid, snapshot=True)
            target = self._best_replica(now)
            self.placements.get(rid, set()).discard(r.id)
            if rec is None:
                continue
            if target is None:
                self._requeue(rec, self.attempts.get(rid, 1), now)
            else:
                target.engine.resume(rec)
                self.placements.setdefault(rid, set()).add(target.id)
                self.stats["placements"] += 1

    def kill(self, replica: int, now: Optional[float] = None, *,
             lose_state: bool = False) -> None:
        """Hard-stop ``replica`` and fail its work over (operator-initiated
        version of an injected crash)."""
        now = self._now if now is None else now
        r = self.replicas[replica]
        if r.state == DEAD:
            return
        self._fail_replica(r, now, lose_state=lose_state, reason="kill")

    def restore(self, replica: int, now: Optional[float] = None) -> None:
        """Bring a dead/down replica back with a fresh engine (compiled
        dispatches shared from the fleet template — no recompilation) and a
        reset straggler baseline."""
        now = self._now if now is None else now
        r = self.replicas[replica]
        if r.state == LIVE:
            return
        if r.state == DRAINING:
            # un-drain: the engine (and its in-flight work) is intact
            r.state = LIVE
            self.stats["restores"] += 1
            return
        params = self.params
        if r.device is not None:
            params = jax.device_put(params, r.device)
        tp = self.fcfg.shards_per_replica
        r.engine = Engine(self.cfg, params, self.ecfg,
                          dispatch_from=self._dispatch_template,
                          tp=tp, tp_devices=r.devices if tp > 1 else None)
        r.state = LIVE
        r.steps = 0
        r.marks = 0
        r.stall_until = 0.0
        r.slow_factor, r.slow_left = 1.0, 0
        r.probe_breaches = 0
        r.last_progress = now
        r.reported = set()
        r.straggler.reset_ewma()
        self.stats["restores"] += 1

    # -- scheduling ----------------------------------------------------------

    def _apply_chaos(self, r: Replica, now: float) -> None:
        if self.injector is None:
            return
        for ev in self.injector.fire(r.id, r.steps, now):
            if ev.kind == "crash":
                self._fail_replica(r, now, lose_state=ev.lose_state, reason="crash")
            elif ev.kind == "stall":
                r.stall_until = max(r.stall_until, now + ev.duration_s)
                self.stats["stalls"] += 1
            elif ev.kind == "slow":
                r.slow_factor, r.slow_left = ev.factor, ev.steps
                self.stats["slows"] += 1
            elif ev.kind == "corrupt_probe":
                r.probe_corrupt_left += ev.steps
            elif ev.kind == "storm":
                if r.pool is not None and r.pool.integrity is not None:
                    # deterministic per (replica, step): traces replay exactly
                    r.pool.integrity.storm(
                        jax.random.PRNGKey(1_000_003 * r.id + ev.at_step),
                        corrupt_rate=ev.corrupt, stuck_rate=ev.stuck,
                    )
                    self.stats["storms"] += 1

    def step(self, now: float) -> bool:
        """One fleet cycle: chaos → queue expiry → placement → per-replica
        engine cycles (with straggler observation) → hedging → result
        collection → health probes.  Returns True if any engine dispatched.
        """
        self._now = now
        self.cycle += 1
        for r in self.replicas:
            if r.alive:
                self._apply_chaos(r, now)
        self._expire_queue(now)
        self._place(now)
        did = False
        for r in self.replicas:
            if not r.alive:
                continue
            if r.stalled(now):
                self._maybe_hedge(r, now)
                continue
            r.steps += 1
            t0 = time.perf_counter()
            try:
                stepped = r.engine.step(now)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception:
                # a real dispatch failure is a crash with host state intact
                self._fail_replica(r, now, lose_state=False, reason="crash")
                continue
            wall = (time.perf_counter() - t0) * r.slow_factor
            if r.slow_left > 0:
                r.slow_left -= 1
                if r.slow_left == 0:
                    r.slow_factor = 1.0
            straggling = r.straggler.observe(r.steps, wall)
            r.marks = r.marks + 1 if straggling else 0
            did = did or stepped
            if stepped or r.backlog() == 0:
                # an idle replica isn't "stalled" — only a replica that owes
                # work and isn't producing it trips the no-progress hedge
                r.last_progress = now
            self._maybe_hedge(r, now)
            self._collect(r, now)
            if r.state == DRAINING and r.backlog() == 0:
                r.state = DOWN
        self._check_health(now)
        return did

    def run(self, requests: list[Request]) -> list[FleetResult]:
        """Serve a trace to completion (wall-clock arrival times), like
        ``Engine.run`` but with arrivals submitted when they *happen* — the
        bounded queue and degraded mode react to real backlog.  Raises if
        every replica dies with work outstanding."""
        arrivals = deque(sorted(requests, key=lambda r: (r.arrival_time, r.rid)))
        t0 = time.perf_counter()
        while True:
            now = time.perf_counter() - t0
            while arrivals and arrivals[0].arrival_time <= now:
                self.submit(arrivals.popleft(), now)
            if not arrivals and all(r.rid in self.results for r in requests):
                break
            outstanding = self.queue or any(
                r.alive and r.backlog() for r in self.replicas
            )
            if outstanding and not any(
                r.state == LIVE for r in self.replicas
            ):
                raise RuntimeError(
                    "fleet lost every replica with requests outstanding"
                )
            if not self.step(now):
                # nothing dispatched: park briefly (next arrival, retry
                # not-before, or stall expiry) instead of spinning hot
                nxt = arrivals[0].arrival_time - now if arrivals else 0.001
                time.sleep(min(max(nxt, 0.0005), 0.05))
        return [self.results[r.rid] for r in requests]
