"""Post-GSPMD HLO analysis: collective bytes + roofline terms.

``collective_bytes(hlo_text)`` parses the compiled (partitioned) HLO and
prices every collective op.  Result shapes in the partitioned module are
*per-device*; wire bytes use the standard ring-algorithm factors with the
replica-group size g parsed per op:

    all-reduce          2 * R * (g-1)/g      (reduce-scatter + all-gather)
    all-gather          R * (g-1)/g          (R = gathered output)
    reduce-scatter      R * (g-1)            (R = scattered output; input R*g)
    all-to-all          R * (g-1)/g
    collective-permute  R

Roofline terms (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI.  ``cost_analysis()`` of a partitioned module reports
per-device FLOPs/bytes, so terms are per-chip directly; this equals the
brief's global formulation (global = per-chip x chips, divided by chips).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,\s]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        # iota format [n_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m and m.group(1).strip():
        return len(m.group(1).split(","))
    return 2  # conservative default


@dataclasses.dataclass
class CollectiveReport:
    by_kind_bytes: dict[str, int]
    by_kind_wire: dict[str, float]
    by_kind_count: dict[str, int]

    @property
    def total_wire(self) -> float:
        return sum(self.by_kind_wire.values())

    @property
    def total_bytes(self) -> int:
        return sum(self.by_kind_bytes.values())


def collective_bytes(hlo_text: str) -> CollectiveReport:
    by_bytes: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    by_wire: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    by_count: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"((?:\([^)]*\))|(?:[\w\[\],]+))\s+([\w-]+)", rhs)
        if not m:
            continue
        result_type, opname = m.group(1), m.group(2)
        base = opname.removesuffix("-start")
        if base not in _COLLECTIVES or opname.endswith("-done"):
            continue
        r = _shape_bytes(result_type)
        g = _group_size(s)
        if g <= 1:
            continue
        if base == "all-reduce":
            wire = 2.0 * r * (g - 1) / g
        elif base == "all-gather":
            wire = r * (g - 1) / g
        elif base == "reduce-scatter":
            wire = float(r) * (g - 1)
        elif base == "all-to-all":
            wire = r * (g - 1) / g
        else:  # collective-permute
            wire = float(r)
        by_bytes[base] += r
        by_wire[base] += wire
        by_count[base] += 1
    return CollectiveReport(by_bytes, by_wire, by_count)


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device bytes accessed
    wire_bytes: float  # per-device collective wire bytes
    model_flops: Optional[float] = None  # 6ND / 2ND analytic

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / self.flops

    @property
    def roofline_fraction(self) -> Optional[float]:
        """Fraction of chip peak achieved at the roofline step time, counting
        only useful (analytic model) FLOPs — the §Perf score."""
        if self.model_flops is None or self.step_time_s == 0:
            return None
        return (self.model_flops / self.step_time_s) / PEAK_FLOPS

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }
