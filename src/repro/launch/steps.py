"""Step functions: train_step / prefill_step / serve_step per architecture.

These are the functions the dry-run lowers and the runtime executes.  All
are pure (params, state, batch) -> (new state, metrics) functions suitable
for ``jax.jit`` with explicit in/out shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api
from repro.optim import AdamWConfig, adamw_update


def loss_fn(params, cfg: ArchConfig, batch: dict, remat: str = "none") -> tuple[jax.Array, dict]:
    logits, aux = api.forward(params, cfg, batch, remat=remat)
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    if cfg.stub_prefix_len:
        # modality-stub positions carry no next-token target
        pos = jnp.arange(nll.shape[1])
        mask = (pos >= cfg.stub_prefix_len).astype(jnp.float32)[None]
        nll = nll * mask
        loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask) * nll.shape[0], 1.0)
    else:
        loss = jnp.mean(nll)
    return loss + aux, {"nll": loss, "aux": aux}


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *, remat: str = "full"):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    if remat not in ("none", "full", "dots"):
        raise ValueError(f"unknown remat policy {remat!r}")

    def train_step(params, opt_state, batch):
        f = functools.partial(loss_fn, cfg=cfg, batch=batch, remat=remat)
        (loss, parts), grads = jax.value_and_grad(f, has_aux=True)(params)
        new_params, new_state, opt_metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return new_params, new_state, metrics

    return train_step


def _serving_params(params):
    """Backend policy for bit-packed weight operands in serving steps.

    On TPU, packed operand dicts flow through to the model unchanged — every
    decode step computes on them via the packed Pallas ``cim_matmul`` kernel,
    reading ~1 bit of weight HBM per bit cell.  On backends without the
    compiled kernel the packed representation is a *storage* format: the
    serve/prefill steps decompress it to dense achieved weights once per
    dispatch (inside jit, hoisted above the whole scan-over-tokens decode
    loop) instead of paying a per-token, per-site bit-unpack emulation.
    Int8-plane operands are exempt: they exist as the faithful per-step
    bit-sliced simulation baseline.

    Codec-encoded packed dicts (``core.planes.encode_operands``: plane-axis
    reorder + zero-tile flags) need no special casing here — ``densify`` and
    ``cim_linear`` both decode them exactly, so either route serves the same
    bits as raw operands.
    """
    from repro.core import simulator
    from repro.kernels._util import on_tpu

    return params if on_tpu() else simulator.densify_packed(params)


def prepare_serving_params(params):
    """Once-per-deployment host-side materialization of serving params.

    Same backend policy as ``_serving_params``, but executed *eagerly before
    any dispatch is built*: on non-TPU backends every packed operand dict is
    decompressed to dense achieved weights exactly once, and the resulting
    pytree is reused by every jitted variant (warmup + timed runs, every
    engine bucket).  Without this hoist the densify ops are traced into each
    dispatch and re-executed on device per call.  ``_serving_params`` stays
    inside the step functions as the TPU packed-flow policy (it is a cheap
    trace-time no-op on an already-prepared tree), so step makers remain
    correct for callers that skip preparation.
    """
    return _serving_params(params)


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return api.prefill(_serving_params(params), cfg, batch)

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """One-token decode: (params, cache, token, pos) -> (logits, cache)."""

    def serve_step(params, cache, token, pos):
        return api.decode_step(_serving_params(params), cfg, cache, token, pos)

    return serve_step


def cache_donation() -> tuple[int, ...]:
    """``donate_argnums`` for the cache operand of serve_step / decode_loop.

    Donating the KV cache lets XLA update it in place instead of copying the
    full cache every decoded token.  Params are deliberately NOT donated:
    every decode step (and every subsequent ``generate`` call — fp vs cim
    comparisons serve the same params twice) reuses them.  CPU has no buffer
    donation; returning () there avoids a per-dispatch warning.
    """
    return (1,) if jax.default_backend() != "cpu" else ()


def make_decode_loop(cfg: ArchConfig, n_steps: int, *, greedy: bool = True):
    """Whole-generation decode as ONE ``lax.scan`` dispatch.

    Returns decode_loop(params, cache, tok0, key, prompt_len) ->
    (tokens (B, n_steps) i32, final cache).  The scan carries (cache, token,
    key); combined with cache donation the KV cache is updated in place for
    the entire generation — no per-token dispatch, no per-step cache copy.
    The sampling path and PRNG split schedule are identical to the eager
    per-token loop in ``launch.serve.generate``, so both loops emit the same
    tokens for the same seed.
    """

    def decode_loop(params, cache, tok0, key, prompt_len):
        params = _serving_params(params)  # hoisted above the token scan

        def body(carry, pos):
            cache, tok, key = carry
            logits, cache = api.decode_step(params, cfg, cache, tok, pos)
            if greedy:
                nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits[:, -1])[:, None].astype(jnp.int32)
            return (cache, nxt, key), nxt

        positions = prompt_len + jnp.arange(n_steps, dtype=jnp.int32)
        (cache, _, _), toks = jax.lax.scan(body, (cache, tok0, key), positions)
        # toks: (n_steps, B, 1) -> (B, n_steps)
        return jnp.swapaxes(toks[..., 0], 0, 1), cache

    return decode_loop


def _row_pick(logits, keys, greedy, consume=None):
    """Per-row token pick — THE sampling path and PRNG split schedule shared
    by every ragged dispatch (decode loop, prefill chunk, fused step), so
    their streams stay bit-identical to the solo ``serve.generate`` pick.

    logits (B, S, V) — the last position samples; keys (B, 2); greedy (B,)
    bool — greedy rows take argmax and never consume randomness (matching
    the solo loop's schedule); ``consume`` optionally masks which sampled
    rows' keys really advance (rows whose pick the caller will discard —
    mid-prompt chunks, replayed tokens — must not burn a split).
    Returns (tok (B,) i32, keys_out (B, 2)).
    """
    greedy_tok = jnp.argmax(logits[:, -1], axis=-1)
    split = jax.vmap(jax.random.split)(keys)  # (B, 2, 2)
    keys_new, subs = split[:, 0], split[:, 1]
    sampled = jax.vmap(jax.random.categorical)(subs, logits[:, -1])
    tok = jnp.where(greedy, greedy_tok, sampled).astype(jnp.int32)
    advance = ~greedy if consume is None else (consume & ~greedy)
    keys_out = jnp.where(advance[:, None], keys_new, keys)
    return tok, keys_out


def _ragged_scan_body(params, cfg: ArchConfig, greedy):
    """The one decode-quantum scan body: ``make_paged_decode_loop`` and the
    fused step's decode sub-batch run this exact closure, so fused-vs-split
    is purely a scheduling difference.  Carry: (caches, tok (B, 1), keys,
    pos (B,)); emits each step's (B,) tokens."""

    def body(carry, _):
        caches, tok, keys, pos = carry
        logits, caches = api.decode_step(params, cfg, caches, tok, pos)
        nxt, keys = _row_pick(logits, keys, greedy)
        return (caches, nxt[:, None], keys, pos + 1), nxt

    return body


def make_paged_decode_loop(cfg: ArchConfig, n_steps: int, page_size: int):
    """Ragged continuous-batching decode quantum as ONE ``lax.scan`` dispatch.

    Returns decode_loop(params, pools, table (B, P) i32, state (B, 3) i32
    rows = [tok, pos, greedy], keys (B, 2) u32) ->
    (tokens (B, n_steps) i32, pools, keys (B, 2)).

    Same donated-cache scan structure as :func:`make_decode_loop`, but every
    slot carries its own position, PRNG key, and greedy flag: the KV write
    and attention mask are per-slot (paged pool + block table), and sampling
    splits each slot's key independently — so each row's token stream is
    bit-identical to a solo ``launch.serve.generate`` run of that request
    (rows are padded/retired independently; the host discards post-EOS
    tokens).  The block ``table`` must already cover positions up to
    ``pos + n_steps`` for every live row; padded rows point at the dummy
    page.
    """

    def decode_loop(params, pools, table, state, keys):
        params = _serving_params(params)  # hoisted above the token scan
        tok0 = state[:, 0:1]
        pos0 = state[:, 1]
        greedy = state[:, 2].astype(bool)
        # gather every slot's pages ONCE; the scan then runs the ordinary
        # contiguous-cache decode step (vector positions) against the view
        caches = api.paged_view(cfg, pools, table, page_size)
        (caches, _, keys, _), toks = jax.lax.scan(
            _ragged_scan_body(params, cfg, greedy),
            (caches, tok0, keys, pos0), None, length=n_steps,
        )
        # write back only the quantum's new cells, one scatter per dispatch
        pools = api.paged_writeback(cfg, pools, caches, table, pos0, n_steps, page_size)
        return jnp.swapaxes(toks, 0, 1), pools, keys

    return decode_loop


def make_fused_step(cfg: ArchConfig, n_steps: int, page_size: int):
    """Fused prefill+decode dispatch: ONE bucketed dispatch per engine cycle
    in which some rows are prefill chunks and others are decode quanta.

    Returns fused_step(params, pools,
        pf_table (Bp, P) i32, pf_tokens (Bp, C) i32, pf_meta (Bp, 5) i32,
        pf_keys (Bp, 2) u32,
        table (B, P) i32, state (B, 5) i32, keys (B, 2) u32, join (B,) i32)
    -> (pf_tok (Bp,) i32, toks (B, n_steps) i32, keys_out (B, 2), pools).

    Two sub-batches, one XLA computation, one host round trip:

      * **Chunk sub-batch** (prefill rows only, width C bucketed to the
        widest live chunk): exactly the ``make_prefill_chunk_step`` compute —
        ``pf_meta`` rows are [start, kv_len, last_idx, greedy, consume];
        ``pf_tok`` samples each row's next token in-graph (``consume``
        marks rows whose PRNG key this pick really advances: final-chunk
        rows that are not replaying an already-emitted token).
      * **Decode sub-batch** (decode rows + rows whose prompt finishes in
        this very dispatch): exactly the ``make_paged_decode_loop`` scan —
        ``state`` rows are [tok, pos, greedy, tok_override, use_override].
        ``join`` maps each scan row to its chunk row (-1 for plain decode
        rows): a finishing row's scan seeds from its in-graph first token
        ``pf_tok[join]`` and continuation key — it rolls straight from
        prefill into an ``n_steps``-token decode quantum *inside the same
        dispatch*, no dead cycle between phases.  ``use_override`` rows
        (recompute re-admissions replaying prompt+generated) seed from
        ``tok_override`` — the token they emitted before preemption —
        without consuming PRNG: its sampling already happened once.

    Keeping the two sub-batches separate (rather than widening every row to
    the chunk width) means decode rows pay exactly the decode-loop compute,
    the chunk stage runs at its own (usually much smaller) row bucket, and
    both stages are literally the same code the split dispatches run —
    ``_row_pick`` and ``_ragged_scan_body`` are shared with
    :func:`make_paged_decode_loop` / :func:`make_prefill_chunk_step` — so
    fused-vs-split is purely a scheduling difference and every row's token
    stream stays bit-identical to a solo ``launch.serve.generate`` run
    (pinned in tests/test_engine.py).  The scan's view is gathered after the
    chunk write-back, so a finishing row's prompt KV is visible to its own
    decode steps.
    """

    def fused_step(params, pools, pf_table, pf_tokens, pf_meta, pf_keys,
                   table, state, keys, join):
        params = _serving_params(params)

        # ---- chunk sub-batch: one prefill chunk per prefilling row --------
        start, kv_len, last_idx = pf_meta[:, 0], pf_meta[:, 1], pf_meta[:, 2]
        pf_greedy = pf_meta[:, 3].astype(bool)
        pf_consume = pf_meta[:, 4].astype(bool)
        caches = api.paged_view(cfg, pools, pf_table, page_size)
        logits, caches = api.chunk_on_views(
            params, cfg, caches, pf_tokens, start, kv_len, last_idx
        )
        pf_tok, pf_keys_out = _row_pick(logits, pf_keys, pf_greedy, consume=pf_consume)
        bp, c = pf_tokens.shape
        start_b = jnp.broadcast_to(jnp.atleast_1d(start), (bp,))
        pools = api.paged_writeback(cfg, pools, caches, pf_table, start_b, c, page_size)

        # ---- decode quantum: decode rows + just-finished prefill rows -----
        use_join = join >= 0
        jidx = jnp.clip(join, 0)
        tok0 = jnp.where(use_join, pf_tok[jidx], state[:, 0])
        tok0 = jnp.where(state[:, 4].astype(bool), state[:, 3], tok0)[:, None]
        keys0 = jnp.where(use_join[:, None], pf_keys_out[jidx], keys)
        pos0 = state[:, 1]
        greedy = state[:, 2].astype(bool)
        caches = api.paged_view(cfg, pools, table, page_size)
        (caches, _, keys_out, _), toks = jax.lax.scan(
            _ragged_scan_body(params, cfg, greedy),
            (caches, tok0, keys0, pos0), None, length=n_steps,
        )
        pools = api.paged_writeback(cfg, pools, caches, table, pos0, n_steps, page_size)
        return pf_tok, jnp.swapaxes(toks, 0, 1), keys_out, pools

    return fused_step


def make_prefill_chunk_step(cfg: ArchConfig, page_size: int):
    """One chunked-prefill dispatch, B requests wide, first-token sampling
    fused in.

    (params, pools, table (B, P), tokens (B, C), meta (B, 4) i32 rows =
    [start, kv_len, last_idx, greedy], keys (B, 2) u32) ->
    (tok (B,) i32, keys_out (B, 2), pools).

    ``meta`` is traced, so one compiled variant serves every chunk of a
    given (B, C, P) bucket; ``tok[r]`` is only meaningful on row r's final
    chunk (earlier chunks sample from a mid-prompt position and the caller
    ignores them — a row's key is only adopted when the caller accepts the
    token, keeping the PRNG schedule identical to the solo pick)."""

    def chunk_step(params, pools, table, tokens, meta, keys):
        params = _serving_params(params)
        start, kv_len, last_idx = meta[:, 0], meta[:, 1], meta[:, 2]
        greedy = meta[:, 3].astype(bool)
        logits, pools = api.prefill_chunk(
            params, cfg, pools, table, tokens, start, kv_len, last_idx, page_size
        )
        tok, keys_out = _row_pick(logits, keys, greedy)
        return tok, keys_out, pools

    return chunk_step
