"""Step functions: train_step / prefill_step / serve_step per architecture.

These are the functions the dry-run lowers and the runtime executes.  All
are pure (params, state, batch) -> (new state, metrics) functions suitable
for ``jax.jit`` with explicit in/out shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api
from repro.optim import AdamWConfig, adamw_update


def loss_fn(params, cfg: ArchConfig, batch: dict, remat: str = "none") -> tuple[jax.Array, dict]:
    logits, aux = api.forward(params, cfg, batch, remat=remat)
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    if cfg.stub_prefix_len:
        # modality-stub positions carry no next-token target
        pos = jnp.arange(nll.shape[1])
        mask = (pos >= cfg.stub_prefix_len).astype(jnp.float32)[None]
        nll = nll * mask
        loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask) * nll.shape[0], 1.0)
    else:
        loss = jnp.mean(nll)
    return loss + aux, {"nll": loss, "aux": aux}


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *, remat: str = "full"):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    if remat not in ("none", "full", "dots"):
        raise ValueError(f"unknown remat policy {remat!r}")

    def train_step(params, opt_state, batch):
        f = functools.partial(loss_fn, cfg=cfg, batch=batch, remat=remat)
        (loss, parts), grads = jax.value_and_grad(f, has_aux=True)(params)
        new_params, new_state, opt_metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return new_params, new_state, metrics

    return train_step


def _serving_params(params):
    """Backend policy for bit-packed weight operands in serving steps.

    On TPU, packed operand dicts flow through to the model unchanged — every
    decode step computes on them via the packed Pallas ``cim_matmul`` kernel,
    reading ~1 bit of weight HBM per bit cell.  On backends without the
    compiled kernel the packed representation is a *storage* format: the
    serve/prefill steps decompress it to dense achieved weights once per
    dispatch (inside jit, hoisted above the whole scan-over-tokens decode
    loop) instead of paying a per-token, per-site bit-unpack emulation.
    Int8-plane operands are exempt: they exist as the faithful per-step
    bit-sliced simulation baseline.
    """
    from repro.core import simulator
    from repro.kernels._util import on_tpu

    return params if on_tpu() else simulator.densify_packed(params)


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return api.prefill(_serving_params(params), cfg, batch)

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """One-token decode: (params, cache, token, pos) -> (logits, cache)."""

    def serve_step(params, cache, token, pos):
        return api.decode_step(_serving_params(params), cfg, cache, token, pos)

    return serve_step


def cache_donation() -> tuple[int, ...]:
    """``donate_argnums`` for the cache operand of serve_step / decode_loop.

    Donating the KV cache lets XLA update it in place instead of copying the
    full cache every decoded token.  Params are deliberately NOT donated:
    every decode step (and every subsequent ``generate`` call — fp vs cim
    comparisons serve the same params twice) reuses them.  CPU has no buffer
    donation; returning () there avoids a per-dispatch warning.
    """
    return (1,) if jax.default_backend() != "cpu" else ()


def make_decode_loop(cfg: ArchConfig, n_steps: int, *, greedy: bool = True):
    """Whole-generation decode as ONE ``lax.scan`` dispatch.

    Returns decode_loop(params, cache, tok0, key, prompt_len) ->
    (tokens (B, n_steps) i32, final cache).  The scan carries (cache, token,
    key); combined with cache donation the KV cache is updated in place for
    the entire generation — no per-token dispatch, no per-step cache copy.
    The sampling path and PRNG split schedule are identical to the eager
    per-token loop in ``launch.serve.generate``, so both loops emit the same
    tokens for the same seed.
    """

    def decode_loop(params, cache, tok0, key, prompt_len):
        params = _serving_params(params)  # hoisted above the token scan

        def body(carry, pos):
            cache, tok, key = carry
            logits, cache = api.decode_step(params, cfg, cache, tok, pos)
            if greedy:
                nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits[:, -1])[:, None].astype(jnp.int32)
            return (cache, nxt, key), nxt

        positions = prompt_len + jnp.arange(n_steps, dtype=jnp.int32)
        (cache, _, _), toks = jax.lax.scan(body, (cache, tok0, key), positions)
        # toks: (n_steps, B, 1) -> (B, n_steps)
        return jnp.swapaxes(toks[..., 0], 0, 1), cache

    return decode_loop
