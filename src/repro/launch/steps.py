"""Step functions: train_step / prefill_step / serve_step per architecture.

These are the functions the dry-run lowers and the runtime executes.  All
are pure (params, state, batch) -> (new state, metrics) functions suitable
for ``jax.jit`` with explicit in/out shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api
from repro.optim import AdamWConfig, adamw_update


def loss_fn(params, cfg: ArchConfig, batch: dict, remat: str = "none") -> tuple[jax.Array, dict]:
    logits, aux = api.forward(params, cfg, batch, remat=remat)
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    if cfg.stub_prefix_len:
        # modality-stub positions carry no next-token target
        pos = jnp.arange(nll.shape[1])
        mask = (pos >= cfg.stub_prefix_len).astype(jnp.float32)[None]
        nll = nll * mask
        loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask) * nll.shape[0], 1.0)
    else:
        loss = jnp.mean(nll)
    return loss + aux, {"nll": loss, "aux": aux}


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *, remat: str = "full"):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    if remat not in ("none", "full", "dots"):
        raise ValueError(f"unknown remat policy {remat!r}")

    def train_step(params, opt_state, batch):
        f = functools.partial(loss_fn, cfg=cfg, batch=batch, remat=remat)
        (loss, parts), grads = jax.value_and_grad(f, has_aux=True)(params)
        new_params, new_state, opt_metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return api.prefill(params, cfg, batch)

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """One-token decode: (params, cache, token, pos) -> (logits, cache)."""

    def serve_step(params, cache, token, pos):
        return api.decode_step(params, cfg, cache, token, pos)

    return serve_step
