import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
# ^ the two lines above MUST precede any jax import/init: jax locks the host
#   device count on first initialization.  Set here (and ONLY here) so smoke
#   tests and benchmarks keep seeing 1 device.
#
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
# For each cell this driver builds ShapeDtypeStruct stand-ins for every input
# (params via eval_shape — zero allocation), assigns shardings from the
# logical rules, lowers the step function under the production mesh, compiles
# it, and records memory_analysis / cost_analysis / the collective schedule
# parsed from the partitioned HLO.  Results land in experiments/dryrun/*.json
# and feed EXPERIMENTS.md §Dry-run and §Roofline.
#
# Usage:
#   python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
#   python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_arch, list_archs
from repro.configs.base import ArchConfig, ShapeSpec, shape_applicable
from repro.launch import hlo_analysis, hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import api
from repro.optim import AdamWConfig, adamw_init
from repro.parallel import sharding as shard_lib


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ---------------------------------------------------------------------------

def param_specs(cfg: ArchConfig):
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: api.init(k, cfg), key_spec)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.encdec:
        out["src_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.float32)
    elif cfg.stub_prefix_len:
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.stub_prefix_len, cfg.d_model), jnp.float32
        )
    return out


def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    return jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len, src_len=shape.seq_len)
    )


def input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """All lowering inputs for the cell's step function, as SDS pytrees."""
    params = param_specs(cfg)
    if shape.kind == "train":
        opt = jax.eval_shape(adamw_init, params)
        return {"params": params, "opt_state": opt, "batch": batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": params, "batch": batch_specs(cfg, shape)}
    # decode
    return {
        "params": params,
        "cache": cache_specs(cfg, shape),
        "token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Sharding assignment
# ---------------------------------------------------------------------------

def shardings_for(cfg: ArchConfig, shape: ShapeSpec, mesh, specs, *, fsdp: bool = False):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ns = lambda spec: NamedSharding(mesh, spec)
    p_sh = shard_lib.param_shardings(specs["params"], mesh, fsdp=fsdp)

    def batch_sh(bspecs):
        return jax.tree.map(
            lambda l: ns(shard_lib.data_spec(mesh, l.shape[0], l.ndim)), bspecs
        )

    if shape.kind == "train":
        opt_sh = {
            "m": p_sh, "v": p_sh,
            "count": ns(P()),
        }
        return {"params": p_sh, "opt_state": opt_sh, "batch": batch_sh(specs["batch"])}
    if shape.kind == "prefill":
        return {"params": p_sh, "batch": batch_sh(specs["batch"])}
    cache_sh = jax.tree.map(
        lambda l: ns(shard_lib.cache_pspec(mesh, tuple(l.shape), axis_sizes)), specs["cache"]
    )
    return {
        "params": p_sh,
        "cache": cache_sh,
        "token": ns(shard_lib.data_spec(mesh, shape.global_batch, 2)),
        "pos": ns(P()),
    }


# ---------------------------------------------------------------------------
# Analytic model FLOPs (roofline denominator)
# ---------------------------------------------------------------------------

def model_flops(cfg: ArchConfig, shape: ShapeSpec, n_active: int, chips: int) -> float:
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    per_token = 6 * n_active if shape.kind == "train" else 2 * n_active
    return per_token * tokens / chips  # per-chip share


def active_params(cfg: ArchConfig) -> int:
    specs = param_specs(cfg)
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(specs))
    if cfg.moe is None:
        return total
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    routed = 0
    for path, leaf in flat:
        names = [str(getattr(k, "key", "")) for k in path]
        if any(n in ("wi_gate", "wi_up") for n in names) and leaf.ndim == 4:
            routed += int(np.prod(leaf.shape))
        if "wo" in names and leaf.ndim == 4:
            routed += int(np.prod(leaf.shape))
    # padded expert rows are dead weights: active = top_k real experts
    return total - routed + int(routed * cfg.moe.top_k / cfg.moe.n_alloc)


# ---------------------------------------------------------------------------
# Per-cell dry run
# ---------------------------------------------------------------------------

def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    remat: str = "full",
    fsdp: bool = False,
    swa_banded: bool = False,
    moe_sharded: bool = False,
    out_dir: Path | None = None,
    variant: str = "",
) -> dict:
    from repro.models.attention import set_attention_impl
    from repro.models.moe import set_moe_distribution

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    result: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "remat": remat, "fsdp": fsdp, "variant": variant,
        "swa_banded": swa_banded, "moe_sharded": moe_sharded,
    }
    if not ok:
        result.update(status="skipped", reason=why)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(mesh.devices.shape))
    specs = input_specs(cfg, shape)
    shardings = shardings_for(cfg, shape, mesh, specs, fsdp=fsdp)

    set_attention_impl(swa_banded=swa_banded)
    set_moe_distribution(mesh if moe_sharded else None)

    t0 = time.time()
    try:
        with mesh:
            if shape.kind == "train":
                step = make_train_step(cfg, AdamWConfig(), remat=remat)
                jitted = jax.jit(
                    step,
                    in_shardings=(shardings["params"], shardings["opt_state"], shardings["batch"]),
                    out_shardings=(
                        shardings["params"],
                        shardings["opt_state"],
                        None,
                    ),
                )
                lowered = jitted.lower(specs["params"], specs["opt_state"], specs["batch"])
            elif shape.kind == "prefill":
                step = make_prefill_step(cfg)
                jitted = jax.jit(
                    step, in_shardings=(shardings["params"], shardings["batch"])
                )
                lowered = jitted.lower(specs["params"], specs["batch"])
            else:
                step = make_serve_step(cfg)
                jitted = jax.jit(
                    step,
                    in_shardings=(
                        shardings["params"], shardings["cache"],
                        shardings["token"], shardings["pos"],
                    ),
                    out_shardings=(None, shardings["cache"]),
                )
                lowered = jitted.lower(
                    specs["params"], specs["cache"], specs["token"], specs["pos"]
                )
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    except Exception as e:  # a failing cell is a bug to fix, but keep sweeping
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-4000:])
        return result
    finally:
        set_attention_impl(swa_banded=False)
        set_moe_distribution(None)

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:
        mem_d = {}

    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)
    # trip-count-aware re-analysis: HloCostAnalysis counts each while body
    # once, undercounting scan-over-layers models by ~n_layers.  hlo_cost
    # re-derives per-device FLOPs/bytes/wire with call-graph multiplicities
    # (validated against analytic 6ND in tests/test_hlo_cost.py).
    hc = hlo_cost.analyze(hlo)

    n_active = active_params(cfg)
    mf = model_flops(cfg, shape, n_active, chips)
    roof = hlo_analysis.Roofline(
        flops=hc.flops,
        hbm_bytes=hc.bytes_accessed,
        wire_bytes=hc.wire_bytes,
        model_flops=mf,
    )

    result.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        n_active_params=n_active,
        memory_analysis=mem_d,
        collectives={
            "count": hc.collective_counts,  # trip-count-weighted
            "wire_bytes": hc.collective_wire,
            "static_count": coll.by_kind_count,  # one-pass HLO text counts
        },
        hlo_structure={"n_while": hc.n_while, "max_trip": hc.max_trip},
        cost_analysis_raw={k: cost[k] for k in sorted(cost) if isinstance(cost[k], (int, float))},
        roofline=roof.to_dict(),
    )
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}_{shape_name}_{mesh_kind}" + (f"_{variant}" if variant else "")
        (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=1))
    return result


def iter_cells(mesh_kinds: list[str]):
    for arch in list_archs():
        cfg = get_arch(arch)
        for shape_name in SHAPES:
            for mk in mesh_kinds:
                yield arch, shape_name, mk


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--swa-banded", action="store_true")
    ap.add_argument("--moe-sharded", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        cells = list(iter_cells(mesh_kinds))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape, mk) for mk in mesh_kinds]

    n_ok = n_skip = n_err = 0
    for arch, shape_name, mk in cells:
        r = run_cell(
            arch, shape_name, mk,
            remat=args.remat, fsdp=args.fsdp,
            swa_banded=args.swa_banded, moe_sharded=args.moe_sharded,
            out_dir=out_dir, variant=args.variant,
        )
        if r["status"] == "ok":
            n_ok += 1
            roof = r["roofline"]
            print(
                f"OK    {arch:24s} {shape_name:12s} {mk:6s} "
                f"compile={r['compile_s']:.0f}s flops={roof['flops']:.3g} "
                f"bytes={roof['hbm_bytes']:.3g} wire={roof['wire_bytes']:.3g} "
                f"bottleneck={roof['bottleneck']}",
                flush=True,
            )
        elif r["status"] == "skipped":
            n_skip += 1
            print(f"SKIP  {arch:24s} {shape_name:12s} {mk:6s} {r['reason']}", flush=True)
        else:
            n_err += 1
            print(f"ERROR {arch:24s} {shape_name:12s} {mk:6s} {r['error']}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")


if __name__ == "__main__":
    main()
