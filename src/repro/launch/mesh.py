"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* first jax
initialization, while smoke tests and benchmarks must see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    The "pod" axis carries pure data parallelism across the inter-pod DCN
    link; "model" is the intra-pod ICI tensor/expert-parallel axis.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Trivial 1x1 mesh over the real local device (tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])


def replica_devices(n: int) -> list[jax.Device]:
    """One device per data-parallel engine replica along the "data" axis.

    With more replicas than devices the assignment wraps (replicas share a
    device) — tests run with 1 CPU device and the fleet benchmark emulates
    a mesh with ``--xla_force_host_platform_device_count=N`` (set before
    first jax initialization, exactly like the dry-run's 512-chip override;
    the benchmark's ``--devices`` flag does this pre-import)."""
    if n < 1:
        raise ValueError(f"need at least one replica, got {n}")
    devs = jax.devices()
    return [devs[i % len(devs)] for i in range(n)]
