"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* first jax
initialization, while smoke tests and benchmarks must see 1 device.
"""
from __future__ import annotations

import warnings

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    The "pod" axis carries pure data parallelism across the inter-pod DCN
    link; "model" is the intra-pod ICI tensor/expert-parallel axis.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Trivial 1x1 mesh over the real local device (tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])


def replica_devices(n: int) -> list[jax.Device]:
    """One device per data-parallel engine replica along the "data" axis.

    With more replicas than devices the assignment wraps (replicas share a
    device) — tests run with 1 CPU device and the fleet benchmark emulates
    a mesh with ``--xla_force_host_platform_device_count=N`` (set before
    first jax initialization, exactly like the dry-run's 512-chip override;
    the benchmark's ``--devices`` flag does this pre-import)."""
    if n < 1:
        raise ValueError(f"need at least one replica, got {n}")
    return [g[0] for g in replica_submeshes(n, 1)]


def replica_submeshes(
    n_replicas: int, shards_per_replica: int = 1
) -> list[list[jax.Device]]:
    """Carve the device list into per-replica "model"-axis submeshes.

    Replica ``i`` owns the ``shards_per_replica`` contiguous devices starting
    at ``i * shards_per_replica`` — contiguity is what keeps a tensor-
    parallel psum on intra-group links.  Assignment rules:

    * ``shards_per_replica == 1`` — the PR 8 behavior: with more replicas
      than devices the assignment wraps silently (replicas share a device;
      how single-CPU tests run an N-replica fleet).
    * ``shards_per_replica > 1`` and one physical device — every replica
      gets the single device repeated (pure emulation: the TP layer runs
      its shards under ``vmap`` on that device), with a warning so a
      misconfigured production launch is loud.
    * ``shards_per_replica > 1`` on a real mesh — a replica whose group
      would straddle the device-list end non-contiguously (wrap-around
      mixing the first and last devices of the "model" axis) is REJECTED:
      the wrapped group's psum would hop the mesh seam every layer.  Grow
      the emulated mesh (``--xla_force_host_platform_device_count``) or
      drop the replica count.
    """
    if n_replicas < 1:
        raise ValueError(f"need at least one replica, got {n_replicas}")
    if shards_per_replica < 1:
        raise ValueError(f"need at least one shard per replica, got {shards_per_replica}")
    devs = jax.devices()
    d = len(devs)
    if shards_per_replica == 1:
        return [[devs[i % d]] for i in range(n_replicas)]
    if d == 1:
        warnings.warn(
            f"{shards_per_replica}-way tensor parallelism on a single device: "
            "shards will be vmap-emulated, not distributed",
            stacklevel=2,
        )
        return [[devs[0]] * shards_per_replica for _ in range(n_replicas)]
    groups = []
    for i in range(n_replicas):
        start = (i * shards_per_replica) % d
        if start + shards_per_replica > d:
            raise ValueError(
                f"replica {i}'s {shards_per_replica}-device submesh would wrap "
                f"non-contiguously around the {d}-device mesh (start {start}); "
                f"the model axis must stay contiguous — use "
                f"n_replicas * shards_per_replica <= {d} (or a multiple)"
            )
        groups.append(list(devs[start : start + shards_per_replica]))
    return groups
