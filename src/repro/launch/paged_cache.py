"""Paged KV cache: fixed-size blocks, per-slot block tables, free-list alloc.

The physical cache is one token-major pool per model segment
(``models.api.init_paged_pools``): k/v of shape (layers, T, Hkv, hd) with
T = ``num_blocks * page_size``.  A *block* (page) is ``page_size``
consecutive pool cells; a decode slot owns an ordered list of blocks — its
block-table row — mapping logical positions to physical cells:

    flat(pos) = table[slot, pos // page_size] * page_size + pos % page_size

Allocation is a host-side free list.  Block 0 is reserved as the *dummy*
page: padded dispatch rows and prompt-padding tokens route their writes
there, so a bucketed dispatch never touches a live slot's cells.  Freeing a
retired request returns its blocks for mid-flight admission of queued
requests — the engine's continuous-batching lever.

Under block pressure the engine *preempts*: :func:`swap_out` snapshots a
victim slot's live cells to host memory so its blocks can be freed, and
:func:`swap_in` restores the snapshot into freshly allocated (generally
different) blocks on re-admission — byte-identical contents, because the
snapshot is keyed by *logical* position and the block table re-maps it.
The dummy block is never part of a snapshot (a slot's live cells live in
its own blocks by construction; ``slot_cells`` asserts it).

Everything here is host bookkeeping (numpy) except the two swap helpers,
which gather/scatter pool cells on device; the jitted dispatches receive
plain int32 index arrays derived from the tables.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

DUMMY_BLOCK = 0


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Shape policy for the paged pool.

    ``num_blocks`` includes the reserved dummy block; a slot may own at most
    ``max_pages`` blocks (ceil(max_seq_len / page_size) for the engine).
    """

    page_size: int = 16
    num_blocks: int = 257
    max_slots: int = 8
    max_pages: int = 32

    @property
    def num_tokens(self) -> int:
        return self.num_blocks * self.page_size

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1  # block 0 is the dummy page


class BlockAllocator:
    """LIFO free list over physical blocks 1..num_blocks-1."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need at least one usable block beyond the dummy")
        self._free = list(range(num_blocks - 1, 0, -1))  # pop() yields 1, 2, ...

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """n blocks, or None (allocation is all-or-nothing) if exhausted."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if b == DUMMY_BLOCK:
                raise ValueError("freeing the reserved dummy block")
        self._free.extend(blocks)


class PagedKVCache:
    """Block tables + allocator for ``max_slots`` concurrent decode slots.

    The device pools themselves are owned by the engine (they thread through
    the donated dispatches); this class tracks which physical cells each
    slot's logical sequence occupies.
    """

    def __init__(self, cfg: PagedCacheConfig):
        self.cfg = cfg
        self.allocator = BlockAllocator(cfg.num_blocks)
        # rows padded with the dummy block: gathers from unallocated pages
        # read garbage that the attention mask kills
        self.tables = np.full((cfg.max_slots, cfg.max_pages), DUMMY_BLOCK, np.int32)
        self.n_pages = np.zeros((cfg.max_slots,), np.int32)

    # -- lifecycle ---------------------------------------------------------

    def ensure_capacity(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot`` to hold ``n_tokens`` cells; False if out of blocks
        (the caller keeps the request queued until a retirement frees some)."""
        need = -(-n_tokens // self.cfg.page_size)
        if need > self.cfg.max_pages:
            raise ValueError(
                f"request needs {need} pages > max_pages={self.cfg.max_pages}"
            )
        have = int(self.n_pages[slot])
        if need <= have:
            return True
        got = self.allocator.alloc(need - have)
        if got is None:
            return False
        self.tables[slot, have:need] = got
        self.n_pages[slot] = need
        return True

    def release(self, slot: int) -> None:
        """Return a retired slot's blocks to the free list."""
        n = int(self.n_pages[slot])
        if n:
            self.allocator.free(self.tables[slot, :n].tolist())
        self.tables[slot, :] = DUMMY_BLOCK
        self.n_pages[slot] = 0

    # -- index derivation for dispatches -----------------------------------

    def table_rows(self, slots: list[int], n_pages: int) -> np.ndarray:
        """(len(slots), n_pages) block-table slice for a bucketed dispatch;
        unallocated entries are the dummy block."""
        return self.tables[np.asarray(slots, np.int64), :n_pages].astype(np.int32)

    def flat_idx(self, slot: int, pos: int) -> int:
        """Physical pool cell of logical position ``pos`` in ``slot``
        (debug/test helper; dispatches derive cells from the table rows)."""
        page = self.cfg.page_size
        blk = int(self.tables[slot, pos // page])
        return blk * page + pos % page

    def slot_cells(self, slot: int, n_tokens: int) -> np.ndarray:
        """(n_tokens,) physical pool cells of logical positions
        [0, n_tokens) in ``slot``, in logical order — the index array the
        swap helpers gather/scatter through.  Every position must be inside
        the slot's allocation; the dummy block is never a live cell."""
        page = self.cfg.page_size
        need = -(-n_tokens // page)
        if need > int(self.n_pages[slot]):
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens exceed its "
                f"{int(self.n_pages[slot])}-page allocation"
            )
        pos = np.arange(n_tokens)
        blocks = self.tables[slot, pos // page]
        assert not np.any(blocks == DUMMY_BLOCK), "live cell in the dummy block"
        return (blocks.astype(np.int64) * page + pos % page).astype(np.int32)


# -- preemption: host-side block snapshots ----------------------------------

def swap_out(pools, kv: "PagedKVCache", slot: int, n_tokens: int):
    """Snapshot ``slot``'s live cells — logical positions [0, n_tokens) —
    to host memory (numpy), so the caller can ``release`` the slot's blocks.

    ``pools`` is the engine-owned device pool pytree (one token-major leaf
    per segment, cell axis at -3: (layers, T, Hkv, hd), with any extra
    leading axes — e.g. a tensor-parallel shard axis — passing through);
    the snapshot pytree mirrors it with the cell axis re-indexed to logical
    order.  The transfer is forced synchronously (``np.asarray``) so later
    donated dispatches cannot invalidate the buffers mid-read.
    """
    cells = kv.slot_cells(slot, n_tokens)
    return jax.tree.map(lambda a: np.asarray(a[..., cells, :, :]), pools)


_swap_scatter = None  # lazily jitted so the backend is known at first use


def swap_in(pools, kv: "PagedKVCache", slot: int, snapshot):
    """Restore a :func:`swap_out` snapshot into ``slot``'s current blocks.

    The caller re-allocates first (``ensure_capacity`` for at least the
    snapshot's token count); blocks will generally differ from the ones
    snapshotted — contents land byte-identical anyway because both sides
    index by logical position.  Returns the updated pools pytree; the input
    pools are donated where the backend supports it (the scatter updates
    the pool buffers in place instead of copying every leaf per swap-in),
    so callers must rebind — exactly the engine's ``self.pools = ...``
    discipline for its donated dispatches.  Cell counts are bucketed to
    powers of two to bound retraces; pad cells point at the dummy page,
    which absorbs their zero writes like every other bucketed dispatch's
    padding.
    """
    global _swap_scatter
    if _swap_scatter is None:
        donate = (0,) if jax.default_backend() != "cpu" else ()
        _swap_scatter = jax.jit(
            lambda p, cells, s: jax.tree.map(
                lambda a, sl: a.at[..., cells, :, :].set(sl), p, s
            ),
            donate_argnums=donate,
        )
    n_tokens = next(iter(jax.tree.leaves(snapshot))).shape[-3]
    cells = kv.slot_cells(slot, n_tokens)
    nb = 1 << max(0, n_tokens - 1).bit_length()
    if pad := nb - n_tokens:
        cells = np.concatenate([cells, np.zeros(pad, np.int32)])  # dummy cells
        snapshot = jax.tree.map(
            lambda s: np.concatenate(
                [s, np.zeros(s.shape[:-3] + (pad,) + s.shape[-2:], s.dtype)],
                axis=-3,
            ),
            snapshot,
        )
    return _swap_scatter(pools, jnp.asarray(cells), snapshot)
