"""End-to-end training driver.

Runs the same ``train_step`` the dry-run lowers for 512 chips, on the local
mesh, with the full production control plane (checkpoint/restart, retries,
straggler watchdog, optional crossbar redeploy pricing).  This is the
driver behind examples/train_lm.py and the accuracy-preservation benchmark.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_arch
from repro.data import DataConfig, make_dataset
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import FaultPolicy, StragglerPolicy, TrainLoop, TrainLoopConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="CPU-scale reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--redeploy-every", type=int, default=0)
    ap.add_argument("--task", default="lm", choices=["lm", "copy"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write metrics JSON here")
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=min(20, args.steps // 5))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=args.remat))

    data = make_dataset(
        DataConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
            task=args.task, seed=args.seed,
        )
    )

    def init_state():
        params = api.init(jax.random.PRNGKey(args.seed), cfg)
        return params, adamw_init(params)

    loop = TrainLoop(
        cfg,
        TrainLoopConfig(
            total_steps=args.steps,
            checkpoint_every=args.ckpt_every,
            checkpoint_dir=args.ckpt_dir,
            redeploy_every=args.redeploy_every,
        ),
        train_step=step_fn,
        init_state=init_state,
        dataset=data,
        fault=FaultPolicy(max_retries=2),
        straggler=StragglerPolicy(),
    )
    print(f"training {cfg.name} ({'reduced' if args.reduced else 'full'}) "
          f"from step {loop.start_step} to {args.steps}")
    result = loop.run()
    for rec in result["metrics_log"]:
        print(f"step {rec['step']:5d}  loss {rec['loss']:.4f}  lr {rec.get('lr', 0):.2e}  "
              f"wall {rec['wall_s']:.3f}s")
    if result["redeploy_log"]:
        print("redeploy pricing (per snapshot):")
        for rec in result["redeploy_log"]:
            print(f"  step {rec['step']:5d} {rec['tensor']}: inplace={rec['transitions_natural']} "
                  f"stale-sort streaming {rec['stale_sort_speedup']:.2f}x")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
