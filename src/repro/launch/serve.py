"""Batched serving driver: prefill + decode with optional CIM-deployed weights.

Serves a model with batched requests through the same prefill/serve_step
functions the dry-run lowers, optionally swapping every eligible weight for
its crossbar-deployed (quantized + bit-stuck) counterpart so the *serving*
accuracy impact of the paper's technique is observable end to end.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --batch 4 --prompt-len 32 --gen 16 [--cim --p-stuck 0.5]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment, deploy_params
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import api


def generate(cfg, params, batch, *, gen_len: int, greedy: bool = True, seed: int = 0):
    """Prefill then decode ``gen_len`` tokens; returns (tokens, tok/s)."""
    b, prompt_len = batch["tokens"].shape
    prefill = jax.jit(make_prefill_step(cfg))
    serve = jax.jit(make_serve_step(cfg))

    # cache sized for the full generation; encdec keeps a src-len cross cache
    cache = api.init_cache(
        cfg, b, prompt_len + gen_len,
        src_len=prompt_len if cfg.encdec else None,
    )
    t0 = time.time()
    logits, pf_cache = prefill(params, batch)
    # prefill returns per-segment caches of the prompt; copy into the full cache
    cache = api.merge_prefill_cache(cfg, cache, pf_cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    key = jax.random.PRNGKey(seed)
    for i in range(gen_len - 1):
        logits, cache = serve(params, cache, tok, jnp.int32(prompt_len + i))
        if greedy:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1])[:, None].astype(jnp.int32)
        out.append(tok)
    tokens = jnp.concatenate(out, axis=1)
    jax.block_until_ready(tokens)
    dt = time.time() - t0
    return tokens, b * gen_len / dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cim", action="store_true", help="serve crossbar-deployed weights")
    ap.add_argument("--p-stuck", type=float, default=0.5)
    ap.add_argument("--rows", type=int, default=128)
    ap.add_argument("--cols", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, args.batch, args.prompt_len)

    tokens, tps = generate(cfg, params, batch, gen_len=args.gen, seed=args.seed)
    print(f"fp weights:   {tps:8.1f} tok/s   first request: {tokens[0, :12].tolist()}")

    if args.cim:
        plan = build_deployment(
            params,
            CrossbarSpec(rows=args.rows, cols=args.cols),
            PlannerConfig(p_stuck=args.p_stuck, min_size=1024),
        )
        params_hat = deploy_params(params, plan)
        tokens_hat, tps_hat = generate(cfg, params_hat, batch, gen_len=args.gen, seed=args.seed)
        agree = float(jnp.mean((tokens == tokens_hat).astype(jnp.float32)))
        t = plan.totals()
        print(f"cim weights:  {tps_hat:8.1f} tok/s   first request: {tokens_hat[0, :12].tolist()}")
        print(f"token agreement: {agree:.3f}   reprog speedup: {t['total_speedup']:.2f}x "
              f"(sws {t['sws_speedup']:.2f}x)")


if __name__ == "__main__":
    main()
