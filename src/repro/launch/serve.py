"""Batched serving driver: prefill + decode with optional CIM-deployed weights.

Serves a model with batched requests through the same prefill/serve_step
functions the dry-run lowers, optionally swapping every eligible weight for
its crossbar-deployed (quantized + bit-stuck) counterpart so the *serving*
accuracy impact of the paper's technique is observable end to end.  With
``--cim`` the deployment streams through a persistent ``CrossbarPool``, so
the report includes physical wear: max/mean per-cell writes and the
endurance-budget exhaustion horizon (how many such deployments the pool
survives).

Serving representation (``--materialize``): ``dense`` serves the achieved
weights as ordinary f32 matmuls (the baseline); ``packed`` serves straight
from the crossbar state — bit-packed plane operands (the same canonical
packed words the planner/pool hold) flowing through the Pallas
``cim_matmul`` packed kernel on TPU (portable packed reference elsewhere);
``planes_int8`` is the one-byte-per-bit-cell traffic baseline.

Stored-plane codec (``--codec``, ``core/planes.py``): ``raw`` | ``const_rle``
| ``col_perm`` | ``col_perm_rle``.  Non-raw codecs change the physical bits
the pool programs (column-similarity reordering cuts reprogramming
transitions; constant-tile elision cuts weight traffic) and, with
``--materialize packed``, ride into the serving operands (plane-axis
reorder + zero-tile kernel skipping).  Token streams are bit-identical to
dense under every codec — the decode contract of ``core.planes``.

Decode loop (``--loop``): ``scan`` (default) runs the whole generation as a
single ``lax.scan`` dispatch with the KV cache donated, so decode never
copies the cache between tokens; ``python`` keeps the per-token dispatch
loop (cache still donated per step where the backend supports it).

Throughput accounting: one full prefill+decode step runs *before* the timer
starts, so jit compilation never pollutes the reported tok/s.

This driver serves ONE fixed-shape lockstep batch; ``launch.engine`` serves
streaming heterogeneous traffic (paged KV cache, fused prefill+decode,
preemption) with per-request token streams bit-identical to this module's
``generate`` — see docs/architecture.md for how the two relate.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --batch 4 --prompt-len 32 --gen 16 \
      [--cim --p-stuck 0.5 --pool-leveling lpt --materialize packed]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.planner import (
    MATERIALIZATIONS,
    CrossbarSpec,
    PlannerConfig,
    build_deployment,
    deploy_params,
)
from repro.core.planes import CODECS
from repro.core.pool import DEFAULT_ENDURANCE, LEVELINGS, CrossbarPool
from repro.launch.steps import (
    cache_donation,
    make_decode_loop,
    make_prefill_step,
    make_serve_step,
    prepare_serving_params,
)
from repro.models import api


def make_generator(
    cfg, params, batch, *, gen_len: int, greedy: bool = True, seed: int = 0,
    loop: str = "scan",
):
    """Compile a full prefill+decode pipeline once; returns ``timed_run()``
    -> (tokens, seconds).

    The first call made here (untimed) is the jit warmup; each subsequent
    ``timed_run`` re-serves the same batch through the already-compiled
    dispatches.  Benchmarks comparing several deployments keep one generator
    per variant alive and interleave timed passes, so every variant samples
    the same background-load conditions (see serving_throughput).
    """
    if loop not in ("scan", "python"):
        raise ValueError(f"unknown decode loop {loop!r}")
    b, prompt_len = batch["tokens"].shape
    # once-per-deployment packed->dense decompression on non-TPU backends;
    # every dispatch below (warmup included) reuses the prepared tree
    params = prepare_serving_params(params)
    prefill = jax.jit(make_prefill_step(cfg))
    donate = cache_donation()
    if loop == "scan":
        decode = jax.jit(
            make_decode_loop(cfg, gen_len - 1, greedy=greedy), donate_argnums=donate
        )
    else:
        serve = jax.jit(make_serve_step(cfg), donate_argnums=donate)

    # cache sized for the full generation; encdec keeps a src-len cross cache
    cache = api.init_cache(
        cfg, b, prompt_len + gen_len,
        src_len=prompt_len if cfg.encdec else None,
    )

    key = jax.random.PRNGKey(seed)

    def pick(logits, key):
        """Next token from the last position — one sampling path for every
        decode step, the first post-prefill token included."""
        if greedy:
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32), key
        key, sub = jax.random.split(key)
        return jax.random.categorical(sub, logits[:, -1])[:, None].astype(jnp.int32), key

    def run(key):
        """One full prefill + decode; called once untimed, then per pass."""
        logits, pf_cache = prefill(params, batch)
        # prefill returns per-segment caches of the prompt; copy into the full cache
        run_cache = api.merge_prefill_cache(cfg, cache, pf_cache)
        tok, key = pick(logits, key)
        if loop == "scan":
            toks, _ = decode(params, run_cache, tok, key, jnp.int32(prompt_len))
            tokens = jnp.concatenate([tok, toks], axis=1)
        else:
            out = [tok]
            for i in range(gen_len - 1):
                logits, run_cache = serve(params, run_cache, tok, jnp.int32(prompt_len + i))
                tok, key = pick(logits, key)
                out.append(tok)
            tokens = jnp.concatenate(out, axis=1)
        jax.block_until_ready(tokens)
        return tokens

    run(key)  # warmup: compile prefill + decode outside any timed region

    def timed_run():
        t0 = time.time()
        tokens = run(key)
        return tokens, time.time() - t0

    return timed_run


def generate(
    cfg, params, batch, *, gen_len: int, greedy: bool = True, seed: int = 0,
    loop: str = "scan", repeats: int = 1,
):
    """Prefill then decode ``gen_len`` tokens; returns (tokens, tok/s).

    The first prefill+decode step is executed once untimed (jit warmup):
    compile time used to land inside the timer and understate tok/s by an
    order of magnitude on short generations.  ``loop="scan"`` (default)
    fuses the decode loop into one donated-cache ``lax.scan`` dispatch;
    ``loop="python"`` is the legacy per-token dispatch loop.  Both share one
    sampling path and PRNG schedule, so tokens agree between loops.

    ``repeats``: the timed region for a reduced model is tens of
    milliseconds — a single sample swings tens of percent with scheduler /
    allocator noise, which is enough to invert the ordering of identical
    compute graphs (fp vs cim-dense are the same f32 matmuls).  Benchmarks
    pass ``repeats>=3`` and take the best run; tokens come from the last.
    """
    b, gen = batch["tokens"].shape[0], gen_len
    timed_run = make_generator(
        cfg, params, batch, gen_len=gen_len, greedy=greedy, seed=seed, loop=loop
    )
    best = float("inf")
    for _ in range(max(1, repeats)):
        tokens, dt = timed_run()
        best = min(best, dt)
    return tokens, b * gen / best


def main() -> None:
    """CLI entry: serve a (reduced) arch with fp weights, then optionally
    re-serve it crossbar-deployed (``--cim``) and report tok/s, token
    agreement, reprogramming speedups, pool wear, and the endurance
    horizon.  For streaming heterogeneous traffic use ``launch.engine``
    (continuous batching) instead; this driver serves one lockstep batch."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cim", action="store_true", help="serve crossbar-deployed weights")
    ap.add_argument(
        "--materialize", choices=MATERIALIZATIONS, default="dense",
        help="serving representation of deployed tensors (packed = bit-plane-native)",
    )
    ap.add_argument(
        "--codec", choices=CODECS, default="raw",
        help="stored-plane codec (core/planes.py): changes the physical bits "
             "the pool programs (and the priced transitions) and, with "
             "--materialize packed, the serving operand layout; token streams "
             "stay bit-identical to dense for every codec",
    )
    ap.add_argument(
        "--loop", choices=["scan", "python"], default="scan",
        help="decode loop: one fused lax.scan dispatch or per-token dispatches",
    )
    ap.add_argument("--p-stuck", type=float, default=0.5)
    ap.add_argument("--rows", type=int, default=128)
    ap.add_argument("--cols", type=int, default=10)
    ap.add_argument(
        "--min-size", type=int, default=PlannerConfig().min_size,
        help="smallest tensor (elements) deployed to crossbars",
    )
    ap.add_argument(
        "--pool-leveling", choices=LEVELINGS, default="none",
        help="wear-leveling chain->crossbar assignment for the pool",
    )
    ap.add_argument(
        "--endurance", type=float, default=DEFAULT_ENDURANCE,
        help="per-cell write endurance budget for the exhaustion horizon",
    )
    ap.add_argument(
        "--fault-rate", type=float, default=0.0,
        help="per-cell stuck-at rate (split evenly stuck-at-0/1) injected "
             "into the pool before deployment; reads go through the masks",
    )
    ap.add_argument(
        "--fault-hotspot", type=float, default=0.0,
        help="fraction of crossbars with 8x the stuck-at rate (the "
             "heterogeneous-yield setting 'fault' leveling remaps around)",
    )
    ap.add_argument(
        "--scrub", action="store_true",
        help="enable the online integrity layer (core/integrity.py): tile "
             "checksums + spare columns registered at program() time, with a "
             "scrub/repair summary in the report",
    )
    ap.add_argument(
        "--scrub-tiles", type=int, default=64,
        help="tile-verification budget per scrub round (bounds scrub latency)",
    )
    ap.add_argument(
        "--spare-cols", type=int, default=2,
        help="clean spare column planes per section (remap targets for hard "
             "stuck-at faults found by the scrubber)",
    )
    ap.add_argument(
        "--scrub-storm", type=float, default=0.0,
        help="after deployment, corrupt stored bits at this rate (plus 1/10th "
             "of it as new hard stuck cells), scrub to convergence, and report "
             "repair cost vs a full reprogram of the affected tensors",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if (args.scrub or args.scrub_storm > 0.0) and not args.cim:
        ap.error("--scrub/--scrub-storm apply to crossbar-deployed weights; add --cim")
    if args.scrub_storm > 0.0 and not args.scrub:
        ap.error("--scrub-storm needs the integrity layer; add --scrub")
    if args.codec != "raw":
        if not args.cim:
            ap.error("--codec applies to crossbar-deployed weights; add --cim")
        if args.materialize == "planes_int8":
            ap.error(
                "--codec encodes packed serving operands; --materialize "
                "planes_int8 has no stored-plane layout (use packed or dense)"
            )

    cfg = get_arch(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, args.batch, args.prompt_len)

    tokens, tps = generate(cfg, params, batch, gen_len=args.gen, seed=args.seed, loop=args.loop)
    print(f"fp weights:   {tps:8.1f} tok/s   first request: {tokens[0, :12].tolist()}")

    if args.cim:
        spec = CrossbarSpec(rows=args.rows, cols=args.cols)
        planner_cfg = PlannerConfig(
            p_stuck=args.p_stuck,
            min_size=args.min_size,
            pool_leveling=args.pool_leveling,
            codec=args.codec,
        )
        pool = CrossbarPool(spec, planner_cfg.crossbars, leveling=args.pool_leveling)
        if args.scrub:
            from repro.core.integrity import IntegrityConfig

            pool.enable_integrity(IntegrityConfig(
                spare_cols=args.spare_cols, scrub_tiles=args.scrub_tiles,
            ))
        if args.fault_rate > 0.0:
            from repro.core import nonideal

            fstate = pool.inject_faults(
                nonideal.FaultModel(
                    stuck0=args.fault_rate / 2, stuck1=args.fault_rate / 2,
                    hotspot_fraction=args.fault_hotspot, hotspot_mult=8.0,
                ),
                jax.random.PRNGKey(args.seed),
            )
            cells = fstate.fault_cells()
            print(f"injected faults: {int(cells.sum())} stuck cells across "
                  f"{pool.n_crossbars} crossbars (worst {int(cells.max())}; "
                  f"{int(fstate.hot.sum())} hotspots)")
        plan = build_deployment(params, spec, planner_cfg, pool=pool)
        # dense materialization has no stored-plane layout to encode; the
        # plan's codec already shaped the pool's physical programming above
        codec = args.codec if args.materialize == "packed" else "raw"
        params_hat = deploy_params(params, plan, materialize=args.materialize, codec=codec)
        tokens_hat, tps_hat = generate(
            cfg, params_hat, batch, gen_len=args.gen, seed=args.seed, loop=args.loop
        )
        agree = float(jnp.mean((tokens == tokens_hat).astype(jnp.float32)))
        t = plan.totals()
        stats = pool.stats()
        horizon = stats.exhaustion_horizon(args.endurance)
        print(f"cim weights:  {tps_hat:8.1f} tok/s   ({args.materialize} materialization)"
              f"   first request: {tokens_hat[0, :12].tolist()}")
        print(f"token agreement: {agree:.3f}   reprog speedup: {t['total_speedup']:.2f}x "
              f"(sws {t['sws_speedup']:.2f}x)")
        print(f"pool wear: max cell {stats.max_cell_writes} writes, "
              f"mean {stats.mean_cell_writes:.2f}, total {stats.total_writes} "
              f"over {stats.tensors_seen} tensors")
        print(f"endurance horizon: ~{horizon:.3g} such deployments "
              f"@ {args.endurance:.0e} writes/cell ({args.pool_leveling} leveling)")
        if args.scrub:
            mgr = pool.integrity
            s = mgr.summary()
            print(f"integrity: {s['tensors']} tensors registered, {s['tiles']} "
                  f"checksum tiles, {s['spare_cols']} spare cols/section"
                  + (" + parity" if s["parity_col"] else ""))
            if args.scrub_storm > 0.0:
                st = mgr.storm(
                    jax.random.PRNGKey(args.seed + 1),
                    corrupt_rate=args.scrub_storm,
                    stuck_rate=args.scrub_storm / 10,
                )
                rep = mgr.scrub_until_clean()
                full = mgr.transitions_full_affected()
                ratio = rep.repair_transitions / max(full, 1)
                print(f"storm: {st['corrupted_bits']} bits corrupted, "
                      f"{st['new_stuck_cells']} new stuck cells -> "
                      f"{rep.detections} detections, {rep.rewrites} rewrites, "
                      f"{rep.remaps} remaps, {rep.migrations} migrations, "
                      f"{rep.tolerated} tolerated")
                print(f"repair cost: {rep.repair_transitions} transitions vs "
                      f"{full} full reprogram ({ratio:.4f}x); reads restored: "
                      f"{mgr.verify_all()}")


if __name__ == "__main__":
    main()
