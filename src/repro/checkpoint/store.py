"""Sharding-aware checkpointing: atomic, async-capable, elastically reshardable.

Design (mirrors production Orbax-style layouts without the dependency):

* A checkpoint is a directory ``step_<n>/`` holding one ``.npy`` per leaf
  (flattened path as filename) plus a ``MANIFEST.json`` with the treedef,
  shapes, dtypes, and the step.  Writes go to ``step_<n>.tmp/`` and are
  published with a single atomic ``rename`` — a crash mid-write can never
  leave a readable-but-corrupt checkpoint (fault tolerance, DESIGN.md §5).
* ``save`` gathers each (possibly sharded) jax.Array to host memory; restore
  re-shards onto the *current* mesh via ``jax.device_put(..., sharding)``,
  so a checkpoint written on mesh A loads onto mesh B with any device count
  — this is the elastic-scaling path (tests/test_checkpoint.py proves
  1-device -> k-device roundtrips bit-exactly).
* ``CheckpointManager`` adds retention, ``latest``, and an async writer
  (a single background thread; ``wait()`` joins before the next save —
  overlap checkpoint I/O with the next training steps).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


_MANIFEST = "MANIFEST.json"


def _leaf_name(path) -> str:
    return (
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        .replace("/", "__")
        or "root"
    )


def save_checkpoint(directory: str | os.PathLike, step: int, tree: Any) -> Path:
    """Write ``tree`` under ``directory/step_<step>`` atomically; returns path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    manifest: dict[str, Any] = {"step": step, "leaves": []}
    for path, leaf in flat:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"{name}.npy", arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    manifest["treedef"] = str(treedef)
    (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    return final


def restore_checkpoint(
    directory: str | os.PathLike,
    step: int,
    like: Any,
    *,
    shardings: Any = None,
) -> Any:
    """Load ``step`` into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``, if given, is a matching pytree of
    ``jax.sharding.Sharding`` — each leaf is placed directly onto the current
    mesh (elastic re-shard)."""
    final = Path(directory) / f"step_{step:08d}"
    if not (final / _MANIFEST).exists():
        raise FileNotFoundError(f"no checkpoint at {final}")

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    sh_flat = None
    if shardings is not None:
        sh_flat = jax.tree_util.tree_flatten(shardings)[0]
        if len(sh_flat) != len(flat):
            raise ValueError("shardings structure does not match tree")

    leaves = []
    for i, (path, leaf) in enumerate(flat):
        name = _leaf_name(path)
        arr = np.load(final / f"{name}.npy")
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != expected {want_shape}")
        arr = arr.astype(leaf.dtype)
        if sh_flat is not None:
            leaves.append(jax.device_put(arr, sh_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and (p / _MANIFEST).exists():
            steps.append(int(p.name.removeprefix("step_")))
    return max(steps) if steps else None


class CheckpointManager:
    """Retention + async writes on top of save/restore."""

    def __init__(self, directory: str | os.PathLike, *, keep: int = 3, async_write: bool = True):
        self.directory = Path(directory)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write path -----------------------------------------------------------

    def save(self, step: int, tree: Any) -> None:
        self.wait()  # at most one in-flight write
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.removeprefix("step_"))
            for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)

    # -- read path --------------------------------------------------------------

    def latest(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    def restore(self, step: int, like: Any, *, shardings: Any = None) -> Any:
        self.wait()
        return restore_checkpoint(self.directory, step, like, shardings=shardings)
