"""Public jit'd wrapper for the Hamming kernel (pads, dispatches)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._util import default_interpret, pad_axis_to, round_up
from repro.kernels.hamming.kernel import hamming_pairs_kernel


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def hamming_pairs(
    a: jax.Array, b: jax.Array, *, bt: int = 256, interpret: bool | None = None
) -> jax.Array:
    """Per-pair transition counts: popcount(a[t] ^ b[t]) -> int32[T].

    Zero-padding pairs is free (popcount(0^0) = 0) so arbitrary T is fine.
    """
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch: {a.shape} vs {b.shape}")
    t = a.shape[0]
    interp = default_interpret(interpret)
    bt_ = min(bt, round_up(max(t, 1), 8))
    tp = round_up(max(t, 1), bt_)
    ap = pad_axis_to(a, 0, tp)
    bp = pad_axis_to(b, 0, tp)
    out = hamming_pairs_kernel(ap, bp, bt=bt_, interpret=interp)
    return out[:t]


def chain_costs(packed_states: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Consecutive reprogram costs along a chain of packed states -> int32[S-1]."""
    return hamming_pairs(packed_states[:-1], packed_states[1:], interpret=interpret)
