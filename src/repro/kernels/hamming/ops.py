"""Public jit'd wrapper for the Hamming kernel (pads, dispatches).

Two entry points:
  * ``hamming_pairs``  — always routes through the Pallas kernel (compiled on
    TPU, interpreted elsewhere); the parity/testing surface.
  * ``price_pairs``    — the planner's hot-path dispatcher: the compiled
    Pallas kernel on TPU, a plain ``lax.population_count`` XOR elsewhere
    (interpret-mode Pallas runs the grid in Python and would be orders of
    magnitude slower than the portable fallback on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._util import default_interpret, on_tpu, pad_axis_to, round_up
from repro.kernels.hamming import ref as hamming_ref
from repro.kernels.hamming.kernel import hamming_pairs_kernel


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def hamming_pairs(
    a: jax.Array, b: jax.Array, *, bt: int = 256, interpret: bool | None = None
) -> jax.Array:
    """Per-pair transition counts: popcount(a[t] ^ b[t]) -> int32[T].

    Zero-padding pairs is free (popcount(0^0) = 0) so arbitrary T is fine.
    """
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch: {a.shape} vs {b.shape}")
    t = a.shape[0]
    interp = default_interpret(interpret)
    bt_ = min(bt, round_up(max(t, 1), 8))
    tp = round_up(max(t, 1), bt_)
    ap = pad_axis_to(a, 0, tp)
    bp = pad_axis_to(b, 0, tp)
    out = hamming_pairs_kernel(ap, bp, bt=bt_, interpret=interp)
    return out[:t]


def chain_costs(packed_states: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Consecutive reprogram costs along a chain of packed states -> int32[S-1]."""
    return hamming_pairs(packed_states[:-1], packed_states[1:], interpret=interpret)


def price_pairs(a: jax.Array, b: jax.Array) -> jax.Array:
    """Best-available per-pair pricing: popcount(a[t] ^ b[t]) -> int32[T].

    a, b: uint8[T, W, C] packed planes.  Dispatches to the compiled Pallas
    kernel on TPU and to the portable ``lax.population_count`` oracle on every
    other backend.  Safe to call inside jit; T may be 0.
    """
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch: {a.shape} vs {b.shape}")
    if a.shape[0] == 0:
        return jnp.zeros((0,), jnp.int32)
    if on_tpu():
        return hamming_pairs(a, b, interpret=False)
    return hamming_ref.hamming_pairs(a, b)
