"""Pure-jnp oracle for packed Hamming transition counting.

Contract (shared with kernel.py / ops.py):
  a, b: uint8[T, W, C] packed bit planes (W = ceil(rows/8) byte words,
        C = bit columns); see ``repro.core.bitslice.pack_rows``.
  out:  int32[T] — per-pair transition counts: popcount(a[t] XOR b[t]).

This is Eq. 1 of the paper evaluated for T crossbar reprogram pairs at once;
the planner calls it with a = states[:-1], b = states[1:] along a chain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hamming_pairs(a: jax.Array, b: jax.Array) -> jax.Array:
    x = jax.lax.population_count(jnp.bitwise_xor(a, b))
    return jnp.sum(x.astype(jnp.int32), axis=(1, 2))
