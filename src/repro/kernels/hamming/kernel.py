"""Pallas TPU kernel for packed Hamming transition counting (Eq. 1).

The planner's dominant compute when pricing large models is XOR+popcount
over millions of packed section pairs.  Each grid step loads a (bt, W, C)
block of both operands into VMEM, XORs on the VPU, popcounts with a SWAR
shift/mask sequence (portable across Mosaic and the interpreter), and
reduces to bt per-pair counts.

Blocks are sized so 2 * bt * W * C input bytes stay well under VMEM
(default bt=256 with 128x16 sections = 2 * 256 * 16 * 16 = 128 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._util import cdiv, popcount_i32


def _kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    x = jnp.bitwise_xor(a, b)
    pc = popcount_i32(x)
    o_ref[...] = jnp.sum(pc, axis=(1, 2))


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def hamming_pairs_kernel(
    a: jax.Array, b: jax.Array, *, bt: int = 256, interpret: bool = False
) -> jax.Array:
    """Raw kernel entry: T must already be a multiple of bt.

    a, b: uint8[T, W, C] -> int32[T].
    """
    t, w, c = a.shape
    grid = (cdiv(t, bt),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, w, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((bt, w, c), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.int32),
        interpret=interpret,
    )(a, b)
