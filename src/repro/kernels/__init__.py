"""Pallas TPU kernels for the paper's compute hot-spots.

  cim_matmul      — fused bit-sliced (crossbar) matmul: the CIM execution path
  hamming         — XOR + popcount transition counting (Eq. 1 at scale)
  bitslice        — fused quantize + bit-plane extraction
  flash_attention — blockwise attention for the 32k-prefill serving path

Each kernel directory has: kernel.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd public wrapper with CPU-interpret/TPU dispatch), ref.py (pure
jnp oracle).  TPU is the target; on this CPU-only container every kernel is
validated with interpret=True against its oracle (see tests/test_kernels.py).
"""
