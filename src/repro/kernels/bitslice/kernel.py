"""Pallas TPU kernel for fused quantize + bit-plane extraction.

Deploying a model to crossbars bit-slices every weight tensor; doing the
quantize->shift->mask pipeline in one VMEM pass avoids materializing the
intermediate int32 q tensor in HBM (at cols=10, that intermediate alone is
4 bytes/weight vs the 1-byte/plane output).  All VPU integer ops.

Grid: (K/bk, N/bn); each step writes all ``cols`` planes of its tile, so the
output block is (cols, bk, bn) and the plane axis is never re-visited.
``inv_scale`` rides in SMEM as a (1, 1) scalar block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._util import cdiv


def _kernel(scale_ref, w_ref, o_ref, *, cols: int):
    w = w_ref[...].astype(jnp.float32)
    inv_scale = scale_ref[0, 0]
    levels = jnp.float32(2**cols - 1)
    q = jnp.clip(jnp.round(jnp.abs(w) * inv_scale), 0.0, levels).astype(jnp.int32)
    sign = jnp.where(w < 0, -1, 1).astype(jnp.int32)
    for b in range(cols):
        o_ref[b, :, :] = (((q >> b) & 1) * sign).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("cols", "bk", "bn", "interpret"))
def bitslice_kernel(
    w: jax.Array,
    inv_scale: jax.Array,
    *,
    cols: int,
    bk: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Raw kernel entry: (K, N) must already be padded to block multiples."""
    k, n = w.shape
    grid = (cdiv(k, bk), cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(_kernel, cols=cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((cols, bk, bn), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((cols, k, n), jnp.int8),
        interpret=interpret,
    )(inv_scale.reshape(1, 1).astype(jnp.float32), w)
