"""Public jit'd wrapper for the bitslice kernel (pads, dispatches)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._util import default_interpret, pad_axis_to, round_up
from repro.kernels.bitslice.kernel import bitslice_kernel


@functools.partial(jax.jit, static_argnames=("cols", "bk", "bn", "interpret"))
def bitslice_planes(
    w: jax.Array,
    inv_scale: jax.Array | float,
    cols: int,
    *,
    bk: int = 256,
    bn: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused quantize + slice: f32[K, N] -> int8[cols, K, N] signed planes."""
    if w.ndim != 2:
        raise ValueError("bitslice_planes expects a 2-D weight")
    k, n = w.shape
    interp = default_interpret(interpret)
    bk_ = min(bk, round_up(k, 8))
    bn_ = min(bn, round_up(n, 128))
    wp = pad_axis_to(pad_axis_to(w, 0, round_up(k, bk_)), 1, round_up(n, bn_))
    out = bitslice_kernel(
        wp, jnp.asarray(inv_scale, jnp.float32), cols=cols, bk=bk_, bn=bn_, interpret=interp
    )
    return out[:, :k, :n]
