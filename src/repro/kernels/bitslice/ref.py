"""Pure-jnp oracle for fused quantize + bit-plane extraction.

Contract (shared with kernel.py / ops.py):
  w:         f32 [K, N] weights
  inv_scale: f32 scalar, 1 / quantization scale
  cols:      bitwidth

  q      = clip(round(|w| * inv_scale), 0, 2**cols - 1)
  out[b] = ((q >> b) & 1) * sign(w)     (int8 [cols, K, N]; plane 0 = LSB)

This produces exactly the ``splanes`` operand of the CIM matmul kernel for
sign_magnitude encoding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitslice_planes(w: jax.Array, inv_scale: jax.Array, cols: int) -> jax.Array:
    levels = 2**cols - 1
    q = jnp.clip(jnp.round(jnp.abs(w.astype(jnp.float32)) * inv_scale), 0, levels)
    q = q.astype(jnp.int32)
    sign = jnp.where(w < 0, -1, 1).astype(jnp.int32)
    shifts = jnp.arange(cols, dtype=jnp.int32).reshape(cols, *([1] * w.ndim))
    planes = (q[None] >> shifts) & 1
    return (planes * sign[None]).astype(jnp.int8)
