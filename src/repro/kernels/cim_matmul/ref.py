"""Pure-jnp oracle for the bit-sliced CIM matmul.

Contract (shared with kernel.py / ops.py):
  x:       f32/bf16 [M, K] activations
  splanes: int8 [cols, K, N] signed bit planes, plane 0 = LSB; values in
           {-1, 0, +1} (sign folded into the plane for sign_magnitude, all
           non-negative for offset_binary)
  scale:   f32 scalar dequantization scale

  y[m, n] = scale * sum_b 2**b * sum_k x[m, k] * splanes[b, k, n]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cim_matmul(x: jax.Array, splanes: jax.Array, scale: jax.Array) -> jax.Array:
    cols = splanes.shape[0]
    pow2 = (2.0 ** jnp.arange(cols, dtype=jnp.float32))
    y = jnp.einsum(
        "mk,bkn,b->mn",
        x.astype(jnp.float32),
        splanes.astype(jnp.float32),
        pow2,
        precision=jax.lax.Precision.HIGHEST,
    )
    return y * scale
