"""Pure-jnp oracle for the bit-sliced CIM matmul.

Contract (shared with kernel.py / ops.py):
  x:       f32/bf16 [M, K] activations
  splanes: int8 [cols, K, N] signed bit planes, plane 0 = LSB; values in
           {-1, 0, +1} (sign folded into the plane for sign_magnitude, all
           non-negative for offset_binary)
  scale:   f32 scalar dequantization scale

  y[m, n] = scale * sum_b 2**b * sum_k x[m, k] * splanes[b, k, n]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cim_matmul(x: jax.Array, splanes: jax.Array, scale: jax.Array) -> jax.Array:
    cols = splanes.shape[0]
    pow2 = (2.0 ** jnp.arange(cols, dtype=jnp.float32))
    y = jnp.einsum(
        "mk,bkn,b->mn",
        x.astype(jnp.float32),
        splanes.astype(jnp.float32),
        pow2,
        precision=jax.lax.Precision.HIGHEST,
    )
    return y * scale


def unpack_weights(
    planes_packed: jax.Array,
    sign_packed: jax.Array,
    k: int,
    plane_gain: jax.Array | None = None,
    plane_ids: jax.Array | None = None,
) -> jax.Array:
    """Packed serving operands -> dense unscaled weights f32[..., K, N].

    planes_packed: uint8[..., cols, ceil(K/8), N], plane 0 = LSB, K packed
    MSB-first per byte (``bitslice.pack_linear_planes``); sign_packed:
    uint8[..., ceil(K/8), N] with bit 1 = negative.  Returns sign * magnitude,
    i.e. ``w_hat / scale``.

    ``plane_gain`` f32[..., cols, N] models per-bit-line conductance drift
    (``core.nonideal``): each bit plane's power-of-two weight is multiplied
    by its gain before summation, exactly what a drifted analog column
    contributes.  ``None`` keeps the exact power-of-two sum.

    ``plane_ids`` int32[..., cols] is the ``col_perm`` serving codec
    (``core.planes.encode_operands``): stored plane ``p`` holds logical
    plane ``plane_ids[..., p]``, so its weight is ``2**plane_ids[..., p]``
    instead of ``2**p``.  Powers of two are exact in f32, so the permuted
    sum is bit-identical to the raw-layout sum.  Composes with
    ``plane_gain``: drift attaches to the *stored* bit line, decode to the
    logical significance — the hardware order of operations.
    """
    cols = planes_packed.shape[-3]
    bits = jnp.unpackbits(planes_packed, axis=-2, count=k)  # [..., cols, K, N]
    if plane_ids is None:
        pow2 = (2.0 ** jnp.arange(cols, dtype=jnp.float32))
        per_plane = pow2 if plane_gain is None else pow2[:, None] * plane_gain
    else:
        pow2 = 2.0 ** plane_ids.astype(jnp.float32)  # [..., cols]
        per_plane = pow2[..., None] if plane_gain is None else pow2[..., None] * plane_gain
    if plane_gain is None and plane_ids is None:
        mag = jnp.einsum("...bkn,b->...kn", bits.astype(jnp.float32), per_plane)
    else:
        mag = jnp.einsum(
            "...bkn,...bn->...kn",
            bits.astype(jnp.float32),
            jnp.broadcast_to(per_plane, bits.shape[:-3] + (cols, bits.shape[-1])),
        )
    sgn = 1.0 - 2.0 * jnp.unpackbits(sign_packed, axis=-2, count=k).astype(jnp.float32)
    return mag * sgn


def cim_matmul_packed(
    x: jax.Array,
    planes_packed: jax.Array,
    sign_packed: jax.Array,
    scale: jax.Array,
    plane_gain: jax.Array | None = None,
    plane_ids: jax.Array | None = None,
) -> jax.Array:
    """Bit-packed oracle / portable fast path: y = scale * (x @ unpack(planes)).

    Also the CPU/GPU serving fallback (see simulator.cim_linear's dispatch
    policy): the unpack is a handful of byte ops and the matmul is a single
    dense dot, so XLA compiles this far faster than an interpreted Pallas
    grid or the ``cols``-matmul einsum of the int8-plane oracle.
    """
    k = x.shape[-1]
    w = unpack_weights(planes_packed, sign_packed, k, plane_gain, plane_ids)
    return (x.astype(jnp.float32) @ w) * scale
