"""Public jit'd wrappers for the CIM matmul kernels (pad, dispatch, scale)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._util import cdiv, default_interpret, pad_axis_to, round_up
from repro.kernels.cim_matmul.kernel import (
    cim_matmul_kernel,
    cim_matmul_packed_kernel,
    cim_matmul_packed_skip_kernel,
)


def _block(requested: int, dim: int, unit: int) -> int:
    """Clamp a requested block size to the problem while keeping it a multiple
    of the hardware ``unit``.

    The naive ``min(b, round_up(dim, unit))`` can return a non-multiple of
    ``unit`` when the caller's ``b`` isn't one (and the padded dim, a multiple
    of the *block*, is then not tile-aligned) — degenerate decode shapes
    (M = 1..8) hit exactly this.  Rounding the clamp itself keeps every padded
    axis a multiple of both the block and the unit; the kernel entries assert
    the invariant.
    """
    return round_up(min(requested, round_up(dim, unit)), unit)


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk", "interpret"))
def cim_matmul(
    x: jax.Array,
    splanes: jax.Array,
    scale: jax.Array | float = 1.0,
    *,
    mode: str = "fused_dequant",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """y = scale * sum_b 2^b * (x @ splanes[b]) — see ref.py for the contract.

    Accepts arbitrary (M, K, N); pads to MXU-aligned block multiples and
    slices the result back.  ``interpret=None`` auto-selects: compiled on
    TPU, interpreted elsewhere (this container).
    """
    m, k = x.shape
    cols, k2, n = splanes.shape
    if k != k2:
        raise ValueError(f"K mismatch: x has {k}, splanes has {k2}")
    interp = default_interpret(interpret)

    bm_ = _block(bm, m, 8)
    bn_ = _block(bn, n, 128)
    bk_ = _block(bk, k, 128)
    xp = pad_axis_to(pad_axis_to(x, 0, round_up(m, bm_)), 1, round_up(k, bk_))
    pp = pad_axis_to(pad_axis_to(splanes, 1, round_up(k, bk_)), 2, round_up(n, bn_))

    y = cim_matmul_kernel(xp, pp, bm=bm_, bn=bn_, bk=bk_, mode=mode, interpret=interp)
    return y[:m, :n] * jnp.asarray(scale, dtype=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("bn", "bk", "m_chunk", "interpret")
)
def cim_matmul_packed(
    x: jax.Array,
    planes_packed: jax.Array,
    sign_packed: jax.Array,
    scale: jax.Array | float = 1.0,
    *,
    bn: int = 128,
    bk: int = 128,
    m_chunk: int = 256,
    interpret: bool | None = None,
    tile_nz: jax.Array | None = None,
) -> jax.Array:
    """Bit-packed serving matmul: y = scale * (x @ unpack(planes, signs)).

    Operand contract (``bitslice.pack_linear_planes`` / ``pack_linear_sign``):
    planes_packed uint8[cols, ceil(K/8), N] with plane 0 = LSB and K packed
    MSB-first per byte; sign_packed uint8[ceil(K/8), N] with bit 1 = negative.
    Each stored bit cell costs one bit of HBM traffic — (cols+1)/8 bytes per
    weight vs ``cols`` bytes for the int8-plane operand.

    Arbitrary (M, K, N), K need not divide 8.  M is processed in chunks of
    ``m_chunk`` rows so the whole-M-resident kernel grid stays inside VMEM;
    within a chunk the weight tile is unpacked once per (N, K) block, never
    per M block.

    ``tile_nz`` (uint8[cols, ceil(ceil(K/8)/16)] — the const_rle serving
    codec's zero-tile flags, ``core.planes.encode_operands``) routes to the
    skip-kernel twin: tiles flagged all-zero skip their unpack+accumulate
    entirely.  Bit-exact with the flag-less path.  The 16-byte flag tile is
    exactly one bk=128 K block; if a caller overrides ``bk`` to anything
    else the flag granularity no longer matches the grid and the flags are
    ignored (correct either way — flags are an optimization, not semantics).
    """
    m, k = x.shape
    cols, kw, n = planes_packed.shape
    if kw != cdiv(k, 8):
        raise ValueError(f"planes K bytes {kw} != ceil({k}/8)")
    if sign_packed.shape != (kw, n):
        raise ValueError(f"sign shape {sign_packed.shape} != {(kw, n)}")
    interp = default_interpret(interpret)

    bn_ = _block(bn, n, 128)
    bk_ = _block(bk, k, 128)  # multiple of 128, hence of 8
    kp = round_up(k, bk_)
    xp = pad_axis_to(x, 1, kp)
    pp = pad_axis_to(pad_axis_to(planes_packed, 1, kp // 8), 2, round_up(n, bn_))
    sp = pad_axis_to(pad_axis_to(sign_packed, 0, kp // 8), 1, round_up(n, bn_))

    n_k = kp // bk_
    nz = None
    if tile_nz is not None and bk_ == 128 and tile_nz.shape == (cols, n_k):
        nz = tile_nz.astype(jnp.int32).reshape(-1)

    outs = []
    for m0 in range(0, max(m, 1), m_chunk):
        chunk = xp[m0 : m0 + m_chunk]
        mp = round_up(chunk.shape[0], 8)
        xc = pad_axis_to(chunk, 0, mp)
        if nz is not None:
            yc = cim_matmul_packed_skip_kernel(
                xc, pp, sp, nz, bn=bn_, bk=bk_, interpret=interp
            )
        else:
            yc = cim_matmul_packed_kernel(xc, pp, sp, bn=bn_, bk=bk_, interpret=interp)
        outs.append(yc[: chunk.shape[0]])
    y = jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]
    return y[:m, :n] * jnp.asarray(scale, dtype=jnp.float32)
