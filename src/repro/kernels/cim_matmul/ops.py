"""Public jit'd wrapper for the CIM matmul kernel (pads, dispatches, scales)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._util import default_interpret, pad_axis_to, round_up
from repro.kernels.cim_matmul.kernel import cim_matmul_kernel


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk", "interpret"))
def cim_matmul(
    x: jax.Array,
    splanes: jax.Array,
    scale: jax.Array | float = 1.0,
    *,
    mode: str = "fused_dequant",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """y = scale * sum_b 2^b * (x @ splanes[b]) — see ref.py for the contract.

    Accepts arbitrary (M, K, N); pads to MXU-aligned block multiples and
    slices the result back.  ``interpret=None`` auto-selects: compiled on
    TPU, interpreted elsewhere (this container).
    """
    m, k = x.shape
    cols, k2, n = splanes.shape
    if k != k2:
        raise ValueError(f"K mismatch: x has {k}, splanes has {k2}")
    interp = default_interpret(interpret)

    bm_ = min(bm, round_up(m, 8))
    bn_ = min(bn, round_up(n, 128))
    bk_ = min(bk, round_up(k, 128))
    xp = pad_axis_to(pad_axis_to(x, 0, round_up(m, bm_)), 1, round_up(k, bk_))
    pp = pad_axis_to(pad_axis_to(splanes, 1, round_up(k, bk_)), 2, round_up(n, bn_))

    y = cim_matmul_kernel(xp, pp, bm=bm_, bn=bn_, bk=bk_, mode=mode, interpret=interp)
    return y[:m, :n] * jnp.asarray(scale, dtype=jnp.float32)
