"""Pallas TPU kernel for the bit-sliced CIM matmul.

TPU co-design (DESIGN.md §2): a naive bit-sliced matmul issues one matmul
per bit column and re-reads the activation tile ``cols`` times from HBM.
This kernel keeps the activation tile resident in VMEM across all planes and
offers two execution modes:

  * ``fused_dequant`` (default, TPU-optimal): reconstruct the weight tile in
    VMEM with a VPU weighted-sum over planes (w = sum_b 2^b * P_b), then one
    MXU matmul per (bm, bn, bk) tile.  MXU work equals a dense matmul; the
    bit-plane storage cost is paid only in HBM->VMEM bytes.
  * ``planes`` (faithful crossbar dataflow): one MXU matmul per plane with
    power-of-two scaling on the partial sums — mirrors how the analog array
    accumulates per-column dot products, useful for studying per-column
    error injection at matmul time.

Grid: (M/bm, N/bn, K/bk), K innermost so the f32 accumulator tile lives in a
VMEM scratch across the K loop.  Block shapes default to MXU-aligned
(128, 128) with bk=128; splanes blocks are (cols, bk, bn).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._util import cdiv


def _kernel(x_ref, p_ref, o_ref, acc_ref, *, cols: int, n_k: int, mode: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    if mode == "fused_dequant":
        # VPU: reconstruct the quantized weight tile, then a single MXU dot.
        w = jnp.zeros(p_ref.shape[1:], dtype=jnp.float32)  # (bk, bn)
        for b in range(cols):
            w = w + (2.0**b) * p_ref[b, :, :].astype(jnp.float32)
        acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)
    elif mode == "planes":
        # Faithful per-column accumulation: one MXU dot per bit plane.
        partial = jnp.zeros(acc_ref.shape, dtype=jnp.float32)
        for b in range(cols):
            plane = p_ref[b, :, :].astype(jnp.float32)
            partial += (2.0**b) * jax.lax.dot(x, plane, preferred_element_type=jnp.float32)
        acc_ref[...] += partial
    else:
        raise ValueError(f"unknown mode {mode!r}")

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "mode", "interpret")
)
def cim_matmul_kernel(
    x: jax.Array,
    splanes: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    mode: str = "fused_dequant",
    interpret: bool = False,
) -> jax.Array:
    """Raw kernel entry: shapes must already be padded to block multiples.

    x: f32[M, K]; splanes: int8[cols, K, N] -> f32[M, N] (unscaled).
    """
    m, k = x.shape
    cols, k2, n = splanes.shape
    assert k == k2, (k, k2)
    n_k = cdiv(k, bk)
    grid = (cdiv(m, bm), cdiv(n, bn), n_k)

    return pl.pallas_call(
        functools.partial(_kernel, cols=cols, n_k=n_k, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((cols, bk, bn), lambda i, j, kk: (0, kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, splanes)
