"""Pallas TPU kernels for the bit-sliced CIM matmul.

TPU co-design (DESIGN.md §2): a naive bit-sliced matmul issues one matmul
per bit column and re-reads the activation tile ``cols`` times from HBM.
These kernels keep the activation tile resident in VMEM across all planes
and offer three execution modes:

  * ``fused_dequant`` (int8 planes, parity oracle): reconstruct the weight
    tile in VMEM with a VPU weighted-sum over planes (w = sum_b 2^b * P_b),
    then one MXU matmul per (bm, bn, bk) tile.  MXU work equals a dense
    matmul; the bit-plane storage cost is paid only in HBM->VMEM bytes.
  * ``planes`` (int8 planes, faithful crossbar dataflow): one MXU matmul per
    plane with power-of-two scaling on the partial sums — mirrors how the
    analog array accumulates per-column dot products, useful for studying
    per-column error injection at matmul time.
  * **packed** (``cim_matmul_packed_kernel``, the serving hot path): the
    weight operand arrives bit-packed — ``uint8[cols, K/8, N]`` planes plus a
    ``uint8[K/8, N]`` sign-bit mask — so each stored bit cell costs exactly
    one bit of HBM traffic ((cols+1)/8 bytes per weight vs ``cols`` bytes for
    the int8-plane operand, an ~8x reduction).  Bits are unpacked in VMEM
    with shift/mask on the VPU, signs applied digitally, then one MXU dot.

Int8-plane grid: (M/bm, N/bn, K/bk), K innermost so the f32 accumulator tile
lives in a VMEM scratch across the K loop.  Packed grid: (N/bn, K/bk) with
the *whole* (padded) M resident in VMEM — decode-time M is tiny (batch x 1),
and hoisting the M axis out of the grid means each weight tile is unpacked
exactly once per (j, kk), never redone per M block (the ops wrapper chunks
very large M at the JAX level instead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._util import cdiv


def _kernel(x_ref, p_ref, o_ref, acc_ref, *, cols: int, n_k: int, mode: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    if mode == "fused_dequant":
        # VPU: reconstruct the quantized weight tile, then a single MXU dot.
        w = jnp.zeros(p_ref.shape[1:], dtype=jnp.float32)  # (bk, bn)
        for b in range(cols):
            w = w + (2.0**b) * p_ref[b, :, :].astype(jnp.float32)
        acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)
    elif mode == "planes":
        # Faithful per-column accumulation: one MXU dot per bit plane.
        partial = jnp.zeros(acc_ref.shape, dtype=jnp.float32)
        for b in range(cols):
            plane = p_ref[b, :, :].astype(jnp.float32)
            partial += (2.0**b) * jax.lax.dot(x, plane, preferred_element_type=jnp.float32)
        acc_ref[...] += partial
    else:
        raise ValueError(f"unknown mode {mode!r}")

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "mode", "interpret")
)
def cim_matmul_kernel(
    x: jax.Array,
    splanes: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    mode: str = "fused_dequant",
    interpret: bool = False,
) -> jax.Array:
    """Raw kernel entry: shapes must already be padded to block multiples.

    x: f32[M, K]; splanes: int8[cols, K, N] -> f32[M, N] (unscaled).
    """
    m, k = x.shape
    cols, k2, n = splanes.shape
    assert k == k2, (k, k2)
    # block multiples are a hard precondition: a ragged tail block would read
    # out of bounds in interpret mode and miscompile on Mosaic
    assert m % bm == 0, f"M={m} not a multiple of bm={bm}"
    assert n % bn == 0, f"N={n} not a multiple of bn={bn}"
    assert k % bk == 0, f"K={k} not a multiple of bk={bk}"
    n_k = cdiv(k, bk)
    grid = (cdiv(m, bm), cdiv(n, bn), n_k)

    return pl.pallas_call(
        functools.partial(_kernel, cols=cols, n_k=n_k, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((cols, bk, bn), lambda i, j, kk: (0, kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, splanes)


# ---------------------------------------------------------------------------
# Packed-plane mode (serving hot path)
# ---------------------------------------------------------------------------

def _unpack_bits(bytes_2d: jax.Array, bk: int, bn: int) -> jax.Array:
    """uint8/int32[bk/8, bn] byte block -> int32[bk, bn] bits in {0, 1}.

    Row ``r`` of the output is bit ``7 - (r % 8)`` of byte ``r // 8`` — the
    MSB-first convention of ``jnp.packbits`` / ``bitslice.pack_linear_planes``.
    Written with repeat + broadcasted_iota (no sublane reshape) so it lowers
    on both Mosaic and the interpreter.
    """
    rep = jnp.repeat(bytes_2d.astype(jnp.int32), 8, axis=0)  # (bk, bn)
    shifts = 7 - jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0) % 8
    return (rep >> shifts) & 1


def _packed_kernel(x_ref, p_ref, s_ref, o_ref, acc_ref, *, cols: int, n_k: int):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bk8, bn = s_ref.shape
    bk = bk8 * 8
    # VPU: unpack the bit planes into a magnitude tile, apply signs digitally.
    # This runs once per (j, kk) — the M axis lives inside the single MXU dot
    # below, so reconstruction is never redone per M block.
    w = jnp.zeros((bk, bn), dtype=jnp.float32)
    for b in range(cols):
        w = w + (2.0**b) * _unpack_bits(p_ref[b, :, :], bk, bn).astype(jnp.float32)
    sgn = 1.0 - 2.0 * _unpack_bits(s_ref[...], bk, bn).astype(jnp.float32)
    w = w * sgn
    x = x_ref[...].astype(jnp.float32)  # (M, bk)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _packed_skip_kernel(
    nz_ref, x_ref, p_ref, s_ref, o_ref, acc_ref, w_ref, *, cols: int, n_k: int
):
    """Packed kernel twin with zero-tile skipping (const_rle serving codec).

    ``nz_ref`` (SMEM, scalar-prefetched) holds one flag per (plane, K-block)
    tile, flattened row-major to int32[cols * n_k]; a 0 flag means every byte
    of that plane's K-block is zero across all N, so its unpack+accumulate is
    skipped.  Bit-exact with ``_packed_kernel``: a skipped tile contributes
    exact zeros to the magnitude tile.  The reconstruction accumulates in a
    VMEM scratch (``w_ref``) because ``pl.when`` bodies mutate refs, not
    loop-carried values.
    """
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bk8, bn = s_ref.shape
    bk = bk8 * 8
    w_ref[...] = jnp.zeros_like(w_ref)
    for b in range(cols):
        @pl.when(nz_ref[b * n_k + kk] != 0)
        def _acc(b=b):
            w_ref[...] += (2.0**b) * _unpack_bits(p_ref[b, :, :], bk, bn).astype(
                jnp.float32
            )
    sgn = 1.0 - 2.0 * _unpack_bits(s_ref[...], bk, bn).astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)  # (M, bk)
    acc_ref[...] += jax.lax.dot(x, w_ref[...] * sgn, preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def cim_matmul_packed_skip_kernel(
    x: jax.Array,
    planes_packed: jax.Array,
    sign_packed: jax.Array,
    tile_nz: jax.Array,
    *,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Raw packed entry with zero-tile skip flags (same contract as
    :func:`cim_matmul_packed_kernel`, plus ``tile_nz`` int32[cols * K/bk]
    flattened row-major from uint8[cols, K/bk] — see
    ``core.planes.encode_operands``).  Flags ride the scalar-prefetch lane
    (SMEM), so the skip predicates are known before each grid step runs."""
    m, k = x.shape
    cols, kw, n = planes_packed.shape
    assert bk % 8 == 0, f"bk={bk} must be a multiple of 8 (packed K bytes)"
    assert kw * 8 == k, f"planes K/8={kw} inconsistent with x K={k}"
    assert sign_packed.shape == (kw, n), (sign_packed.shape, (kw, n))
    assert m % 8 == 0, f"M={m} not a multiple of 8"
    assert n % bn == 0, f"N={n} not a multiple of bn={bn}"
    assert k % bk == 0, f"K={k} not a multiple of bk={bk}"
    n_k = cdiv(k, bk)
    assert tile_nz.shape == (cols * n_k,), (tile_nz.shape, cols, n_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(cdiv(n, bn), n_k),
        in_specs=[
            pl.BlockSpec((m, bk), lambda j, kk, nz: (0, kk)),
            pl.BlockSpec((cols, bk // 8, bn), lambda j, kk, nz: (0, kk, j)),
            pl.BlockSpec((bk // 8, bn), lambda j, kk, nz: (kk, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j, kk, nz: (0, j)),
        scratch_shapes=[
            pltpu.VMEM((m, bn), jnp.float32),
            pltpu.VMEM((bk, bn), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_packed_skip_kernel, cols=cols, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(tile_nz.astype(jnp.int32), x, planes_packed, sign_packed)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def cim_matmul_packed_kernel(
    x: jax.Array,
    planes_packed: jax.Array,
    sign_packed: jax.Array,
    *,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Raw packed-mode entry: shapes must already be padded to block multiples.

    x: f32[M, K]; planes_packed: uint8[cols, K/8, N] (plane 0 = LSB, K packed
    MSB-first per byte); sign_packed: uint8[K/8, N] (bit 1 = negative).
    Returns f32[M, N] (unscaled).  Grid is (N/bn, K/bk) with all of M
    resident in VMEM — callers chunk M before invoking (see ops.py).
    """
    m, k = x.shape
    cols, kw, n = planes_packed.shape
    assert bk % 8 == 0, f"bk={bk} must be a multiple of 8 (packed K bytes)"
    assert kw * 8 == k, f"planes K/8={kw} inconsistent with x K={k}"
    assert sign_packed.shape == (kw, n), (sign_packed.shape, (kw, n))
    assert m % 8 == 0, f"M={m} not a multiple of 8"
    assert n % bn == 0, f"N={n} not a multiple of bn={bn}"
    assert k % bk == 0, f"K={k} not a multiple of bk={bk}"
    n_k = cdiv(k, bk)
    grid = (cdiv(n, bn), n_k)

    return pl.pallas_call(
        functools.partial(_packed_kernel, cols=cols, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, bk), lambda j, kk: (0, kk)),
            pl.BlockSpec((cols, bk // 8, bn), lambda j, kk: (0, kk, j)),
            pl.BlockSpec((bk // 8, bn), lambda j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m, bn), jnp.float32)],
        interpret=interpret,
    )(x, planes_packed, sign_packed)
