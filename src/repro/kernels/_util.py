"""Shared helpers for Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pad_axis_to(x: jax.Array, axis: int, size: int) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to ``size`` (no-op if already there)."""
    cur = x.shape[axis]
    if cur == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, size - cur)
    return jnp.pad(x, pads)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret(interpret: bool | None) -> bool:
    """Kernels run compiled on TPU, interpreted (Python) elsewhere."""
    return (not on_tpu()) if interpret is None else interpret


def popcount_i32(x: jax.Array) -> jax.Array:
    """SWAR popcount for int32 holding byte values in [0, 255].

    Written with shifts/masks only so it lowers on both Mosaic (TPU) and the
    interpreter — ``lax.population_count`` support varies by backend/dtype.
    """
    x = x - ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x + (x >> 4)) & 0x0F0F0F0F
    return x & 0xFF
