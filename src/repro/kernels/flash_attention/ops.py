"""Public jit'd wrapper for the flash-attention kernel (pads, dispatches)."""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.kernels._util import default_interpret, pad_axis_to, round_up
from repro.kernels.flash_attention.kernel import flash_attention_kernel


@functools.partial(
    jax.jit, static_argnames=("kind", "window", "bq", "bk", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_valid_len: Optional[jax.Array] = None,
    *,
    kind: str = "causal",
    window: Optional[int] = None,
    q_offset: Union[int, jax.Array] = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """See ref.py for the contract.  Arbitrary Sq/Sk; pads + slices back.

    ``q_offset``: absolute position of q[0] — a scalar shared by the batch,
    or a (B,) vector of *traced per-row* offsets (ragged fused dispatches:
    every row of the batch sits at its own prompt position).
    ``kv_valid_len``: optional traced scalar or (B,) per-row vector — key
    positions >= it are masked without recompiling (per-slot cache-view
    tails in engine prefill/fused dispatches).  Both land in SMEM, so one
    compiled kernel serves every per-row combination.
    """
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    interp = default_interpret(interpret)
    bq_ = min(bq, round_up(sq, 8))
    bk_ = min(bk, round_up(sk, 8))
    qp = pad_axis_to(q, 2, round_up(sq, bq_))
    kp = pad_axis_to(k, 2, round_up(sk, bk_))
    vp = pad_axis_to(v, 2, round_up(sk, bk_))
    qoff = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))
    kvl = jnp.broadcast_to(
        jnp.asarray(sk if kv_valid_len is None else kv_valid_len, jnp.int32), (b,)
    )
    out = flash_attention_kernel(
        qp, kp, vp, qoff, kvl,
        kind=kind, window=window,
        bq=bq_, bk=bk_, sk_valid=sk, interpret=interp,
    )
    return out[:, :, :sq]
