"""Public jit'd wrapper for the flash-attention kernel (pads, dispatches)."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels._util import default_interpret, pad_axis_to, round_up
from repro.kernels.flash_attention.kernel import flash_attention_kernel


@functools.partial(
    jax.jit, static_argnames=("kind", "window", "q_offset", "bq", "bk", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_valid_len: Optional[jax.Array] = None,
    *,
    kind: str = "causal",
    window: Optional[int] = None,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """See ref.py for the contract.  Arbitrary Sq/Sk; pads + slices back.

    ``kv_valid_len``: optional traced scalar — key positions >= it are
    masked without recompiling (paged cache-view tail in engine prefill).
    """
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    interp = default_interpret(interpret)
    bq_ = min(bq, round_up(sq, 8))
    bk_ = min(bk, round_up(sk, 8))
    qp = pad_axis_to(q, 2, round_up(sq, bq_))
    kp = pad_axis_to(k, 2, round_up(sk, bk_))
    vp = pad_axis_to(v, 2, round_up(sk, bk_))
    out = flash_attention_kernel(
        qp, kp, vp, kv_valid_len,
        kind=kind, window=window, q_offset=q_offset,
        bq=bq_, bk=bk_, sk_valid=sk, interpret=interp,
    )
    return out[:, :, :sq]
