"""Pallas TPU flash-attention kernel (forward).

IO-aware attention for the 32k-prefill cells: the (Sq, Sk) score matrix is
never materialized in HBM.  Grid is (B, Hq, Sq/bq, Sk/bk) with the key axis
innermost; the online-softmax statistics (m, l) and the output accumulator
live in VMEM scratch across the k loop, so each q tile is read once and
each k/v tile is read once per q tile.

GQA without KV expansion: the k/v BlockSpec index_map divides the query
head index by the group size, so KV HBM traffic stays at the GQA-reduced
size (the reason GQA helps the memory roofline term at 32k).

Causal/SWA tiles that are fully masked are skipped with ``pl.when`` on the
*block* indices — the compile-time analogue of FlashAttention's block
skipping, worth ~2x on causal prefill (half the tiles are dead).

Ragged serving support: ``q_offsets`` and ``kv_valid_len`` are *traced
per-row* scalars living in SMEM, indexed by the batch grid axis — one
compiled kernel serves every mix of per-request prompt positions and cache
valid lengths (the fused prefill+decode dispatch batches rows at different
absolute positions with different live-cache extents).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._util import cdiv

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, qoff_ref, kvl_ref, o_ref, m_ref, l_ref, acc_ref,
    *, kind: str, window: Optional[int], bq: int, bk: int,
    n_k: int, sk_valid: int, scale: float,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # per-row traced scalars (SMEM, indexed by the batch grid axis):
    # absolute position of this row's q[0], and its live cache extent
    q_lo = qoff_ref[0, 0] + iq * bq  # absolute position of this q tile's 1st row
    k_lo = ik * bk
    kvl = kvl_ref[0, 0]

    def body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        # static padding tail AND the traced per-row valid length (paged
        # serving: the gathered cache view's tail holds stale pool bytes)
        mask = jnp.logical_and(k_pos < sk_valid, k_pos < kvl)
        if kind != "bidir":
            mask = jnp.logical_and(mask, k_pos <= q_pos)
            if kind == "swa":
                mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if kind == "bidir":
        body()
    else:
        # causal block skip: tile is dead iff its first key position exceeds
        # the last query position (and for SWA, iff it is entirely behind the
        # window of the last query row).
        live = k_lo <= q_lo + bq - 1
        # tiles entirely past the traced valid length are dead too (the cache
        # view's unwritten tail in paged serving)
        live = jnp.logical_and(live, k_lo < kvl)
        if kind == "swa":
            live = jnp.logical_and(live, k_lo + bk - 1 > q_lo - window)
        pl.when(live)(body)

    @pl.when(ik == n_k - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "window", "bq", "bk", "sk_valid", "interpret"),
)
def flash_attention_kernel(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_offsets: jax.Array,
    kv_valid_len: jax.Array,
    *,
    kind: str = "causal",
    window: Optional[int] = None,
    bq: int = 128,
    bk: int = 128,
    sk_valid: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Raw kernel entry: Sq % bq == 0 and Sk % bk == 0 required.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D] -> [B, Hq, Sq, D].
    ``q_offsets``: (B,) i32 traced per-row absolute position of each row's
    q[0] — rows of a ragged dispatch sit at their own prompt positions.
    ``kv_valid_len``: (B,) i32 traced per-row live cache extents — key
    positions >= a row's extent are masked without recompiling (continuous-
    batching rows attend to a fixed-shape view whose valid length differs
    per slot and grows per chunk).  ``sk_valid`` masks the *static* padding
    tail.  Callers wanting the historical scalar behaviour broadcast one
    value (ops.py does).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    assert hq == hkv * g, (hq, hkv)
    n_q, n_k = cdiv(sq, bq), cdiv(sk, bk)
    sk_valid = sk if sk_valid is None else sk_valid
    qoff = jnp.reshape(jnp.asarray(q_offsets, jnp.int32), (b, 1))
    kvl = jnp.reshape(jnp.asarray(kv_valid_len, jnp.int32), (b, 1))
    grid = (b, hq, n_q, n_k)

    kern = functools.partial(
        _kernel,
        kind=kind, window=window,
        bq=bq, bk=bk, n_k=n_k, sk_valid=sk_valid, scale=d**-0.5,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec(
                (1, 1), lambda ib, ih, iq, ik: (ib, 0), memory_space=pltpu.SMEM
            ),
            pl.BlockSpec(
                (1, 1), lambda ib, ih, iq, ik: (ib, 0), memory_space=pltpu.SMEM
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, qoff, kvl)
