"""Pure-jnp oracle for flash attention.

Contract (shared with kernel.py / ops.py):
  q: f32/bf16 [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D] with Hq % Hkv == 0
  kind: "causal" | "bidir" | "swa" (causal sliding window of `window`)
  q_offset: absolute position of q[0] (continuation chunks / decode);
    scalar shared by the batch, or (B,) per-row (ragged fused dispatches)
  kv_valid_len: optional scalar or (B,) per-row — key positions >= it are
    masked (live cache extent of each slot's view)

  out[b,h,i] = sum_j softmax_j(q_i . k_j / sqrt(D) + mask) v_j
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_valid_len: Optional[jax.Array] = None,
    *,
    kind: str = "causal",
    window: Optional[int] = None,
    q_offset: Union[int, jax.Array] = 0,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    scores = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * (d**-0.5)
    # (B, Sq, Sk) masks when offsets/extents are per-row; (Sq, Sk) otherwise
    off = jnp.asarray(q_offset)
    qp = (off[:, None, None] + jnp.arange(sq)[None, :, None]) if off.ndim else (
        off + jnp.arange(sq)[:, None]
    )
    kp = jnp.arange(sk)
    if kind == "bidir":
        mask = jnp.ones_like(qp + kp, dtype=jnp.bool_)
    else:
        mask = kp <= qp
        if kind == "swa":
            assert window is not None
            mask = jnp.logical_and(mask, kp > qp - window)
    if kv_valid_len is not None:
        vl = jnp.asarray(kv_valid_len)
        vl = vl[:, None, None] if vl.ndim else vl
        mask = jnp.logical_and(mask, kp < vl)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)
