"""Deterministic, shardable synthetic token pipeline.

Production framing: each host materializes only its shard of the global
batch, keyed purely by ``(seed, step, host_index)`` — a restarted or
elastically re-joined host reproduces exactly the tokens it would have seen,
which is what makes checkpoint/restart and elastic scaling bit-exact
(DESIGN.md §5, tested in tests/test_runtime.py).

Two task families:

* ``lm``   — Zipf-distributed token stream with a planted Markov structure,
  so a trained LM has signal to learn (loss drops measurably in a few
  hundred steps — used by examples/train_lm.py and the accuracy-preservation
  benchmark).
* ``copy`` — deterministic copy task (predict token t-1), the fastest
  "does the training loop learn at all" probe for integration tests.

No external data: the brief's environment has no ImageNet/corpora, so the
pipeline *is* the data substrate (DESIGN.md §2 assumption changes).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    task: str = "lm"  # "lm" | "copy"
    seed: int = 0
    zipf_a: float = 1.2
    markov_order: int = 1
    n_states: int = 64  # planted structure size


class SyntheticLMDataset:
    """Stateless batch generator: ``batch_at(step, host, n_hosts)``."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch < 1:
            raise ValueError("global_batch must be >= 1")
        self.cfg = cfg
        # Planted Markov transition table (host-independent, derived from
        # seed only): state s -> a band of likely next tokens.
        rng = np.random.default_rng(cfg.seed)
        self._trans = rng.integers(
            0, cfg.vocab_size, size=(cfg.n_states, 8), dtype=np.int64
        )

    # -- helpers -------------------------------------------------------------

    def host_batch(self, n_hosts: int) -> int:
        if self.cfg.global_batch % n_hosts != 0:
            raise ValueError(
                f"global_batch {self.cfg.global_batch} not divisible by {n_hosts} hosts"
            )
        return self.cfg.global_batch // n_hosts

    def _fold(self, step: int, host: int) -> jax.Array:
        key = jax.random.PRNGKey(self.cfg.seed)
        key = jax.random.fold_in(key, step)
        return jax.random.fold_in(key, host)

    # -- batch materialization -------------------------------------------------

    def batch_at(self, step: int, host: int = 0, n_hosts: int = 1) -> dict[str, jax.Array]:
        """Materialize this host's shard of the global batch for ``step``."""
        cfg = self.cfg
        b = self.host_batch(n_hosts)
        key = self._fold(step, host)
        if cfg.task == "copy":
            # deterministic next-token rule t_{i+1} = (5 t_i + 7) mod V: any
            # model that can learn a vocab-sized lookup drives loss to ~0 —
            # the fastest "does the training loop learn" probe.
            k1, _ = jax.random.split(key)
            first = jax.random.randint(k1, (b,), 0, cfg.vocab_size, jnp.int32)

            def nxt(t, _):
                t = (5 * t + 7) % cfg.vocab_size
                return t, t

            _, rest = jax.lax.scan(nxt, first, None, length=cfg.seq_len - 1)
            tokens = jnp.concatenate([first[:, None], rest.T], axis=1)
            return {"tokens": tokens}
        if cfg.task != "lm":
            raise ValueError(f"unknown task {cfg.task!r}")
        k1, k2, k3 = jax.random.split(key, 3)
        # Zipf backbone via inverse-CDF on uniform samples
        u = jax.random.uniform(k1, (b, cfg.seq_len), minval=1e-6, maxval=1.0)
        ranks = jnp.clip(
            (u ** (-1.0 / (self.cfg.zipf_a - 1.0))).astype(jnp.int32) - 1,
            0,
            cfg.vocab_size - 1,
        )
        # Plant Markov structure: with prob 0.5 the next token comes from the
        # transition band of the current token's state.
        state = ranks % self.cfg.n_states
        trans = jnp.asarray(self._trans)
        band_pick = jax.random.randint(k2, (b, cfg.seq_len), 0, trans.shape[1])
        markov_next = trans[state, band_pick].astype(jnp.int32)
        use_markov = jax.random.bernoulli(k3, 0.5, (b, cfg.seq_len))
        shifted = jnp.concatenate([markov_next[:, -1:], markov_next[:, :-1]], axis=1)
        tokens = jnp.where(use_markov, shifted, ranks)
        return {"tokens": tokens % cfg.vocab_size}

    def batches(self, n_steps: int, host: int = 0, n_hosts: int = 1):
        for step in range(n_steps):
            yield self.batch_at(step, host, n_hosts)


def make_dataset(cfg: DataConfig) -> SyntheticLMDataset:
    return SyntheticLMDataset(cfg)
