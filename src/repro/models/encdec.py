"""Encoder-decoder LM (SeamlessM4T-style backbone, audio frontend stubbed).

The encoder consumes precomputed frame embeddings (B, S_src, d_model) — the
modality frontend stub mandated by the brief — through bidirectional
attention blocks.  The decoder is a causal LM with per-layer cross-attention
into the encoder output.  Decode caches hold both the self-attention KV and
the *precomputed* cross-attention KV (encoder K/V projected once at prefill,
then reused every step — the standard production serving layout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.attention import blockwise_attention, decode_attention
from repro.models.blocks import _qkv, attention_step, init_attention, init_attn_block, attn_block_fwd
from repro.models.layers import Params


# ---------------------------------------------------------------------------
# Decoder block: causal self-attn + cross-attn + MLP
# ---------------------------------------------------------------------------

def init_dec_block(key, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.init_norm(cfg.d_model),
        "self": init_attention(k1, cfg),
        "ln_x": layers.init_norm(cfg.d_model),
        "cross": init_attention(k2, cfg),
        "ln2": layers.init_norm(cfg.d_model),
        "mlp": layers.init_glu_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def _cross_kv(p: Params, cfg: ArchConfig, enc_out: jax.Array):
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    dtype = enc_out.dtype
    k = layers.linear(p["wk"], enc_out, dtype).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = layers.linear(p["wv"], enc_out, dtype).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return k, v


def _cross_attend(p: Params, cfg: ArchConfig, x: jax.Array, k, v):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    dtype = x.dtype
    q = layers.linear(p["wq"], x, dtype).reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    out = blockwise_attention(q, k, v, kind="bidir")
    return layers.linear(p["wo"], out.transpose(0, 2, 1, 3).reshape(b, s, -1), dtype)


def dec_block_fwd(
    p: Params, cfg: ArchConfig, x, enc_out, *, q_offset=0, return_cache=False
):
    a, cache = _self_attn_fwd(p, cfg, x, q_offset=q_offset, return_cache=return_cache)
    x = x + a
    ck, cv = _cross_kv(p["cross"], cfg, enc_out)
    x = x + _cross_attend(p["cross"], cfg, layers.rmsnorm(p["ln_x"], x), ck, cv)
    x = x + layers.glu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], x), cfg.act, x.dtype)
    if return_cache:
        cache = {"self": cache, "cross_k": ck, "cross_v": cv}
    return x, cache


def _self_attn_fwd(p: Params, cfg: ArchConfig, x, *, q_offset, return_cache):
    from repro.models.blocks import attention_fwd

    return attention_fwd(
        p["self"], cfg, layers.rmsnorm(p["ln1"], x),
        q_offset=q_offset, kind="causal", return_cache=return_cache,
    )


def dec_block_step(p: Params, cfg: ArchConfig, x, cache, pos):
    a, self_cache = attention_step(
        p["self"], cfg, layers.rmsnorm(p["ln1"], x), cache["self"], pos
    )
    x = x + a
    xq = layers.rmsnorm(p["ln_x"], x)
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q = layers.linear(p["cross"]["wq"], xq, x.dtype).reshape(b, 1, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    out = decode_attention(q, cache["cross_k"], cache["cross_v"], cache["cross_k"].shape[2])
    x = x + layers.linear(p["cross"]["wo"], out.transpose(0, 2, 1, 3).reshape(b, 1, -1), x.dtype)
    x = x + layers.glu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], x), cfg.act, x.dtype)
    return x, {"self": self_cache, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}


# ---------------------------------------------------------------------------
# Model assembly
# ---------------------------------------------------------------------------

def init(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "src_proj": layers.init_dense(ks[2], cfg.d_model, cfg.d_model),
        "embed": layers.init_embedding(ks[3], cfg.vocab_size, cfg.d_model),
        "encoder": jax.vmap(lambda k: init_attn_block(k, cfg))(enc_keys),
        "enc_norm": layers.init_norm(cfg.d_model),
        "decoder": jax.vmap(lambda k: init_dec_block(k, cfg))(dec_keys),
        "final_norm": layers.init_norm(cfg.d_model),
        "head": layers.init_lm_head(ks[4], cfg.d_model, cfg.vocab_size),
    }


def encode(params: Params, cfg: ArchConfig, src_embeds: jax.Array) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    x = layers.dense(params["src_proj"], src_embeds.astype(dtype), dtype)

    def body(xc, p_layer):
        xc, _ = attn_block_fwd(p_layer, cfg, xc, kind="bidir", return_cache=False)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layers.rmsnorm(params["enc_norm"], x)


def forward(
    params: Params, cfg: ArchConfig, batch: dict, *, remat: str = "none"
) -> tuple[jax.Array, jax.Array]:
    """batch: {"src_embeds": (B, Ss, d), "tokens": (B, St)} -> (logits, aux)."""
    dtype = jnp.dtype(cfg.dtype)
    enc_out = encode(params, cfg, batch["src_embeds"])
    x = layers.embed(params["embed"], batch["tokens"], dtype)

    def layer(p_layer, xc):
        xc, _ = dec_block_fwd(p_layer, cfg, xc, enc_out, return_cache=False)
        return xc

    if remat != "none":
        from repro.models.transformer import _REMAT_POLICIES

        layer = jax.checkpoint(layer, policy=_REMAT_POLICIES[remat]())

    def body(xc, p_layer):
        return layer(p_layer, xc), None

    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = layers.rmsnorm(params["final_norm"], x)
    return layers.lm_head(params["head"], x), jnp.zeros((), jnp.float32)


def prefill(params: Params, cfg: ArchConfig, batch: dict):
    dtype = jnp.dtype(cfg.dtype)
    enc_out = encode(params, cfg, batch["src_embeds"])
    x = layers.embed(params["embed"], batch["tokens"], dtype)

    def body(xc, p_layer):
        xc, cache = dec_block_fwd(p_layer, cfg, xc, enc_out, return_cache=True)
        return xc, cache

    x, caches = jax.lax.scan(body, x, params["decoder"])
    x = layers.rmsnorm(params["final_norm"], x[:, -1:])
    return layers.lm_head(params["head"], x), caches


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, src_len: int, dtype=None):
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    hd = cfg.resolved_head_dim
    l = cfg.n_layers
    kv = (l, batch, cfg.n_kv_heads, seq_len, hd)
    xkv = (l, batch, cfg.n_kv_heads, src_len, hd)
    return {
        "self": {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)},
        "cross_k": jnp.zeros(xkv, dtype),
        "cross_v": jnp.zeros(xkv, dtype),
    }


def decode_step(params: Params, cfg: ArchConfig, caches, token, pos):
    dtype = jnp.dtype(cfg.dtype)
    x = layers.embed(params["embed"], token, dtype)

    def body(xc, pc):
        p_layer, c_layer = pc
        xc, c_new = dec_block_step(p_layer, cfg, xc, c_layer, pos)
        return xc, c_new

    x, caches = jax.lax.scan(body, x, (params["decoder"], caches))
    x = layers.rmsnorm(params["final_norm"], x)
    return layers.lm_head(params["head"], x), caches
