"""Uniform model API over decoder-only and encoder-decoder families."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, transformer


def init(key, cfg: ArchConfig):
    return encdec.init(key, cfg) if cfg.encdec else transformer.init(key, cfg)


def forward(params, cfg: ArchConfig, batch: dict, *, remat: str = "none"):
    if cfg.encdec:
        return encdec.forward(params, cfg, batch, remat=remat)
    return transformer.forward(params, cfg, batch, remat=remat)


def prefill(params, cfg: ArchConfig, batch: dict):
    return encdec.prefill(params, cfg, batch) if cfg.encdec else transformer.prefill(params, cfg, batch)


def decode_step(params, cfg: ArchConfig, cache, token, pos):
    if cfg.encdec:
        return encdec.decode_step(params, cfg, cache, token, pos)
    return transformer.decode_step(params, cfg, cache, token, pos)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype=None, *, src_len: int | None = None):
    if cfg.encdec:
        return encdec.init_cache(cfg, batch, seq_len, src_len or seq_len, dtype)
    # meta tokens occupy cache slots before real positions
    return transformer.init_cache(cfg, batch, seq_len + cfg.n_meta_tokens, dtype)


# --- paged KV serving (continuous-batching engine) -------------------------

def supports_paged(cfg: ArchConfig) -> bool:
    """True iff the arch can serve through the paged-KV engine."""
    return not cfg.encdec and transformer.supports_paged(cfg)


def init_paged_pools(cfg: ArchConfig, num_tokens: int, dtype=None):
    """Token-major physical KV pools (``num_tokens`` = num_blocks * page)."""
    if cfg.encdec:
        raise NotImplementedError("paged KV serving: decoder-only models")
    return transformer.init_paged_pools(cfg, num_tokens, dtype)


def paged_view(cfg: ArchConfig, pools, table, page_size: int):
    """Contiguous per-slot cache views gathered from the paged pools."""
    return transformer.paged_view(cfg, pools, table, page_size)


def paged_writeback(cfg: ArchConfig, pools, caches, table, pos0, n_tokens: int, page_size: int):
    """Scatter a dispatch's newly written cache cells back into the pools."""
    return transformer.paged_writeback(cfg, pools, caches, table, pos0, n_tokens, page_size)


def decode_step_paged(params, cfg: ArchConfig, pools, table, token, pos, page_size):
    """Ragged decode: one token per slot at per-slot positions ``pos`` (B,)."""
    return transformer.decode_step_paged(params, cfg, pools, table, token, pos, page_size)


def prefill_chunk(params, cfg: ArchConfig, pools, table, tokens, start, kv_len, last_idx, page_size):
    """One prompt-chunk dispatch (B requests wide) through the paged pools."""
    return transformer.prefill_chunk(
        params, cfg, pools, table, tokens, start, kv_len, last_idx, page_size
    )


def chunk_on_views(params, cfg: ArchConfig, caches, tokens, start, kv_len, last_idx):
    """Chunk step against gathered cache views (fused dispatch): the caller
    owns the ``paged_view`` gather and the ``paged_writeback`` scatter."""
    return transformer.chunk_on_views(
        params, cfg, caches, tokens, start, kv_len, last_idx
    )


def merge_prefill_cache(cfg: ArchConfig, full_cache, pf_cache):
    """Write prefill caches (prompt length) into a zero full-length cache.

    Both are pytrees with layer-stacked leaves; KV-style leaves differ only
    in the sequence axis (prefill writes positions [0, prompt)), state-style
    leaves (SSM/ring-buffer) match exactly and are replaced wholesale.
    """

    def merge_leaf(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        axes = [i for i, (a, b) in enumerate(zip(dst.shape, src.shape)) if a != b]
        assert len(axes) == 1, (dst.shape, src.shape)
        return jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), (0,) * dst.ndim
        )

    return jax.tree.map(merge_leaf, full_cache, pf_cache)


def make_batch(cfg: ArchConfig, key, batch: int, seq_len: int) -> dict[str, Any]:
    """Random concrete batch (smoke tests / examples)."""
    kt, kp = jax.random.split(key)
    out: dict[str, Any] = {
        "tokens": jax.random.randint(kt, (batch, seq_len), 0, cfg.vocab_size, jnp.int32)
    }
    if cfg.encdec:
        out["src_embeds"] = jax.random.normal(kp, (batch, seq_len, cfg.d_model), jnp.float32)
    elif cfg.stub_prefix_len:
        out["prefix_embeds"] = jax.random.normal(
            kp, (batch, cfg.stub_prefix_len, cfg.d_model), jnp.float32
        )
    return out


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(params, cfg: ArchConfig) -> int:
    """Active params per token (MoE: shared + top_k routed experts only)."""
    total = param_count(params)
    if cfg.moe is None:
        return total
    # subtract the non-active share of routed expert weights
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    routed = 0
    for path, leaf in flat:
        names = [str(getattr(k, "key", "")) for k in path]
        if any(n in ("wi_gate", "wi_up", "wo") for n in names) and leaf.ndim == 3:
            routed += int(leaf.size)
    active_frac = cfg.moe.top_k / cfg.moe.n_alloc
    return total - routed + int(routed * active_frac)
