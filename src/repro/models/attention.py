"""Memory-bounded attention for training, prefill, and decode.

``blockwise_attention`` is a pure-JAX flash-style attention: an online
softmax over key/value blocks carried through ``lax.scan``, so the (Sq, Sk)
score matrix is never materialized — peak memory is O(Sq * block_k) per
head.  This is the framework's default attention everywhere (a 32k prefill
with materialized scores would need terabytes; see DESIGN.md §5).  GQA/MQA
is handled by *grouping queries* (B, Hkv, G, Sq, D) rather than repeating
KV, so KV bytes stay at the GQA-reduced size.

The Pallas flash-attention kernel (repro.kernels.flash_attention) implements
the same contract for TPU; this module is the XLA-compilable path used by
the dry-run (Mosaic kernels cannot lower on the CPU dry-run backend).

Mask kinds: "causal", "bidir", "swa" (sliding window, causal).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# Attention implementation switches (perf variants; see EXPERIMENTS.md §Perf).
# Mutated via set_attention_impl() BEFORE tracing — they select which HLO is
# lowered, exactly like a compile-time config in a production stack.
_IMPL = {"swa_banded": False, "swa_block_q": 512}


def set_attention_impl(*, swa_banded: bool | None = None, swa_block_q: int | None = None):
    if swa_banded is not None:
        _IMPL["swa_banded"] = swa_banded
    if swa_block_q is not None:
        _IMPL["swa_block_q"] = swa_block_q


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    kind: str = "causal",
    window: Optional[int] = None,
    q_offset: int = 0,
    block_k: int = 1024,
) -> jax.Array:
    """Implementation-dispatching attention entry point used by all blocks."""
    if kind == "swa" and _IMPL["swa_banded"] and isinstance(q_offset, int):
        return banded_swa_attention(
            q, k, v, window=window, q_offset=q_offset, block_q=_IMPL["swa_block_q"]
        )
    return blockwise_attention(
        q, k, v, kind=kind, window=window, q_offset=q_offset, block_k=block_k
    )


def _block_mask(
    q_pos: jax.Array, k_pos: jax.Array, kind: str, window: Optional[int]
) -> jax.Array:
    """(..., Sq, bk) boolean visibility mask from absolute positions;
    ``q_pos`` is (Sq,) or (B, Sq) for per-row offsets."""
    qp = q_pos[..., None]
    kp = k_pos
    if kind == "bidir":
        return jnp.ones(q_pos.shape + (k_pos.shape[0],), dtype=jnp.bool_)
    mask = kp <= qp
    if kind == "swa":
        assert window is not None
        mask = jnp.logical_and(mask, kp > qp - window)
    return mask


@functools.partial(jax.jit, static_argnames=("window", "q_offset", "block_q"))
def banded_swa_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    q_offset: int = 0,
    block_q: int = 512,
) -> jax.Array:
    """Sliding-window attention that only computes the live band.

    The full blockwise path scores every (q, k) pair and masks — quadratic
    FLOPs even though SWA only reads a ``window``-wide band.  Here q is
    processed in blocks of ``block_q``; each block attends to a static-shape
    band of ``window + block_q`` keys fetched by dynamic_slice, so FLOPs and
    bytes are O(S * (window + block_q)) instead of O(S^2) — the §Perf lever
    that linearizes Hymba's 29 SWA layers at 32k prefill.

    Same contract as ``blockwise_attention(kind="swa")``: k/v hold positions
    [0, Sk); q holds positions [q_offset, q_offset + Sq).  ``q_offset`` must
    be a static int.  q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale = d**-0.5
    band = window + block_q

    nq = -(-sq // block_q)
    q_pad = nq * block_q - sq
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    # pad keys left by `window` (so the first band exists) and right so the
    # last band's slice is in-bounds: last start = q_offset + (nq-1)*block_q
    pad_r = max(0, q_offset + nq * block_q - sk)
    kp = jnp.pad(k, ((0, 0), (0, 0), (window, pad_r), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (window, pad_r), (0, 0)))
    qg = q.reshape(b, hkv, g, nq * block_q, d)

    def one_block(i):
        q_lo = i * block_q
        qb = jax.lax.dynamic_slice_in_dim(qg, q_lo, block_q, axis=3)
        # first needed key position: q_offset + q_lo - window + 1; slice one
        # earlier for simplicity -> padded-coords start = q_offset + q_lo
        kb = jax.lax.dynamic_slice_in_dim(kp, q_offset + q_lo, band, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vp, q_offset + q_lo, band, axis=2)
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qb.astype(jnp.float32), kb.astype(jnp.float32)
        ) * scale
        q_pos = q_offset + q_lo + jnp.arange(block_q)[:, None]
        k_pos = q_offset + q_lo - window + jnp.arange(band)[None, :]
        mask = (k_pos <= q_pos) & (k_pos > q_pos - window) & (k_pos >= 0) & (k_pos < sk)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        # softmax stays f32; the PV matmul runs with bf16 probabilities
        # (p <= 1, standard flash-kernel practice) — halves the p round-trip,
        # the banded path's largest remaining HBM term (§Perf cell-3 iter 2).
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vb, preferred_element_type=jnp.float32
        )

    blocks = jax.lax.map(one_block, jnp.arange(nq))  # (nq, B, Hkv, G, bq, Dv)
    out = jnp.moveaxis(blocks, 0, 3).reshape(b, hkv, g, nq * block_q, dv)
    return out[:, :, :, :sq].reshape(b, hq, sq, dv).astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("kind", "window", "block_k", "skip_masked_blocks")
)
def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    kind: str = "causal",
    window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    block_k: int = 1024,
    skip_masked_blocks: bool = False,
    kv_valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Online-softmax attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0.
    q_offset: absolute position of q[0] (prefill continuation / decode);
      scalar, or a (B,) vector of per-row offsets (batched ragged prefill
      chunks — every row of the batch sits at its own prompt position, as
      in the engine's fused prefill+decode dispatches).
    kv_valid_len: optional scalar or (B,) vector — positions >= it are
      masked (cache tail / per-slot valid lengths).  The Pallas flash
      kernel (repro.kernels.flash_attention) implements the same per-row
      contract with both values traced in SMEM.
    skip_masked_blocks: when True, fully-masked key blocks contribute via a
      zero multiplier (their matmuls still run under scan; the *compile-time
      skip* variant is a hillclimb lever — see EXPERIMENTS.md §Perf).

    Returns (B, Hq, Sq, D) in q.dtype.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]  # v head dim may differ from qk head dim (MLA)
    g = hq // hkv
    assert hq == hkv * g, (hq, hkv)
    scale = d**-0.5

    nk = -(-sk // block_k)
    pad = nk * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qg = q.reshape(b, hkv, g, sq, d)
    # (Sq,) shared positions, or (B, Sq) per-row; masks broadcast over the
    # batch axis either way (the scalar path is bit-identical to before)
    off = jnp.asarray(q_offset)
    q_pos = (off[..., None] + jnp.arange(sq)) if off.ndim else off + jnp.arange(sq)
    vl = None if kv_valid_len is None else jnp.reshape(jnp.asarray(kv_valid_len), (-1, 1))

    def step(carry, kj):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, kj * block_k, block_k, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(v, kj * block_k, block_k, axis=2)
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), kb.astype(jnp.float32)
        ) * scale
        k_pos = kj * block_k + jnp.arange(block_k)
        mask = _block_mask(q_pos, k_pos, kind, window)  # (Sq, bk) or (B, Sq, bk)
        valid = k_pos < sk if not pad else k_pos < (sk)
        if vl is not None:
            valid = jnp.logical_and(valid, k_pos[None, :] < vl)  # (1|B, bk)
        mask = jnp.logical_and(mask, valid[..., None, :])
        if mask.ndim == 2:
            mask = mask[None]
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), jnp.arange(nk))

    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    valid_len: jax.Array,
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """Single-step attention against a (possibly partially filled) KV cache.

    q: (B, Hq, 1, D); caches: (B, Hkv, S, D); valid_len: scalar int — number
    of valid cache positions (the new token's KV must already be written) —
    or a (B,) vector of per-row lengths (ragged continuous-batching decode:
    every slot sits at its own position in its own sequence).
    """
    b, hq, _, d = q.shape
    _, hkv, s, _ = k_cache.shape
    g = hq // hkv
    qg = q.reshape(b, hkv, g, 1, d)
    scale = d**-0.5
    scores = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    pos = jnp.arange(s)
    # scalar valid_len -> (1, S) mask shared by the batch (bit-identical to
    # the historical path); vector -> (B, S) per-slot mask
    vl = jnp.reshape(jnp.asarray(valid_len), (-1, 1))
    mask = pos[None, :] < vl
    if window is not None:
        mask = jnp.logical_and(mask, pos[None, :] >= vl - window)
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, 1, d).astype(q.dtype)
