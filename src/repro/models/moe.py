"""Mixture-of-Experts blocks (Qwen2-MoE / DeepSeek-V2 style).

Shared experts (always active) are fused into a single dense GLU of width
``n_shared * d_expert``.  Routed experts use drop-on-overflow capacity
dispatch via a *sorted scatter* rather than a (tokens, experts, capacity)
one-hot — the dispatch buffer is (E, C, d) with C = ceil(cf * T * k / E),
which is what makes 160-expert models tractable and shards naturally:
EP when E divides the model axis, TP on d_expert otherwise (DESIGN.md §5).

Aux outputs: the standard switch-style load-balance loss, accumulated by the
layer-stack scan carry.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.blocks import attention_fwd, attention_step
from repro.models.layers import Params

# Distributed-dispatch switch (perf variant; EXPERIMENTS.md §Perf).  When a
# mesh is registered, moe_mlp routes through a shard_map in which dispatch is
# shard-LOCAL: tokens stay on their (pod, data) shard, every model shard
# dispatches only to the experts (EP) or expert-ffn slices (TP) it owns, and
# one psum over the model axis combines — so the only collective is an
# all-reduce of (T_local, d) activations instead of the GSPMD-inferred
# gather/scatter traffic around the data-dependent dispatch scatter.
_DIST: dict = {"mesh": None, "data_axes": (), "model_axis": "model"}


def set_moe_distribution(mesh=None, *, model_axis: str = "model") -> None:
    """Register (or clear, with mesh=None) the mesh for sharded dispatch."""
    if mesh is None:
        _DIST.update(mesh=None, data_axes=())
        return
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    _DIST.update(mesh=mesh, data_axes=data_axes, model_axis=model_axis)


def init_moe_mlp(key, cfg: ArchConfig) -> Params:
    m = cfg.moe
    assert m is not None
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e, d, de = m.n_routed, cfg.d_model, m.d_expert
    ea = m.n_alloc  # >= e; rows [e, ea) are never routed to (see MoEConfig)
    std = 1.0 / (d**0.5)
    p: Params = {
        "router": layers._dense_init(k1, d, e),
        "wi_gate": jax.random.truncated_normal(k2, -3, 3, (ea, d, de), jnp.float32) * std,
        "wi_up": jax.random.truncated_normal(k3, -3, 3, (ea, d, de), jnp.float32) * std,
        "wo": jax.random.truncated_normal(k4, -3, 3, (ea, de, d), jnp.float32) * (1.0 / de**0.5),
    }
    if m.n_shared > 0:
        p["shared"] = layers.init_glu_mlp(k5, d, m.n_shared * de)
    return p


def _route(p: Params, m, xf: jax.Array, e: int):
    """Router: -> (topw (T,k) f32, topi (T,k) i32, aux scalar)."""
    logits = layers.linear(p["router"], xf.astype(jnp.float32), jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, m.top_k)  # (T, k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)
    # load-balance aux (Switch-style)
    me = jnp.mean(probs, axis=0)  # (E,)
    one_hot = jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)
    aux = e * jnp.sum(me * ce) * m.router_aux_weight
    return topw, topi, aux


def _assignment_ranks(flat_e: jax.Array, e: int) -> jax.Array:
    """Rank of each assignment within its expert (stable arrival order)."""
    n = flat_e.shape[0]
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    first = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=sorted_e.dtype), side="left")
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - first[sorted_e].astype(jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[sort_idx].set(pos_sorted)


def _ffn_combine(
    p: Params, cfg: ArchConfig, xf, topw, slot, keep, *, n_buf: int, cap: int
):
    """Scatter -> grouped expert GLUs -> gather-combine.  slot in [0, n_buf*cap]."""
    m = cfg.moe
    dtype = xf.dtype
    t, d = xf.shape
    k = m.top_k
    # gather-based dispatch: invert slot -> source assignment, then gather
    # token rows.  Equivalent to scattering token replicas, but (a) never
    # materializes the (T*k, d) replica tensor and (b) its transpose
    # scatter-adds straight into d_xf (T, d) — under the sharded dispatch the
    # model-axis psum then carries a k-fold smaller cotangent (§Perf iter 3).
    n_assign = t * k
    src = jnp.full((n_buf * cap + 1,), n_assign, jnp.int32).at[slot].min(
        jnp.arange(n_assign, dtype=jnp.int32), mode="drop"
    )[: n_buf * cap]
    valid = src < n_assign
    tok = jnp.minimum(src // k, t - 1)
    buf = (xf[tok] * valid[:, None].astype(dtype)).reshape(n_buf, cap, d)

    # batched per-expert matmuls; layers.linear batches dense weights via the
    # ``@`` broadcasting rule and vmaps crossbar operand dicts over the
    # leading expert axis
    gate = layers.linear(p["wi_gate"], buf, dtype)
    up = layers.linear(p["wi_up"], buf, dtype)
    h = jax.nn.silu(gate) * up
    out = layers.linear(p["wo"], h, dtype)

    flat_o = jnp.concatenate([out.reshape(n_buf * cap, d), jnp.zeros((1, d), dtype)])
    y_tk = flat_o[slot] * (keep.astype(dtype) * topw.reshape(-1).astype(dtype))[:, None]
    return jnp.sum(y_tk.reshape(t, k, d), axis=1)


def moe_mlp(p: Params, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss)."""
    if _DIST["mesh"] is not None:
        return _moe_mlp_sharded(p, cfg, x)
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e = m.n_routed
    xf = x.reshape(t, d)

    topw, topi, aux = _route(p, m, xf, e)

    cap = max(8, int(m.capacity_factor * t * m.top_k / e + 0.999))
    flat_e = topi.reshape(-1)  # (T*k,)
    pos = _assignment_ranks(flat_e, e)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, m.n_alloc * cap)  # overflow -> trash

    y = _ffn_combine(p, cfg, xf, topw, slot, keep, n_buf=m.n_alloc, cap=cap)
    if "shared" in p:
        y = y + layers.glu_mlp(p["shared"], xf, cfg.act, x.dtype)
    return y.reshape(b, s, d), aux


def _moe_mlp_sharded(p: Params, cfg: ArchConfig, x: jax.Array):
    """shard_map dispatch: local routing, owned-expert FFNs, one model psum.

    Tokens are sharded over (pod, data) and replicated over model; expert
    weights are sharded over model (expert-parallel when E divides the axis,
    expert-ffn TP otherwise).  Every model shard computes the contribution of
    the experts/slices it owns for all of its local tokens; a single psum
    over the model axis completes both layouts (EP contributions are
    disjoint, TP contributions are partial sums).  Capacity is per data
    shard (GShard-style per-group capacity).
    """
    mesh = _DIST["mesh"]
    dax = _DIST["data_axes"]
    mx = _DIST["model_axis"]
    m = cfg.moe
    e = m.n_routed
    ea = m.n_alloc
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape))[mx]
    ep = ea % n_model == 0
    b, s, d = x.shape

    if ep:
        w_spec = {"wi_gate": P(mx, None, None), "wi_up": P(mx, None, None),
                  "wo": P(mx, None, None)}
    else:
        w_spec = {"wi_gate": P(None, None, mx), "wi_up": P(None, None, mx),
                  "wo": P(None, mx, None)}
    p_specs: dict = {"router": P(None, None), **w_spec}
    if "shared" in p:
        p_specs["shared"] = {"wi_gate": P(None, mx), "wi_up": P(None, mx),
                             "wo": P(mx, None)}
    x_spec = P(dax, None, None) if dax else P(None, None, None)
    out_specs = (x_spec, P())

    def local_fn(p_l, x_l):
        bl, sl, _ = x_l.shape
        t = bl * sl
        xf = x_l.reshape(t, d)
        topw, topi, aux = _route(p_l, m, xf, e)
        if dax:
            aux = jax.lax.pmean(aux, dax)

        cap = max(8, int(m.capacity_factor * t * m.top_k / e + 0.999))
        flat_e = topi.reshape(-1)
        pos = _assignment_ranks(flat_e, e)
        keep = pos < cap
        if ep:
            e_local = ea // n_model
            lo = jax.lax.axis_index(mx).astype(jnp.int32) * e_local
            keep = keep & (flat_e >= lo) & (flat_e < lo + e_local)
            slot = jnp.where(keep, (flat_e - lo) * cap + pos, e_local * cap)
            n_buf = e_local
        else:
            slot = jnp.where(keep, flat_e * cap + pos, ea * cap)
            n_buf = ea

        y = _ffn_combine(p_l, cfg, xf, topw, slot, keep, n_buf=n_buf, cap=cap)
        if "shared" in p_l:
            y = y + layers.glu_mlp(p_l["shared"], xf, cfg.act, x_l.dtype)
        y = jax.lax.psum(y, mx)
        return y.reshape(bl, sl, d), aux

    sharded = jax.shard_map(
        local_fn, mesh=mesh, in_specs=(p_specs, x_spec), out_specs=out_specs
    )
    return sharded({k_: p[k_] for k_ in p_specs}, x)


# ---------------------------------------------------------------------------
# MoE block: attention + MoE MLP
# ---------------------------------------------------------------------------

def init_moe_block(key, cfg: ArchConfig) -> Params:
    from repro.models.blocks import init_attention

    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.init_norm(cfg.d_model),
        "attn": init_attention(k1, cfg),
        "ln2": layers.init_norm(cfg.d_model),
        "moe": init_moe_mlp(k2, cfg),
    }


def moe_block_fwd(
    p: Params, cfg: ArchConfig, x, *, q_offset=0, kind="causal", window=None,
    return_cache=False, layer_flag=None,
):
    a, cache = attention_fwd(
        p["attn"], cfg, layers.rmsnorm(p["ln1"], x),
        q_offset=q_offset, kind=kind, window=window, return_cache=return_cache,
    )
    x = x + a
    y, aux = moe_mlp(p["moe"], cfg, layers.rmsnorm(p["ln2"], x))
    return x + y, cache, aux


def moe_block_step(p: Params, cfg: ArchConfig, x, cache, pos, *, window=None, layer_flag=None, **_):
    a, cache = attention_step(p["attn"], cfg, layers.rmsnorm(p["ln1"], x), cache, pos, window=window)
    x = x + a
    y, _ = moe_mlp(p["moe"], cfg, layers.rmsnorm(p["ln2"], x))
    return x + y, cache
