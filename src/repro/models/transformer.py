"""Decoder-only LM assembly over heterogeneous block patterns.

``cfg.layer_kinds()`` expands the arch's block pattern to one kind per
layer; consecutive identical kinds form *segments*, and each segment is
executed with ``jax.lax.scan`` over stacked parameters (compact HLO for
80-layer models).  Segment boundaries are exactly where block kind — and
therefore cache structure — changes (e.g. Hymba's 3 global-attention layers
split the 29 SWA layers into separate scans so SWA caches stay
window-bounded).

Interface (used by launch/, runtime/, examples/):
  init(key, cfg)                                  -> params
  forward(params, cfg, batch)                     -> (logits, aux_loss)
  prefill(params, cfg, batch)                     -> (logits, cache)
  decode_step(params, cfg, cache, token, pos)     -> (logits, cache)
  init_cache(cfg, batch, seq_len, dtype)          -> cache
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks, hybrid, layers, mla, moe, ssm
from repro.models.layers import Params


# ---------------------------------------------------------------------------
# Block registry: kind -> behaviour
# ---------------------------------------------------------------------------

class _Kind:
    def __init__(self, init, fwd, step, init_cache, has_aux=False, attn_kind="causal"):
        self.init = init
        self.fwd = fwd
        self.step = step
        self.init_cache = init_cache
        self.has_aux = has_aux
        self.attn_kind = attn_kind  # "causal" | "swa" | None


def _attn_cache(cfg, batch, seq_len, dtype, *, kind):
    return blocks.init_attn_cache(cfg, batch, seq_len, dtype)


KINDS: dict[str, _Kind] = {
    "attn": _Kind(blocks.init_attn_block, blocks.attn_block_fwd, blocks.attn_block_step,
                  _attn_cache),
    "swa": _Kind(blocks.init_attn_block, blocks.attn_block_fwd, blocks.attn_block_step,
                 _attn_cache, attn_kind="swa"),
    "moe": _Kind(moe.init_moe_block, moe.moe_block_fwd, moe.moe_block_step,
                 _attn_cache, has_aux=True),
    "mla_moe": _Kind(mla.init_mla_moe_block, mla.mla_moe_block_fwd, mla.mla_moe_block_step,
                     lambda cfg, b, s, dt, *, kind: mla.init_mla_cache(cfg, b, s, dt),
                     has_aux=True),
    "mlstm": _Kind(ssm.init_mlstm_block, ssm.mlstm_block_fwd, ssm.mlstm_block_step,
                   lambda cfg, b, s, dt, *, kind: ssm.init_mlstm_cache(cfg, b, dt)),
    "slstm": _Kind(ssm.init_slstm_block, ssm.slstm_block_fwd, ssm.slstm_block_step,
                   lambda cfg, b, s, dt, *, kind: ssm.init_slstm_cache(cfg, b, dt)),
    "hymba_swa": _Kind(hybrid.init_hymba_block, hybrid.hymba_block_fwd, hybrid.hymba_block_step,
                       lambda cfg, b, s, dt, *, kind: hybrid.init_hymba_cache(cfg, b, s, dt, kind=kind),
                       attn_kind="swa"),
    "hymba_global": _Kind(hybrid.init_hymba_block, hybrid.hymba_block_fwd, hybrid.hymba_block_step,
                          lambda cfg, b, s, dt, *, kind: hybrid.init_hymba_cache(cfg, b, s, dt, kind=kind),
                          attn_kind="causal"),
}


def segments_of(cfg: ArchConfig) -> list[tuple[str, int]]:
    """Group layer kinds into maximal homogeneous runs."""
    runs: list[tuple[str, int]] = []
    for kind in cfg.layer_kinds():
        if runs and runs[-1][0] == kind:
            runs[-1] = (kind, runs[-1][1] + 1)
        else:
            runs.append((kind, 1))
    return runs


def _fwd_kwargs(cfg: ArchConfig, kind: str) -> dict:
    k = KINDS[kind]
    kw: dict[str, Any] = {"kind": k.attn_kind}
    if k.attn_kind == "swa":
        kw["window"] = cfg.attn_window
    return kw


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(key, cfg: ArchConfig) -> Params:
    segs = segments_of(cfg)
    keys = jax.random.split(key, len(segs) + 3)
    params: Params = {"embed": layers.init_embedding(keys[0], cfg.vocab_size, cfg.d_model)}
    seg_params = []
    for i, (kind, count) in enumerate(segs):
        layer_keys = jax.random.split(keys[i + 1], count)
        seg_params.append(jax.vmap(lambda k: KINDS[kind].init(k, cfg))(layer_keys))
    params["segments"] = seg_params
    params["final_norm"] = layers.init_norm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = layers.init_lm_head(keys[-1], cfg.d_model, cfg.vocab_size)
    if cfg.n_meta_tokens:
        params["meta"] = jax.random.normal(
            keys[-2], (cfg.n_meta_tokens, cfg.d_model), jnp.float32
        ) * 0.02
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(params: Params, cfg: ArchConfig, batch: dict, dtype) -> jax.Array:
    x = layers.embed(params["embed"], batch["tokens"], dtype)
    if cfg.stub_prefix_len:
        # modality frontend stub: precomputed patch/frame embeddings occupy
        # the first `stub_prefix_len` positions (DESIGN.md §4).
        p = cfg.stub_prefix_len
        prefix = batch["prefix_embeds"].astype(dtype)
        x = jnp.concatenate([prefix, x[:, p:]], axis=1)
    if cfg.d_model and getattr(cfg, "embed_scale", False):
        x = x * jnp.asarray(cfg.d_model**0.5, dtype)
    if cfg.n_meta_tokens:
        b = x.shape[0]
        meta = jnp.broadcast_to(
            params["meta"].astype(dtype)[None], (b, cfg.n_meta_tokens, cfg.d_model)
        )
        x = jnp.concatenate([meta, x], axis=1)
    return x


_REMAT_POLICIES = {
    "full": lambda: jax.checkpoint_policies.nothing_saveable,
    "dots": lambda: jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def _run_segments(
    params: Params, cfg: ArchConfig, x: jax.Array, *, q_offset=0, return_cache: bool,
    remat: str = "none",
):
    aux = jnp.zeros((), jnp.float32)
    caches = []
    for (kind, _), p_stack in zip(segments_of(cfg), params["segments"]):
        spec = KINDS[kind]
        kw = _fwd_kwargs(cfg, kind)

        def layer(p_layer, xc, _spec=spec, _kw=kw):
            return _spec.fwd(p_layer, cfg, xc, q_offset=q_offset, return_cache=return_cache, **_kw)

        if remat != "none":
            # per-layer remat inside the scan body: activation memory becomes
            # O(n_layers * saved) instead of O(n_layers * all intermediates)
            layer = jax.checkpoint(layer, policy=_REMAT_POLICIES[remat]())

        def body(carry, p_layer, _spec=spec, _layer=layer):
            xc, auxc = carry
            out = _layer(p_layer, xc)
            if _spec.has_aux:
                xc, cache, aux_l = out
                auxc = auxc + aux_l
            else:
                xc, cache = out
            return (xc, auxc), cache

        (x, aux), seg_cache = jax.lax.scan(body, (x, aux), p_stack)
        caches.append(seg_cache)
    return x, aux, caches if return_cache else None


def _logits(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = layers.rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        return layers.unembed(params["embed"], x)
    return layers.lm_head(params["head"], x)


def forward(
    params: Params, cfg: ArchConfig, batch: dict, *, remat: str = "none"
) -> tuple[jax.Array, jax.Array]:
    """batch: {"tokens": (B, S) int32, ["prefix_embeds": (B, P, d)]}.

    Returns (logits (B, S, V) f32, aux_loss scalar).
    """
    dtype = jnp.dtype(cfg.dtype)
    x = _embed_inputs(params, cfg, batch, dtype)
    x, aux, _ = _run_segments(params, cfg, x, return_cache=False, remat=remat)
    if cfg.n_meta_tokens:
        x = x[:, cfg.n_meta_tokens :]
    return _logits(params, cfg, x), aux


def prefill(params: Params, cfg: ArchConfig, batch: dict) -> tuple[jax.Array, list]:
    dtype = jnp.dtype(cfg.dtype)
    x = _embed_inputs(params, cfg, batch, dtype)
    x, _, caches = _run_segments(params, cfg, x, return_cache=True)
    if cfg.n_meta_tokens:
        x = x[:, cfg.n_meta_tokens :]
    return _logits(params, cfg, x[:, -1:]), caches


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype=None) -> list:
    """Zero cache for decode; seq_len includes meta tokens if any."""
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    caches = []
    for kind, count in segments_of(cfg):
        one = KINDS[kind].init_cache(cfg, batch, seq_len, dtype, kind=kind)
        caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a[None], (count, *a.shape)), one))
    return caches


# ---------------------------------------------------------------------------
# Paged decode / chunked prefill (continuous-batching engine)
# ---------------------------------------------------------------------------

def supports_paged(cfg: ArchConfig) -> bool:
    """Paged KV serving covers pure-attention decoder stacks: every layer
    kind must keep plain (B, Hkv, S, hd) KV state.  SSM/hybrid recurrent
    state is O(1) per slot and needs no paging; encdec keeps a cross cache."""
    kinds = {k for k, _ in segments_of(cfg)}
    return (
        kinds <= {"attn", "swa"}
        and not cfg.encdec
        and cfg.n_meta_tokens == 0
        and cfg.stub_prefix_len == 0
    )


def _check_paged(cfg: ArchConfig) -> None:
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged KV serving supports pure-attention decoder stacks; "
            f"{cfg.name} has kinds {[k for k, _ in segments_of(cfg)]}"
        )


def init_paged_pools(cfg: ArchConfig, num_tokens: int, dtype=None) -> list:
    """Token-major physical KV pools, one stacked pool per segment:
    k/v (count, T, Hkv, hd) with T = num_blocks * page_size."""
    _check_paged(cfg)
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    pools = []
    for kind, count in segments_of(cfg):
        one = blocks.init_attn_pool(cfg, num_tokens, dtype)
        pools.append(jax.tree.map(lambda a: jnp.broadcast_to(a[None], (count, *a.shape)), one))
    return pools


def paged_view(cfg: ArchConfig, pools: list, table: jax.Array, page_size: int) -> list:
    """Gather each slot's pages into contiguous per-slot caches — the same
    (count, B, Hkv, L, hd) layout ``init_cache`` builds, so the ordinary
    ``decode_step`` runs against it unchanged."""
    return [
        jax.tree.map(lambda a: blocks.gather_pool_view(a, table, page_size), pool)
        for pool in pools
    ]


def paged_writeback(
    cfg: ArchConfig, pools: list, caches: list, table: jax.Array,
    pos0: jax.Array, n_tokens: int, page_size: int,
) -> list:
    """Scatter the cells a dispatch wrote — view positions [pos0_r, pos0_r +
    n_tokens) per row — back into the physical pools."""
    return [
        jax.tree.map(
            lambda pa, va: blocks.scatter_pool_view(
                pa, va, table, pos0, n_tokens, page_size
            ),
            pool, cache,
        )
        for pool, cache in zip(pools, caches)
    ]


def decode_step_paged(
    params: Params,
    cfg: ArchConfig,
    pools: list,
    table: jax.Array,
    token: jax.Array,
    pos: jax.Array,
    page_size: int,
) -> tuple[jax.Array, list]:
    """token: (B, 1) i32; pos: (B,) per-slot absolute positions; table
    (B, P) block-table rows.

    Gather view -> ordinary ``decode_step`` (vector positions) -> write the
    one new cell per row back.  Row-independent everywhere, so each slot's
    logits are bit-identical to a solo contiguous-cache decode at the same
    position.  Multi-step callers (the engine's decode quantum) should call
    ``paged_view`` once, scan ``decode_step``, then ``paged_writeback`` —
    paying the gather per dispatch, not per token.

    Returns (logits (B, 1, V), new pools).
    """
    caches = paged_view(cfg, pools, table, page_size)
    logits, caches = decode_step(params, cfg, caches, token, pos)
    pools = paged_writeback(cfg, pools, caches, table, pos, 1, page_size)
    return logits, pools


def chunk_on_views(
    params: Params,
    cfg: ArchConfig,
    caches: list,
    tokens: jax.Array,
    start: jax.Array,
    kv_len: jax.Array,
    last_idx: jax.Array,
) -> tuple[jax.Array, list]:
    """Chunk continuation against contiguous cache views.

    The views-level core of :func:`prefill_chunk`, reusable by the fused
    prefill+decode dispatch (``launch.steps.make_fused_step``): the caller
    owns the ``paged_view`` gather and the ``paged_writeback`` scatter, so a
    fused dispatch can run this chunk step *and* a decode-quantum scan as
    one XLA computation.

    Args:
      caches: per-segment contiguous cache views (the ``init_cache`` layout,
        i.e. what ``paged_view`` returns).
      tokens: (B, C) int32 — row r holds chunk positions
        [start_r, start_r + C) of its own request; columns past a row's true
        extent are padding (masked by causality + ``kv_len``; the written-
        back pad cells are overwritten by the row's own future tokens before
        any masked-visible read).
      start / kv_len / last_idx: (B,) int32 (scalars also accepted) — chunk
        start position, valid cache length after the writes, and the chunk
        column whose logits each row emits.

    Returns (logits (B, 1, V) — row r's column ``last_idx_r`` — and the
    updated cache views, same layout as ``caches``).
    """
    dtype = jnp.dtype(cfg.dtype)
    x = layers.embed(params["embed"], tokens, dtype)
    if getattr(cfg, "embed_scale", False):
        x = x * jnp.asarray(cfg.d_model**0.5, dtype)

    new_caches = []
    for (kind, _), p_stack, cache_stack in zip(segments_of(cfg), params["segments"], caches):
        kw = _fwd_kwargs(cfg, kind)

        def body(x_c, pc, _kw=kw):
            p_layer, c_layer = pc
            return blocks.attn_block_chunk_step(
                p_layer, cfg, x_c, c_layer, start, kv_len, **_kw
            )

        x, seg_cache = jax.lax.scan(body, x, (p_stack, cache_stack))
        new_caches.append(seg_cache)
    x_last = jnp.take_along_axis(x, jnp.reshape(last_idx, (-1, 1, 1)), axis=1)
    return _logits(params, cfg, x_last), new_caches


def prefill_chunk(
    params: Params,
    cfg: ArchConfig,
    pools: list,
    table: jax.Array,
    tokens: jax.Array,
    start: jax.Array,
    kv_len: jax.Array,
    last_idx: jax.Array,
    page_size: int,
) -> tuple[jax.Array, list]:
    """One prompt-chunk dispatch, B requests wide: tokens (B, C), row r at
    positions [start_r, start_r + C) (columns past a row's true chunk length
    are padding — masked by causality + ``kv_len``, and written back into
    cells the row's own future tokens overwrite before any masked-visible
    read); kv_len: (B,) valid cache lengths after the writes; last_idx:
    (B,) chunk column to emit logits for (the prompt's final token on a
    row's last chunk; other rows' logits are discarded by the caller).
    start/kv_len/last_idx also accept scalars (single-request callers).

    Returns (logits (B, 1, V), new pools).
    """
    b, c = tokens.shape
    start_b = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(start)), (b,))
    caches = paged_view(cfg, pools, table, page_size)
    logits, new_caches = chunk_on_views(
        params, cfg, caches, tokens, start, kv_len, last_idx
    )
    pools = paged_writeback(cfg, pools, new_caches, table, start_b, c, page_size)
    return logits, pools


def decode_step(
    params: Params, cfg: ArchConfig, caches: list, token: jax.Array, pos: jax.Array
) -> tuple[jax.Array, list]:
    """token: (B, 1) int32; pos: scalar int32 absolute position (excl. meta).

    Returns (logits (B, 1, V), new caches).
    """
    dtype = jnp.dtype(cfg.dtype)
    x = layers.embed(params["embed"], token, dtype)
    if getattr(cfg, "embed_scale", False):
        x = x * jnp.asarray(cfg.d_model**0.5, dtype)
    pos_eff = pos + cfg.n_meta_tokens

    new_caches = []
    for (kind, _), p_stack, c_stack in zip(segments_of(cfg), params["segments"], caches):
        spec = KINDS[kind]
        kw = _fwd_kwargs(cfg, kind)
        kw.pop("window", None)  # decode windows are baked into cache length

        def body(x_c, pc, _spec=spec, _kw=kw):
            p_layer, c_layer = pc
            x_new, c_new = _spec.step(p_layer, cfg, x_c, c_layer, pos_eff, **_kw)
            return x_new, c_new

        x, seg_cache = jax.lax.scan(body, x, (p_stack, c_stack))
        new_caches.append(seg_cache)
    return _logits(params, cfg, x), new_caches
