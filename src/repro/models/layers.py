"""Shared neural-net layers (pure JAX, explicit param pytrees).

Conventions:
  * params are nested dicts of jnp arrays; init functions mirror apply
    functions: ``init_x(key, ...) -> params`` / ``x(params, inputs, ...)``.
  * activations/compute dtype comes from the caller (cfg.dtype); params are
    stored in f32 (master weights) and cast at use ("mixed precision").
  * weight init: truncated-normal fan-in scaling (matches llama-family).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def _dense_init(key, in_dim: int, out_dim: int, scale: float = 1.0) -> jax.Array:
    std = scale / (in_dim**0.5)
    return jax.random.truncated_normal(key, -3, 3, (in_dim, out_dim), jnp.float32) * std


def init_dense(key, in_dim: int, out_dim: int, scale: float = 1.0) -> Params:
    return {"w": _dense_init(key, in_dim, out_dim, scale)}


def _cim_apply(w: dict, x: jax.Array) -> jax.Array:
    """Crossbar operand dict @ activations, any rank.

    Leading operand dims beyond the canonical 3-D planes (stacked experts /
    scan-sliced layers) are vmapped against matching leading dims of ``x``;
    the remaining batch dims of ``x`` flatten into the matmul M axis.
    """
    from repro.core import simulator

    planes = w.get("planes_packed", w.get("splanes"))
    if planes.ndim > 3:
        return jax.vmap(_cim_apply)(w, x)
    lead = x.shape[:-1]
    y = simulator.cim_linear(x.reshape(-1, x.shape[-1]), w, use_kernel=True)
    return y.reshape(*lead, y.shape[-1])


def linear(w, x: jax.Array, dtype) -> jax.Array:
    """x @ w for a dense weight array or a CIM crossbar operand dict.

    THE routing point for crossbar-native serving: every model matmul whose
    weight the planner may deploy goes through here.  Dense arrays take the
    ordinary dot (bit-identical to the pre-refactor inline ``@``); operand
    dicts (``deploy_params(materialize="packed"/"planes_int8")``) run through
    ``simulator.cim_linear`` — the compiled Pallas kernel on TPU, the portable
    packed reference elsewhere.  Batched 3-D weights (MoE experts) work for
    both representations: dense via the ``@`` batching rule, operands via
    vmap over the leading dims.
    """
    if isinstance(w, dict):
        return _cim_apply(w, x).astype(dtype)
    return x @ w.astype(dtype)


def dense(p: Params, x: jax.Array, dtype) -> jax.Array:
    return linear(p["w"], x, dtype)


def init_norm(dim: int) -> Params:
    return {"g": jnp.ones((dim,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLPs
# ---------------------------------------------------------------------------

def init_glu_mlp(key, d_model: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": _dense_init(k1, d_model, d_ff),
        "wi_up": _dense_init(k2, d_model, d_ff),
        "wo": _dense_init(k3, d_ff, d_model),
    }


def glu_mlp(p: Params, x: jax.Array, act: str, dtype) -> jax.Array:
    gate = linear(p["wi_gate"], x, dtype)
    up = linear(p["wi_up"], x, dtype)
    if act == "swiglu":
        h = jax.nn.silu(gate) * up
    elif act == "geglu":
        h = jax.nn.gelu(gate, approximate=True) * up
    else:
        raise ValueError(f"unknown act {act!r}")
    return linear(p["wo"], h, dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int) -> Params:
    return {"table": jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02}


def embed(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    # logits in f32 for a stable softmax/xent regardless of compute dtype
    return (x.astype(jnp.float32)) @ p["table"].astype(jnp.float32).T


def init_lm_head(key, d_model: int, vocab: int) -> Params:
    return {"w": _dense_init(key, d_model, vocab)}


def lm_head(p: Params, x: jax.Array) -> jax.Array:
    return linear(p["w"], x.astype(jnp.float32), jnp.float32)
