"""Multi-head Latent Attention (DeepSeek-V2) with absorbed-matmul decode.

Training/prefill use the expanded form (per-head K/V up-projections).  The
decode path uses the *absorbed* form: the per-head up-projections W_UK/W_UV
are folded into the query / output sides, so the KV cache holds only the
compressed latent ``c_kv`` (kv_lora_rank) plus the shared RoPE key
(qk_rope_head_dim) — 576 f-elements per token for the 236B config instead of
128 heads x 256. This is the production DeepSeek inference dataflow and the
reason deepseek-v2's decode_32k cell is memory- rather than
collective-bound (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.attention import blockwise_attention
from repro.models.layers import Params


def init_mla(key, cfg: ArchConfig) -> Params:
    m = cfg.mla
    assert m is not None
    ks = jax.random.split(key, 6)
    h = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": layers._dense_init(ks[0], cfg.d_model, m.q_lora_rank),
        "q_norm": layers.init_norm(m.q_lora_rank),
        "wq_b": layers._dense_init(ks[1], m.q_lora_rank, h * qk_dim),
        "wkv_a": layers._dense_init(ks[2], cfg.d_model, m.kv_lora_rank + m.qk_rope_head_dim),
        "kv_norm": layers.init_norm(m.kv_lora_rank),
        "wk_b": layers._dense_init(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim),
        "wv_b": layers._dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim),
        "wo": layers._dense_init(ks[5], h * m.v_head_dim, cfg.d_model),
    }


def _project_q(p: Params, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    """-> q_nope (B,H,S,dn), q_rope (B,H,S,dr)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    dtype = x.dtype
    ql = layers.rmsnorm(p["q_norm"], layers.linear(p["wq_a"], x, dtype))
    q = layers.linear(p["wq_b"], ql, dtype).reshape(b, s, h, -1).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = layers.apply_rope(q_rope, positions[None, None, :], cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p: Params, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    """-> c_kv (B,S,r), k_rope (B,S,dr) — exactly what the decode cache holds."""
    m = cfg.mla
    dtype = x.dtype
    kv = layers.linear(p["wkv_a"], x, dtype)
    c_kv, k_rope = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank :]
    c_kv = layers.rmsnorm(p["kv_norm"], c_kv)
    k_rope = layers.apply_rope(k_rope[:, None], positions[None, None, :], cfg.rope_theta)[:, 0]
    return c_kv, k_rope


def mla_attention_fwd(
    p: Params, cfg: ArchConfig, x: jax.Array, *, q_offset: int = 0, return_cache: bool = False
):
    """Expanded-form MLA for train/prefill; cache stores the latent."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    dtype = x.dtype
    positions = q_offset + jnp.arange(s)

    q_nope, q_rope = _project_q(p, cfg, x, positions)
    c_kv, k_rope = _project_kv_latent(p, cfg, x, positions)

    # wk_b / wv_b stay dense under every materialization (planner
    # MATERIALIZE_DENSE_ONLY): the absorbed decode path below reshapes them
    # per head, which has no crossbar-operand equivalent
    k_nope = (c_kv @ p["wk_b"].astype(dtype)).reshape(b, s, h, m.qk_nope_head_dim)
    v = (c_kv @ p["wv_b"].astype(dtype)).reshape(b, s, h, m.v_head_dim)
    k_nope = k_nope.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    k_rope_h = jnp.broadcast_to(k_rope[:, None], (b, h, s, m.qk_rope_head_dim))

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    out = blockwise_attention(q, k, v, kind="causal", q_offset=q_offset)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    y = layers.linear(p["wo"], out, dtype)
    cache = {"c_kv": c_kv, "k_rope": k_rope} if return_cache else None
    return y, cache


def mla_attention_step(p: Params, cfg: ArchConfig, x: jax.Array, cache, pos):
    """Absorbed-form single-token decode against the latent cache."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    dtype = x.dtype
    positions = jnp.reshape(pos, (1,))

    q_nope, q_rope = _project_q(p, cfg, x, positions)  # (B,H,1,dn) / (B,H,1,dr)
    c_new, kr_new = _project_kv_latent(p, cfg, x, positions)  # (B,1,r) / (B,1,dr)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new, pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new, pos, axis=1)

    # absorb W_UK into q: q_eff (B,H,1,r)
    wk_b = p["wk_b"].astype(dtype).reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_eff = jnp.einsum("bhqd,rhd->bhqr", q_nope, wk_b)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (
        jnp.einsum("bhqr,bsr->bhqs", q_eff.astype(jnp.float32), c_kv.astype(jnp.float32))
        + jnp.einsum("bhqd,bsd->bhqs", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    ) * scale
    s_len = c_kv.shape[1]
    valid = jnp.arange(s_len) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)

    ctx = jnp.einsum("bhqs,bsr->bhqr", probs, c_kv.astype(jnp.float32))  # (B,H,1,r)
    wv_b = p["wv_b"].astype(dtype).reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhqr,rhd->bhqd", ctx.astype(dtype), wv_b)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, h * m.v_head_dim)
    y = layers.linear(p["wo"], out, dtype)
    return y, {"c_kv": c_kv, "k_rope": k_rope}


def init_mla_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, seq_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, seq_len, m.qk_rope_head_dim), dtype),
    }


# ---------------------------------------------------------------------------
# MLA + MoE block (the DeepSeek-V2 layer)
# ---------------------------------------------------------------------------

def init_mla_moe_block(key, cfg: ArchConfig) -> Params:
    from repro.models.moe import init_moe_mlp

    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.init_norm(cfg.d_model),
        "mla": init_mla(k1, cfg),
        "ln2": layers.init_norm(cfg.d_model),
        "moe": init_moe_mlp(k2, cfg),
    }


def mla_moe_block_fwd(
    p: Params, cfg: ArchConfig, x, *, q_offset=0, kind="causal", window=None,
    return_cache=False, layer_flag=None,
):
    from repro.models.moe import moe_mlp

    a, cache = mla_attention_fwd(
        p["mla"], cfg, layers.rmsnorm(p["ln1"], x), q_offset=q_offset, return_cache=return_cache
    )
    x = x + a
    y, aux = moe_mlp(p["moe"], cfg, layers.rmsnorm(p["ln2"], x))
    return x + y, cache, aux


def mla_moe_block_step(p: Params, cfg: ArchConfig, x, cache, pos, *, window=None, layer_flag=None, **_):
    from repro.models.moe import moe_mlp

    a, cache = mla_attention_step(p["mla"], cfg, layers.rmsnorm(p["ln1"], x), cache, pos)
    x = x + a
    y, _ = moe_mlp(p["moe"], cfg, layers.rmsnorm(p["ln2"], x))
    return x + y, cache
