"""LM substrate: pure-JAX model definitions for the assigned architectures.

All models expose the same interface (see ``transformer.LM`` /
``encdec.EncDecLM``):

  init(key, cfg)                          -> params pytree
  forward(params, cfg, batch)             -> logits           (training)
  prefill(params, cfg, tokens)            -> (logits, cache)  (serving)
  decode_step(params, cfg, cache, token)  -> (logits, cache)  (serving)
  init_cache(cfg, batch, seq_len)         -> cache pytree

Layer stacks are built from a ``block_pattern`` of homogeneous segments,
each executed with ``jax.lax.scan`` over stacked parameters so 80-layer
models compile to compact HLO.
"""
from repro.models import transformer, encdec

__all__ = ["transformer", "encdec"]
