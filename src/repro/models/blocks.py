"""Dense transformer blocks (pre-norm attention + gated MLP).

Every block kind in this framework exposes the same pair of functions:

  init_<kind>(key, cfg)                       -> layer params (unstacked)
  <kind>_fwd(p, cfg, x, *, q_offset, return_cache, layer_flag)
                                              -> (x, cache | None)
  <kind>_step(p, cfg, x, cache, pos, *, layer_flag)
                                              -> (x, cache)

``layer_flag`` is a traced per-layer scalar threaded through ``lax.scan``
(used e.g. by Hymba to switch SWA <-> global attention without breaking the
homogeneous-stack scan).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.attention import attention, blockwise_attention, decode_attention
from repro.models.layers import Params


# ---------------------------------------------------------------------------
# Tensor parallelism: cross-shard reduction points
#
# Under a TP plan (parallel/tp.py) this module runs as ONE shard: q/k/v and
# wi_gate/wi_up are column-parallel (cfg already holds the shard-local head /
# d_ff counts), wo is row-parallel, so each shard's wo output is a PARTIAL
# sum over its slice of the contraction axis.  The reduction must happen
# before the residual add (residual + norms are replicated), which is why the
# psum sits here at the block call sites and not inside layers.linear.
# ---------------------------------------------------------------------------

def _tp_reduce(y: jax.Array, cfg: ArchConfig, enabled: bool) -> jax.Array:
    """psum partial row-parallel outputs over cfg.tp_axis (no-op untagged)."""
    if enabled and cfg.tp_axis is not None:
        return jax.lax.psum(y, cfg.tp_axis)
    return y


# ---------------------------------------------------------------------------
# GQA/MQA attention sub-layer
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig) -> Params:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": layers._dense_init(k1, cfg.d_model, cfg.n_heads * hd),
        "wk": layers._dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd),
        "wv": layers._dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd),
        "wo": layers._dense_init(k4, cfg.n_heads * hd, cfg.d_model),
    }


def _qkv(p: Params, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    dtype = x.dtype
    q = layers.linear(p["wq"], x, dtype).reshape(b, s, cfg.n_heads, hd)
    k = layers.linear(p["wk"], x, dtype).reshape(b, s, cfg.n_kv_heads, hd)
    v = layers.linear(p["wv"], x, dtype).reshape(b, s, cfg.n_kv_heads, hd)
    # positions: (S,) shared by the batch, or (B, S) per-row (ragged decode
    # slots each sit at their own absolute position)
    pos_b = positions if positions.ndim == 2 else positions[None]
    q = layers.apply_rope(q.transpose(0, 2, 1, 3), pos_b[:, None, :], cfg.rope_theta)
    k = layers.apply_rope(k.transpose(0, 2, 1, 3), pos_b[:, None, :], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)
    return q, k, v  # (B, H, S, hd)


def attention_fwd(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    q_offset: int = 0,
    kind: str = "causal",
    window: Optional[int] = None,
    return_cache: bool = False,
):
    b, s, _ = x.shape
    positions = q_offset + jnp.arange(s)
    q, k, v = _qkv(p, cfg, x, positions)
    out = attention(q, k, v, kind=kind, window=window, q_offset=q_offset)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    y = _tp_reduce(layers.linear(p["wo"], out, x.dtype), cfg, cfg.tp_attn)
    cache = {"k": k, "v": v} if return_cache else None
    return y, cache


def attention_step(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    cache: dict[str, jax.Array],
    pos: jax.Array,
    *,
    window: Optional[jax.Array] = None,
):
    """x: (B, 1, d); cache k/v: (B, Hkv, S, hd); pos: scalar index to write,
    or a (B,) vector of per-row indices (ragged continuous-batching decode)."""
    b = x.shape[0]
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        q, k, v = _qkv(p, cfg, x, jnp.reshape(pos, (1,)))
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=2)
    else:
        q, k, v = _qkv(p, cfg, x, pos[:, None])
        upd = jax.vmap(
            lambda c, new, p_: jax.lax.dynamic_update_slice_in_dim(c, new, p_, axis=1)
        )
        k_cache = upd(cache["k"], k, pos)
        v_cache = upd(cache["v"], v, pos)
    out = decode_attention(q, k_cache, v_cache, pos + 1, window=window)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    y = _tp_reduce(layers.linear(p["wo"], out, x.dtype), cfg, cfg.tp_attn)
    return y, {"k": k_cache, "v": v_cache}


def init_attn_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype) -> dict[str, Any]:
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.n_kv_heads, seq_len, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# Paged KV attention (continuous-batching engine)
#
# The physical cache is a token-major pool shared by every slot:
# k/v: (T, Hkv, hd) with T = num_blocks * page_size.  A slot owns a list of
# fixed-size pages — its block-table row ``table`` (B, P) — mapping logical
# positions to physical cells.  A dispatch gathers each slot's pages ONCE
# into a contiguous (B, Hkv, L, hd) cache view (one gather index per page,
# contiguous page copies), runs ordinary contiguous-cache steps against it
# (``attention_step`` with per-row positions / ``attention_chunk_step``),
# and scatters only the newly written cells back afterwards — so the
# per-token step math is shared with the static path, and decode quanta pay
# the gather once per dispatch instead of once per token.  View positions
# past a slot's valid length hold stale pool bytes; they are masked to
# NEG_INF before the softmax max, so outputs are bit-identical to a
# contiguous cache (see tests/test_engine.py).
# ---------------------------------------------------------------------------

def init_attn_pool(cfg: ArchConfig, num_tokens: int, dtype) -> dict[str, Any]:
    """Token-major physical KV pool: k/v (T, Hkv, hd)."""
    hd = cfg.resolved_head_dim
    shape = (num_tokens, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gather_pool_view(pool_arr: jax.Array, table: jax.Array, page_size: int) -> jax.Array:
    """(..., T, Hkv, hd) pool + (B, P) block table -> (..., B, Hkv, L, hd)
    contiguous per-slot cache view, L = P * page_size."""
    *lead, t, hkv, hd = pool_arr.shape
    b, p = table.shape
    paged = pool_arr.reshape(*lead, t // page_size, page_size, hkv, hd)
    view = jnp.take(paged, table.reshape(-1), axis=len(lead)).reshape(
        *lead, b, p * page_size, hkv, hd
    )
    return jnp.moveaxis(view, -2, -3)


def scatter_pool_view(
    pool_arr: jax.Array,
    view: jax.Array,
    table: jax.Array,
    pos0: jax.Array,
    n_tokens: int,
    page_size: int,
) -> jax.Array:
    """Write back the cells a dispatch filled: view positions
    [pos0_r, pos0_r + n_tokens) of each row r land in their physical pool
    cells (dummy-page rows absorb padded writes).  view: (..., B, Hkv, L,
    hd); returns the updated (..., T, Hkv, hd) pool."""
    *lead, b, hkv, l, hd = view.shape
    idx = pos0[:, None] + jnp.arange(n_tokens)  # (B, n) logical positions
    blk = jnp.take_along_axis(table, idx // page_size, axis=1)
    flat = (blk * page_size + idx % page_size).reshape(-1)  # (B*n,) pool cells
    # extract written tokens: (..., B, Hkv, n, hd) -> (..., B*n, Hkv, hd)
    got = jnp.take_along_axis(
        view, idx.reshape((1,) * len(lead) + (b, 1, n_tokens, 1)), axis=-2
    )
    got = jnp.moveaxis(got, -3, -2).reshape(*lead, b * n_tokens, hkv, hd)
    if lead:
        return pool_arr.at[:, flat].set(got)
    return pool_arr.at[flat].set(got)


def attention_chunk_step(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    cache: dict[str, jax.Array],
    start: jax.Array,
    kv_len: jax.Array,
    *,
    kind: str = "causal",
    window: Optional[int] = None,
):
    """Multi-token continuation against a contiguous cache view, B rows wide.

    x: (B, C, d) — row r holds chunk positions [start_r, start_r + C) of its
    own request (tail columns past a row's true chunk length are padding —
    causality plus ``kv_len`` masking keep them invisible, and the caller's
    write-back routing sends them to the dummy page); cache k/v:
    (B, Hkv, L, hd); start/kv_len: scalars or (B,) per-row vectors,
    ``kv_len`` the valid cache length after this chunk.  Causality makes
    chunked prefill equal full prefill; the shared blockwise-attention
    kernel with traced per-row ``q_offset`` keeps each row bit-identical to
    its solo prefill (key blocks partition the same way — padding only
    appends masked columns).  Extent-1 decode rows do NOT ride this path:
    the engine's fused dispatch runs them through the decode-quantum scan
    sub-batch (``launch.steps._ragged_scan_body``), whose single-step
    ``decode_attention`` normalization is the one solo decode uses.
    """
    b, c, _ = x.shape
    start = jnp.asarray(start)
    positions = (start[:, None] if start.ndim else start) + jnp.arange(c)
    q, k, v = _qkv(p, cfg, x, positions)  # (B, H, C, hd)
    start_b = jnp.broadcast_to(jnp.atleast_1d(start), (b,))
    upd = jax.vmap(
        lambda cch, new, s: jax.lax.dynamic_update_slice_in_dim(cch, new, s, axis=1)
    )
    k_cache = upd(cache["k"], k, start_b)
    v_cache = upd(cache["v"], v, start_b)
    out = blockwise_attention(
        q, k_cache, v_cache, kind=kind, window=window, q_offset=start,
        kv_valid_len=kv_len,
    )
    out = out.transpose(0, 2, 1, 3).reshape(b, c, -1)
    y = _tp_reduce(layers.linear(p["wo"], out, x.dtype), cfg, cfg.tp_attn)
    return y, {"k": k_cache, "v": v_cache}


def attn_block_chunk_step(
    p: Params, cfg: ArchConfig, x, cache, start, kv_len,
    *, kind: str = "causal", window=None, **_,
):
    a, cache = attention_chunk_step(
        p["attn"], cfg, layers.rmsnorm(p["ln1"], x), cache, start, kv_len,
        kind=kind, window=window,
    )
    x = x + a
    m = layers.glu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], x), cfg.act, x.dtype)
    x = x + _tp_reduce(m, cfg, cfg.tp_mlp)
    return x, cache


# ---------------------------------------------------------------------------
# Dense block: pre-norm attn + pre-norm gated MLP
# ---------------------------------------------------------------------------

def init_attn_block(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.init_norm(cfg.d_model),
        "attn": init_attention(k1, cfg),
        "ln2": layers.init_norm(cfg.d_model),
        "mlp": layers.init_glu_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def attn_block_fwd(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    q_offset: int = 0,
    kind: str = "causal",
    window=None,
    return_cache: bool = False,
    layer_flag=None,
):
    a, cache = attention_fwd(
        p["attn"], cfg, layers.rmsnorm(p["ln1"], x),
        q_offset=q_offset, kind=kind, window=window, return_cache=return_cache,
    )
    x = x + a
    m = layers.glu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], x), cfg.act, x.dtype)
    x = x + _tp_reduce(m, cfg, cfg.tp_mlp)
    return x, cache


def attn_block_step(p: Params, cfg: ArchConfig, x, cache, pos, *, window=None, layer_flag=None, **_):
    a, cache = attention_step(p["attn"], cfg, layers.rmsnorm(p["ln1"], x), cache, pos, window=window)
    x = x + a
    m = layers.glu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], x), cfg.act, x.dtype)
    x = x + _tp_reduce(m, cfg, cfg.tp_mlp)
    return x, cache
