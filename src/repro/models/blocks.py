"""Dense transformer blocks (pre-norm attention + gated MLP).

Every block kind in this framework exposes the same pair of functions:

  init_<kind>(key, cfg)                       -> layer params (unstacked)
  <kind>_fwd(p, cfg, x, *, q_offset, return_cache, layer_flag)
                                              -> (x, cache | None)
  <kind>_step(p, cfg, x, cache, pos, *, layer_flag)
                                              -> (x, cache)

``layer_flag`` is a traced per-layer scalar threaded through ``lax.scan``
(used e.g. by Hymba to switch SWA <-> global attention without breaking the
homogeneous-stack scan).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.attention import attention, decode_attention
from repro.models.layers import Params


# ---------------------------------------------------------------------------
# GQA/MQA attention sub-layer
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig) -> Params:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": layers._dense_init(k1, cfg.d_model, cfg.n_heads * hd),
        "wk": layers._dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd),
        "wv": layers._dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd),
        "wo": layers._dense_init(k4, cfg.n_heads * hd, cfg.d_model),
    }


def _qkv(p: Params, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    dtype = x.dtype
    q = layers.linear(p["wq"], x, dtype).reshape(b, s, cfg.n_heads, hd)
    k = layers.linear(p["wk"], x, dtype).reshape(b, s, cfg.n_kv_heads, hd)
    v = layers.linear(p["wv"], x, dtype).reshape(b, s, cfg.n_kv_heads, hd)
    q = layers.apply_rope(q.transpose(0, 2, 1, 3), positions[None, None, :], cfg.rope_theta)
    k = layers.apply_rope(k.transpose(0, 2, 1, 3), positions[None, None, :], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)
    return q, k, v  # (B, H, S, hd)


def attention_fwd(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    q_offset: int = 0,
    kind: str = "causal",
    window: Optional[int] = None,
    return_cache: bool = False,
):
    b, s, _ = x.shape
    positions = q_offset + jnp.arange(s)
    q, k, v = _qkv(p, cfg, x, positions)
    out = attention(q, k, v, kind=kind, window=window, q_offset=q_offset)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    y = layers.linear(p["wo"], out, x.dtype)
    cache = {"k": k, "v": v} if return_cache else None
    return y, cache


def attention_step(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    cache: dict[str, jax.Array],
    pos: jax.Array,
    *,
    window: Optional[jax.Array] = None,
):
    """x: (B, 1, d); cache k/v: (B, Hkv, S, hd); pos: scalar index to write."""
    b = x.shape[0]
    positions = jnp.reshape(pos, (1,))
    q, k, v = _qkv(p, cfg, x, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=2)
    out = decode_attention(q, k_cache, v_cache, pos + 1, window=window)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    y = layers.linear(p["wo"], out, x.dtype)
    return y, {"k": k_cache, "v": v_cache}


def init_attn_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype) -> dict[str, Any]:
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.n_kv_heads, seq_len, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# Dense block: pre-norm attn + pre-norm gated MLP
# ---------------------------------------------------------------------------

def init_attn_block(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.init_norm(cfg.d_model),
        "attn": init_attention(k1, cfg),
        "ln2": layers.init_norm(cfg.d_model),
        "mlp": layers.init_glu_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def attn_block_fwd(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    q_offset: int = 0,
    kind: str = "causal",
    window=None,
    return_cache: bool = False,
    layer_flag=None,
):
    a, cache = attention_fwd(
        p["attn"], cfg, layers.rmsnorm(p["ln1"], x),
        q_offset=q_offset, kind=kind, window=window, return_cache=return_cache,
    )
    x = x + a
    x = x + layers.glu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], x), cfg.act, x.dtype)
    return x, cache


def attn_block_step(p: Params, cfg: ArchConfig, x, cache, pos, *, window=None, layer_flag=None, **_):
    a, cache = attention_step(p["attn"], cfg, layers.rmsnorm(p["ln1"], x), cache, pos, window=window)
    x = x + a
    x = x + layers.glu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], x), cfg.act, x.dtype)
    return x, cache
