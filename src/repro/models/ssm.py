"""Recurrent sequence blocks: xLSTM (mLSTM + sLSTM) and Mamba-style SSM.

These are the sub-quadratic architectures that make the ``long_500k`` decode
shape runnable: their serving state is O(1) in sequence length.

Training forms:
  * mLSTM — chunkwise-parallel linear attention with per-head scalar gates:
    a scan over chunks carries the (dk, dv) matrix state; within a chunk the
    contribution is a dense (P, P) decay-masked attention.  All decay factors
    are products of sigmoids so everything is <= 1 and stable in log space.
    (Simplification vs the paper's exp input gate + stabilizer m_t: we use a
    sigmoid input gate, which keeps the same functional family with
    unconditional stability; noted in DESIGN.md.)
  * sLSTM — genuinely sequential recurrence (block-diagonal recurrent
    weights R per head), implemented as lax.scan over time with the
    exp-input-gate + stabilizer formulation of the xLSTM paper.
  * Mamba — selective SSM; chunked associative scan over time so the
    materialized (B, chunk, d_inner, N) decay tensor stays VMEM-friendly.

Decode steps are single recurrent updates (state pytrees, no KV cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.layers import Params


# ---------------------------------------------------------------------------
# Depthwise causal conv (shared by mLSTM and Mamba)
# ---------------------------------------------------------------------------

def init_conv(key, channels: int, width: int) -> Params:
    return {"w": jax.random.normal(key, (width, channels), jnp.float32) * (width**-0.5)}


def causal_conv(p: Params, x: jax.Array) -> jax.Array:
    """x: (B, S, C) -> (B, S, C), depthwise causal conv of width W."""
    w = p["w"].astype(x.dtype)  # (W, C)
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return out


def causal_conv_step(p: Params, state: jax.Array, x1: jax.Array) -> tuple[jax.Array, jax.Array]:
    """state: (B, W-1, C) trailing inputs; x1: (B, 1, C) -> (new_state, y1)."""
    w = p["w"].astype(x1.dtype)
    window = jnp.concatenate([state, x1], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window, w)[:, None, :]
    return window[:, 1:], y


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM), chunkwise-parallel
# ---------------------------------------------------------------------------

def init_mlstm_block(key, cfg: ArchConfig) -> Params:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "ln": layers.init_norm(cfg.d_model),
        "w_up": layers._dense_init(ks[0], cfg.d_model, 2 * di),
        "conv": init_conv(ks[1], di, s.conv_width),
        "wq": layers._dense_init(ks[2], di, di),
        "wk": layers._dense_init(ks[3], di, di),
        "wv": layers._dense_init(ks[4], di, di),
        "w_if": layers._dense_init(ks[5], cfg.d_model, 2 * cfg.n_heads),
        "w_down": layers._dense_init(ks[6], di, cfg.d_model),
    }


def _mlstm_chunk(q, k, v, li, lf, state, norm):
    """One chunk of the mLSTM recurrence.

    q,k,v: (B, P, H, dh); li/lf: (B, P, H) log input/forget gates (<= 0).
    state: (B, H, dh, dh) matrix memory; norm: (B, H, dh) normalizer.
    Returns (y (B,P,H,dh), new_state, new_norm).
    """
    p = q.shape[1]
    cum = jnp.cumsum(lf, axis=1)  # (B, P, H) inclusive log decay products
    # intra-chunk: decay-masked attention
    # D[t, j] = exp(cum_t - cum_j + li_j) for j <= t
    logd = cum[:, :, None, :] - cum[:, None, :, :] + li[:, None, :, :]  # (B,P,P,H)
    tri = jnp.tril(jnp.ones((p, p), jnp.bool_))
    d = jnp.where(tri[None, :, :, None], jnp.exp(logd), 0.0)
    scores = jnp.einsum("bthd,bjhd->btjh", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * d
    y_intra = jnp.einsum("btjh,bjhd->bthd", scores, v.astype(jnp.float32))
    # normalizer: the n state accumulates i_j k_j, so the intra-chunk term of
    # q_t . n_t is sum_j D_tj (q_t . k_j) — exactly the row sums of `scores`.
    n_intra = jnp.sum(scores, axis=2)  # (B, P, H)
    # inter-chunk: decayed readout of carried state
    decay_t = jnp.exp(cum)  # (B, P, H)
    y_inter = jnp.einsum(
        "bthd,bhde->bthe", q.astype(jnp.float32) * decay_t[..., None], state
    )
    n_inter = jnp.einsum("bthd,bhd->bth", q.astype(jnp.float32) * decay_t[..., None], norm)
    denom = jnp.maximum(jnp.abs(n_intra + n_inter), 1.0)
    y = (y_intra + y_inter) / denom[..., None]
    # state update
    total = cum[:, -1][:, None]  # (B, 1, H) full-chunk log decay
    w = jnp.exp(total - cum + li)  # (B, P, H): decay from step j to chunk end
    kv = jnp.einsum("bjhd,bjhe->bhde", k.astype(jnp.float32) * w[..., None], v.astype(jnp.float32))
    new_state = jnp.exp(total[:, 0])[..., None, None] * state + kv
    new_norm = jnp.exp(total[:, 0])[..., None] * norm + jnp.sum(
        k.astype(jnp.float32) * w[..., None], axis=1
    )
    return y, new_state, new_norm


def mlstm_cell(q, k, v, i_logit, f_logit, state, norm, chunk: int):
    """Full-sequence chunkwise mLSTM.  q,k,v: (B,S,H,dh); gates: (B,S,H)."""
    b, s, h, dh = q.shape
    q = q * (dh**-0.5)
    li = jax.nn.log_sigmoid(i_logit.astype(jnp.float32))
    lf = jax.nn.log_sigmoid(f_logit.astype(jnp.float32))
    # pad the tail to a chunk multiple with identity steps: input gate 0
    # (li = -inf: contributes nothing) and forget gate 1 (lf = 0: no decay),
    # then slice the outputs back — exact for state and outputs.
    pad = (-s) % chunk
    if pad:
        padq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, padq) for t in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
    s_orig, s = s, s + pad
    nchunk = s // chunk

    def step(carry, xs):
        st, nm = carry
        qc, kc, vc, lic, lfc = xs
        y, st, nm = _mlstm_chunk(qc, kc, vc, lic, lfc, st, nm)
        return (st, nm), y

    def split(t):
        return t.reshape(b, nchunk, chunk, *t.shape[2:]).swapaxes(0, 1)

    (state, norm), ys = jax.lax.scan(
        step, (state, norm), (split(q), split(k), split(v), split(li), split(lf))
    )
    y = ys.swapaxes(0, 1).reshape(b, s, h, dh)[:, :s_orig]
    return y, state, norm


def mlstm_block_fwd(
    p: Params, cfg: ArchConfig, x, *, q_offset=0, return_cache=False, layer_flag=None, **_,
):
    s_cfg = cfg.ssm
    b, s, d = x.shape
    h = cfg.n_heads
    di = s_cfg.expand * d
    dh = di // h
    dtype = x.dtype

    xn = layers.rmsnorm(p["ln"], x)
    u = layers.linear(p["w_up"], xn, dtype)
    u_c, u_g = u[..., :di], u[..., di:]
    c = jax.nn.silu(causal_conv(p["conv"], u_c))
    q = layers.linear(p["wq"], c, dtype).reshape(b, s, h, dh)
    k = layers.linear(p["wk"], c, dtype).reshape(b, s, h, dh)
    v = layers.linear(p["wv"], u_c, dtype).reshape(b, s, h, dh)
    gates = layers.linear(p["w_if"], xn, dtype)
    i_logit, f_logit = gates[..., :h], gates[..., h:]

    state0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    norm0 = jnp.zeros((b, h, dh), jnp.float32)
    y, state, norm = mlstm_cell(q, k, v, i_logit, f_logit, state0, norm0, s_cfg.chunk_size)
    out = layers.linear(p["w_down"], y.reshape(b, s, di).astype(dtype) * jax.nn.silu(u_g), dtype)
    cache = None
    if return_cache:
        cache = {"state": state, "norm": norm, "conv": u_c[:, -(s_cfg.conv_width - 1) :, :]}
    return x + out, cache


def mlstm_block_step(p: Params, cfg: ArchConfig, x, cache, pos, *, layer_flag=None, **_):
    s_cfg = cfg.ssm
    b, _, d = x.shape
    h = cfg.n_heads
    di = s_cfg.expand * d
    dh = di // h
    dtype = x.dtype

    xn = layers.rmsnorm(p["ln"], x)
    u = layers.linear(p["w_up"], xn, dtype)
    u_c, u_g = u[..., :di], u[..., di:]
    conv_state, c = causal_conv_step(p["conv"], cache["conv"], u_c)
    c = jax.nn.silu(c)
    q = layers.linear(p["wq"], c, dtype).reshape(b, h, dh) * (dh**-0.5)
    k = layers.linear(p["wk"], c, dtype).reshape(b, h, dh)
    v = layers.linear(p["wv"], u_c, dtype).reshape(b, h, dh)
    gates = layers.linear(p["w_if"], xn, dtype)
    i_g = jax.nn.sigmoid(gates[..., :h].astype(jnp.float32)).reshape(b, h)
    f_g = jax.nn.sigmoid(gates[..., h:].astype(jnp.float32)).reshape(b, h)

    state = f_g[..., None, None] * cache["state"] + i_g[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    norm = f_g[..., None] * cache["norm"] + i_g[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), state)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), norm)), 1.0)
    y = (num / den[..., None]).reshape(b, 1, di).astype(dtype)
    out = layers.linear(p["w_down"], y * jax.nn.silu(u_g), dtype)
    return x + out, {"state": state, "norm": norm, "conv": conv_state}


def init_mlstm_cache(cfg: ArchConfig, batch: int, dtype):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    dh = di // cfg.n_heads
    return {
        "state": jnp.zeros((batch, cfg.n_heads, dh, dh), jnp.float32),
        "norm": jnp.zeros((batch, cfg.n_heads, dh), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, di), dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with exp gating + stabilizer), sequential
# ---------------------------------------------------------------------------

def init_slstm_block(key, cfg: ArchConfig) -> Params:
    h = cfg.n_heads
    dh = cfg.d_model // h
    ks = jax.random.split(key, 4)
    return {
        "ln": layers.init_norm(cfg.d_model),
        "w": layers._dense_init(ks[0], cfg.d_model, 4 * cfg.d_model),  # i,f,z,o
        "r": jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32) * (dh**-0.5),
        "w_out": layers._dense_init(ks[2], cfg.d_model, cfg.d_model),
    }


def _slstm_step(p: Params, cfg: ArchConfig, wx_t, hs):
    """wx_t: (B, H, 4*dh) input contribution; hs: state dict."""
    h_prev, c_prev, n_prev, m_prev = hs["h"], hs["c"], hs["n"], hs["m"]
    rh = jnp.einsum("bhd,hde->bhe", h_prev, p["r"])  # (B, H, 4*dh)
    g = (wx_t + rh).astype(jnp.float32)
    dh = g.shape[-1] // 4
    ig, fg, zg, og = g[..., :dh], g[..., dh : 2 * dh], g[..., 2 * dh : 3 * dh], g[..., 3 * dh :]
    lf = jax.nn.log_sigmoid(fg)
    m_t = jnp.maximum(lf + m_prev, ig)
    i_p = jnp.exp(ig - m_t)
    f_p = jnp.exp(lf + m_prev - m_t)
    c_t = f_p * c_prev + i_p * jnp.tanh(zg)
    n_t = f_p * n_prev + i_p
    h_t = jax.nn.sigmoid(og) * c_t / jnp.maximum(n_t, 1e-6)
    return {"h": h_t, "c": c_t, "n": n_t, "m": m_t}


def slstm_block_fwd(
    p: Params, cfg: ArchConfig, x, *, q_offset=0, return_cache=False, layer_flag=None, **_,
):
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    dtype = x.dtype
    xn = layers.rmsnorm(p["ln"], x)
    wx = layers.linear(p["w"], xn, dtype).reshape(b, s, h, 4 * dh)

    hs0 = {
        "h": jnp.zeros((b, h, dh), jnp.float32),
        "c": jnp.zeros((b, h, dh), jnp.float32),
        "n": jnp.zeros((b, h, dh), jnp.float32),
        "m": jnp.full((b, h, dh), -1e30, jnp.float32),
    }

    def step(hs, wx_t):
        hs = _slstm_step(p, cfg, wx_t, hs)
        return hs, hs["h"]

    hs, ys = jax.lax.scan(step, hs0, wx.swapaxes(0, 1))
    y = ys.swapaxes(0, 1).reshape(b, s, d).astype(dtype)
    out = layers.linear(p["w_out"], y, dtype)
    cache = hs if return_cache else None
    return x + out, cache


def slstm_block_step(p: Params, cfg: ArchConfig, x, cache, pos, *, layer_flag=None, **_):
    b, _, d = x.shape
    h = cfg.n_heads
    dh = d // h
    dtype = x.dtype
    xn = layers.rmsnorm(p["ln"], x)
    wx = layers.linear(p["w"], xn, dtype).reshape(b, h, 4 * dh)
    hs = _slstm_step(p, cfg, wx, cache)
    y = hs["h"].reshape(b, 1, d).astype(dtype)
    out = layers.linear(p["w_out"], y, dtype)
    return x + out, hs


def init_slstm_cache(cfg: ArchConfig, batch: int, dtype):
    h = cfg.n_heads
    dh = cfg.d_model // h
    z = lambda: jnp.zeros((batch, h, dh), jnp.float32)
    return {"h": z(), "c": z(), "n": z(), "m": jnp.full((batch, h, dh), -1e30, jnp.float32)}


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (Hymba's SSM heads)
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ArchConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    n = s.state_size
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": layers._dense_init(ks[0], d, 2 * di),
        "conv": init_conv(ks[1], di, s.conv_width),
        "x_proj": layers._dense_init(ks[2], di, dt_rank + 2 * n),
        "dt_proj": layers._dense_init(ks[3], dt_rank, di),
        "dt_bias": jnp.log(jnp.exp(jnp.linspace(1e-3, 1e-1, di)) - 1.0).astype(jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": layers._dense_init(ks[4], di, d),
    }


def _mamba_scan_chunked(a_bar, bx, state, chunk: int):
    """h_t = a_bar_t * h_{t-1} + bx_t via chunked associative scan.

    a_bar, bx: (B, S, di, N) — materialized per *chunk* only.
    state: (B, di, N).  Returns (hs (B,S,di,N), final state).
    """
    b, s, di, n = a_bar.shape
    # pad the tail with identity steps (a=1, b=0): state passes through
    pad = (-s) % chunk
    if pad:
        a_bar = jnp.pad(a_bar, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_orig, s = s, s + pad
    nchunk = s // chunk

    def step(h0, xs):
        ac, bc = xs  # (B, P, di, N)
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2
        a_acc, b_acc = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hs = a_acc * h0[:, None] + b_acc
        return hs[:, -1], hs

    split = lambda t: t.reshape(b, nchunk, chunk, di, n).swapaxes(0, 1)
    state, ys = jax.lax.scan(step, state, (split(a_bar), split(bx)))
    return ys.swapaxes(0, 1).reshape(b, s, di, n)[:, :s_orig], state


def mamba_fwd(p: Params, cfg: ArchConfig, xn, *, return_cache=False):
    """xn: (B, S, d) pre-normed input -> (y, cache|None)."""
    s_cfg = cfg.ssm
    b, s, d = xn.shape
    di = s_cfg.expand * d
    n = s_cfg.state_size
    dtype = xn.dtype

    u = layers.linear(p["in_proj"], xn, dtype)
    xc, z = u[..., :di], u[..., di:]
    conv_tail = xc[:, -(s_cfg.conv_width - 1) :, :]
    xc = jax.nn.silu(causal_conv(p["conv"], xc))

    proj = layers.linear(p["x_proj"], xc, dtype)
    dt_rank = proj.shape[-1] - 2 * n
    dt = jax.nn.softplus(
        layers.linear(p["dt_proj"], proj[..., :dt_rank], dtype) + p["dt_bias"].astype(dtype)
    ).astype(jnp.float32)  # (B,S,di)
    b_in = proj[..., dt_rank : dt_rank + n].astype(jnp.float32)  # (B,S,N)
    c_out = proj[..., dt_rank + n :].astype(jnp.float32)  # (B,S,N)

    a = -jnp.exp(p["a_log"])  # (di, N)
    a_bar = jnp.exp(dt[..., None] * a[None, None])  # (B,S,di,N)
    bx = (dt * xc.astype(jnp.float32))[..., None] * b_in[:, :, None, :]  # (B,S,di,N)

    state0 = jnp.zeros((b, di, n), jnp.float32)
    hs, state = _mamba_scan_chunked(a_bar, bx, state0, s_cfg.chunk_size)
    y = jnp.einsum("bsdn,bsn->bsd", hs, c_out) + p["d_skip"][None, None] * xc.astype(jnp.float32)
    y = layers.linear(p["out_proj"], y.astype(dtype) * jax.nn.silu(z), dtype)
    cache = {"state": state, "conv": conv_tail} if return_cache else None
    return y, cache


def mamba_step(p: Params, cfg: ArchConfig, xn, cache):
    """xn: (B, 1, d) -> (y, cache)."""
    s_cfg = cfg.ssm
    b, _, d = xn.shape
    di = s_cfg.expand * d
    n = s_cfg.state_size
    dtype = xn.dtype

    u = layers.linear(p["in_proj"], xn, dtype)
    xc, z = u[..., :di], u[..., di:]
    conv_state, xc1 = causal_conv_step(p["conv"], cache["conv"], xc)
    xc1 = jax.nn.silu(xc1)  # (B,1,di)

    proj = layers.linear(p["x_proj"], xc1, dtype)
    dt_rank = proj.shape[-1] - 2 * n
    dt = jax.nn.softplus(
        layers.linear(p["dt_proj"], proj[..., :dt_rank], dtype) + p["dt_bias"].astype(dtype)
    ).astype(jnp.float32)[:, 0]  # (B,di)
    b_in = proj[:, 0, dt_rank : dt_rank + n].astype(jnp.float32)  # (B,N)
    c_out = proj[:, 0, dt_rank + n :].astype(jnp.float32)  # (B,N)

    a = -jnp.exp(p["a_log"])
    a_bar = jnp.exp(dt[..., None] * a[None])  # (B,di,N)
    bx = (dt * xc1[:, 0].astype(jnp.float32))[..., None] * b_in[:, None, :]
    state = a_bar * cache["state"] + bx
    y = jnp.einsum("bdn,bn->bd", state, c_out) + p["d_skip"][None] * xc1[:, 0].astype(jnp.float32)
    y = layers.linear(p["out_proj"], y[:, None].astype(dtype) * jax.nn.silu(z), dtype)
    return y, {"state": state, "conv": conv_state}


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {
        "state": jnp.zeros((batch, di, s.state_size), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, di), dtype),
    }
