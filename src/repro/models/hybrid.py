"""Hymba-style hybrid block: parallel attention + Mamba heads in one layer.

Both branches read the same pre-normed input; their outputs are per-branch
RMS-normalized and averaged (the Hymba fusion rule), then a gated MLP
follows.  Two block kinds share parameters' structure:

  * ``hymba_swa``    — sliding-window attention branch (ring-buffer cache of
    ``cfg.attn_window`` entries at decode time, so the 500k-decode cell's
    cache is window-bounded for 29 of 32 layers);
  * ``hymba_global`` — full-attention branch (the 3 global layers).

Meta tokens (128 learnable prefix tokens) are handled by the LM assembly,
not per-block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers, ssm
from repro.models.attention import attention, decode_attention
from repro.models.blocks import init_attention, _qkv
from repro.models.layers import Params


def init_hymba_block(key, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.init_norm(cfg.d_model),
        "attn": init_attention(k1, cfg),
        "mamba": ssm.init_mamba(k2, cfg),
        "norm_attn": layers.init_norm(cfg.d_model),
        "norm_ssm": layers.init_norm(cfg.d_model),
        "ln2": layers.init_norm(cfg.d_model),
        "mlp": layers.init_glu_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def _fuse(p: Params, attn_out, ssm_out):
    return 0.5 * (layers.rmsnorm(p["norm_attn"], attn_out) + layers.rmsnorm(p["norm_ssm"], ssm_out))


def hymba_block_fwd(
    p: Params, cfg: ArchConfig, x, *, q_offset=0, kind="swa", window=None,
    return_cache=False, layer_flag=None,
):
    b, s, _ = x.shape
    xn = layers.rmsnorm(p["ln1"], x)
    positions = q_offset + jnp.arange(s)
    q, k, v = _qkv(p["attn"], cfg, xn, positions)
    attn = attention(
        q, k, v, kind=kind, window=window if kind == "swa" else None, q_offset=q_offset
    )
    attn = layers.linear(p["attn"]["wo"], attn.transpose(0, 2, 1, 3).reshape(b, s, -1), x.dtype)
    ssm_out, ssm_cache = ssm.mamba_fwd(p["mamba"], cfg, xn, return_cache=return_cache)
    x = x + _fuse(p, attn, ssm_out)
    x = x + layers.glu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], x), cfg.act, x.dtype)
    cache = None
    if return_cache:
        if kind == "swa":
            w = int(window)
            # keep only the trailing window as a ring buffer, aligned so that
            # slot (pos % w) holds position pos (prefill is assumed to start
            # at q_offset; element at trailing index 0 is position
            # q_offset+s-w and must land on slot (q_offset+s) % w).
            if s >= w:
                kk, vv = k[:, :, -w:], v[:, :, -w:]
                roll = (q_offset + s) % w
                kk = jnp.roll(kk, roll, axis=2)
                vv = jnp.roll(vv, roll, axis=2)
            else:
                pad = ((0, 0), (0, 0), (0, w - s), (0, 0))
                kk, vv = jnp.pad(k, pad), jnp.pad(v, pad)
            cache = {"k": kk, "v": vv, "ssm": ssm_cache}
        else:
            cache = {"k": k, "v": v, "ssm": ssm_cache}
    return x, cache


def hymba_block_step(
    p: Params, cfg: ArchConfig, x, cache, pos, *, kind="swa", window=None, layer_flag=None,
):
    b = x.shape[0]
    xn = layers.rmsnorm(p["ln1"], x)
    positions = jnp.reshape(pos, (1,))
    q, k, v = _qkv(p["attn"], cfg, xn, positions)
    if kind == "swa":
        w = cache["k"].shape[2]
        slot = jnp.mod(pos, w)
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=2)
        valid = jnp.minimum(pos + 1, w)
        attn = decode_attention(q, k_cache, v_cache, valid)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=2)
        attn = decode_attention(q, k_cache, v_cache, pos + 1)
    attn = layers.linear(p["attn"]["wo"], attn.transpose(0, 2, 1, 3).reshape(b, 1, -1), x.dtype)
    ssm_out, ssm_cache = ssm.mamba_step(p["mamba"], cfg, xn, cache["ssm"])
    x = x + _fuse(p, attn, ssm_out)
    x = x + layers.glu_mlp(p["mlp"], layers.rmsnorm(p["ln2"], x), cfg.act, x.dtype)
    return x, {"k": k_cache, "v": v_cache, "ssm": ssm_cache}


def init_hymba_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype, *, kind: str):
    hd = cfg.resolved_head_dim
    # SWA caches are always window-length ring buffers (prefill emits exactly
    # this shape, so prefill->decode cache merging is shape-stable).
    length = cfg.attn_window if kind == "hymba_swa" else seq_len
    shape = (batch, cfg.n_kv_heads, length, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "ssm": ssm.init_mamba_cache(cfg, batch, dtype),
    }
