"""AdamW with global-norm clipping and cosine schedule (from scratch).

Optimizer state mirrors the param pytree (same shapes), so the param
sharding rules apply verbatim to the state — FSDP shards optimizer moments
for free (the memory-term lever in §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def adamw_init(params: Any) -> dict[str, Any]:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros(), "v": zeros(), "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    grads: Any, state: dict[str, Any], params: Any, cfg: AdamWConfig
) -> tuple[Any, dict[str, Any], dict[str, jax.Array]]:
    count = state["count"] + 1
    lr = cosine_lr(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda mm, g: cfg.b1 * mm + (1 - cfg.b1) * g, state["m"], grads)
    v = jax.tree.map(lambda vv, g: cfg.b2 * vv + (1 - cfg.b2) * g * g, state["v"], grads)
    c = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1**c
    bc2 = 1 - cfg.b2**c

    def upd(p, mm, vv):
        step = (mm / bc1) / (jnp.sqrt(vv / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (step + decay)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": m, "v": v, "count": count}, metrics
