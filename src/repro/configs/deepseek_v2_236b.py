"""deepseek-v2-236b — MLA + MoE decoder [arXiv:2405.04434; hf].

60L d_model=5120 128H (MLA) d_ff=1536(per-expert) vocab=102400,
MLA kv_lora=512, MoE: 2 shared + 160 routed top-6.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=1536,
        vocab_size=102400,
        act="swiglu",
        mla=MLAConfig(
            kv_lora_rank=512,
            q_lora_rank=1536,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(n_routed=160, n_shared=2, top_k=6, d_expert=1536),
        block_pattern=(("mla_moe", 1),),
    ),
    reduced=lambda: ArchConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=48,
        vocab_size=256,
        act="swiglu",
        dtype="float32",
        mla=MLAConfig(
            kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        ),
        moe=MoEConfig(n_routed=8, n_shared=1, top_k=2, d_expert=48),
        block_pattern=(("mla_moe", 1),),
    ),
)
