"""internlm2-1.8b — dense GQA decoder [arXiv:2403.17297; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544, RoPE + SwiGLU.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92544,
        act="swiglu",
        rope_theta=1_000_000.0,
        block_pattern=(("attn", 1),),
    ),
    reduced=lambda: ArchConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        act="swiglu",
        dtype="float32",
        block_pattern=(("attn", 1),),
    ),
)
