"""phi3-medium-14b — dense GQA decoder [arXiv:2404.14219; unverified].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352, RoPE + SwiGLU.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="phi3-medium-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=10,
        d_ff=17920,
        vocab_size=100352,
        act="swiglu",
        block_pattern=(("attn", 1),),
    ),
    reduced=lambda: ArchConfig(
        name="phi3-medium-14b",
        family="dense",
        n_layers=2,
        d_model=80,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=256,
        act="swiglu",
        dtype="float32",
        block_pattern=(("attn", 1),),
    ),
)
