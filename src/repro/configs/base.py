"""Architecture + shape config schema, and the global registry.

Every assigned architecture registers an exact ``ArchConfig`` (the full
model, instantiated only via ShapeDtypeStructs in the dry-run) and a
``reduced()`` variant of the same family for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_expert: int  # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # Allocate expert weights padded to this count (> n_routed) so the expert
    # dim divides the 16-way model axis and clean expert-parallelism applies.
    # Routing never selects a padded expert; they are dead weights (the
    # standard production trick for awkward expert counts — §Perf iteration 2
    # showed the expert-TP fallback costs a 10.7 GB f32 dispatch-buffer psum
    # per layer in the backward pass, 65% of the step's wire bytes).
    pad_experts_to: int | None = None

    @property
    def n_alloc(self) -> int:
        return self.pad_experts_to or self.n_routed


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_size: int = 16  # per-channel state (mamba N / mlstm dk factor)
    conv_width: int = 4
    expand: int = 2  # d_inner = expand * d_model
    chunk_size: int = 256  # chunkwise-parallel training chunk


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    act: str = "swiglu"  # swiglu | geglu
    norm: str = "rmsnorm"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embed_scale: bool = False  # multiply embeddings by sqrt(d_model) (gemma)
    dtype: str = "bfloat16"
    # --- family extensions ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # block pattern: sequence of (block_kind, repeat); expanded cyclically to
    # n_layers.  kinds: "attn" (dense attn+mlp), "moe" (attn+moe), "mla_moe",
    # "mlstm", "slstm", "hymba".
    block_pattern: tuple[tuple[str, int], ...] = (("attn", 1),)
    # attention flavour
    attn_window: Optional[int] = None  # sliding-window size (None = full)
    global_layer_every: Optional[int] = None  # hymba: every k-th layer is global
    # enc-dec
    encdec: bool = False
    n_enc_layers: int = 0
    # modality frontend stub: tokens are replaced by precomputed embeddings
    # for the first `stub_prefix_len` positions (vlm patches / audio frames)
    stub_prefix_len: int = 0
    # meta/prefix tokens (hymba): learnable tokens prepended to the sequence
    n_meta_tokens: int = 0
    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False
    # --- tensor parallelism (parallel/tp.py) ---
    # When tp_axis is set, model code runs as ONE shard of a tensor-parallel
    # group: tp_attn means q/k/v are column-parallel and wo row-parallel
    # (psum over tp_axis after wo), tp_mlp means wi_gate/wi_up column-parallel
    # and mlp wo row-parallel (psum after the MLP).  The *local* head/ff
    # counts are already divided down in this config (see tp.local_config);
    # the flags only gate where the cross-shard reductions happen.
    tp_axis: Optional[str] = None
    tp_attn: bool = False
    tp_mlp: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def layer_kinds(self) -> list[str]:
        """Expand block_pattern cyclically to exactly n_layers kinds."""
        kinds: list[str] = []
        while len(kinds) < self.n_layers:
            for kind, rep in self.block_pattern:
                kinds.extend([kind] * rep)
                if len(kinds) >= self.n_layers:
                    break
        return kinds[: self.n_layers]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, ArchConfig] = {}
_REDUCED: dict[str, Callable[[], ArchConfig]] = {}


def register(cfg: ArchConfig, reduced: Callable[[], ArchConfig]) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    _REDUCED[cfg.name] = reduced
    return cfg


def get_arch(name: str, *, reduced: bool = False) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REDUCED[name]() if reduced else _REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention; pure full-attention arch"
    return True, ""
