"""yi-6b — llama-arch dense GQA decoder [arXiv:2403.04652; hf].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="yi-6b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        act="swiglu",
        rope_theta=5_000_000.0,
        block_pattern=(("attn", 1),),
    ),
    reduced=lambda: ArchConfig(
        name="yi-6b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        act="swiglu",
        dtype="float32",
        block_pattern=(("attn", 1),),
    ),
)
