"""qwen2-moe-a2.7b — MoE decoder [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) d_ff=1408(per-expert) vocab=151936,
MoE: 4 shared + 60 routed top-4.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=151936,
        act="swiglu",
        moe=MoEConfig(n_routed=60, n_shared=4, top_k=4, d_expert=1408, pad_experts_to=64),
        block_pattern=(("moe", 1),),
    ),
    reduced=lambda: ArchConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=256,
        act="swiglu",
        dtype="float32",
        moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_expert=96),
        block_pattern=(("moe", 1),),
    ),
)
