"""Architecture configs: one module per assigned architecture + registry."""
from repro.configs.base import (
    ArchConfig,
    MLAConfig,
    MoEConfig,
    SSMConfig,
    ShapeSpec,
    SHAPES,
    get_arch,
    list_archs,
    register,
)

# importing the modules registers their configs
from repro.configs import (  # noqa: F401  (registration side effects)
    xlstm_350m,
    internvl2_76b,
    qwen2_moe_a2_7b,
    deepseek_v2_236b,
    seamless_m4t_medium,
    internlm2_1_8b,
    gemma_2b,
    phi3_medium_14b,
    yi_6b,
    hymba_1_5b,
)

__all__ = [
    "ArchConfig",
    "MLAConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeSpec",
    "SHAPES",
    "get_arch",
    "list_archs",
    "register",
]
