"""seamless-m4t-medium — encoder-decoder, audio frontend stubbed
[arXiv:2308.11596; hf].

12L encoder + 12L decoder, d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
``input_specs`` provides precomputed audio-frame embeddings (B, S, d) for
the encoder; shapes interpret seq_len as both source frames and target
tokens (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="seamless-m4t-medium",
        family="audio",
        n_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        act="swiglu",
        encdec=True,
        n_enc_layers=12,
        block_pattern=(("attn", 1),),
    ),
    reduced=lambda: ArchConfig(
        name="seamless-m4t-medium",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        act="swiglu",
        dtype="float32",
        encdec=True,
        n_enc_layers=2,
        block_pattern=(("attn", 1),),
    ),
)
