"""internvl2-76b — VLM backbone (InternViT frontend stubbed) [arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  Per the brief the
transformer BACKBONE only is modeled; ``input_specs`` provides precomputed
patch embeddings for the first 256 positions (stub_prefix_len).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        act="swiglu",
        stub_prefix_len=256,
        block_pattern=(("attn", 1),),
    ),
    reduced=lambda: ArchConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        act="swiglu",
        stub_prefix_len=8,
        dtype="float32",
        block_pattern=(("attn", 1),),
    ),
)
