"""hymba-1.5b — hybrid parallel attention+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
3 global-attention layers (first / middle / last), SWA elsewhere; 128 meta
tokens.  Sub-quadratic: long_500k runs (SWA ring caches + O(1) SSM state).
"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(
    ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        head_dim=64,
        # chunk_size 16: the chunked selective scan is exact for any chunk; 4
        # associative-scan levels instead of 6 cuts the scan's HBM traffic
        # by a third (EXPERIMENTS.md §Perf cell-3 iter 2)
        ssm=SSMConfig(state_size=16, conv_width=4, expand=2, chunk_size=16),
        attn_window=1024,
        n_meta_tokens=128,
        block_pattern=(
            ("hymba_global", 1),
            ("hymba_swa", 14),
            ("hymba_global", 1),
            ("hymba_swa", 15),
            ("hymba_global", 1),
        ),
        subquadratic=True,
    ),
    reduced=lambda: ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        dtype="float32",
        ssm=SSMConfig(state_size=8, conv_width=4, expand=2, chunk_size=8),
        attn_window=16,
        n_meta_tokens=8,
        block_pattern=(("hymba_global", 1), ("hymba_swa", 2), ("hymba_global", 1)),
        subquadratic=True,
    ),
)
