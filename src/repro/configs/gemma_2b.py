"""gemma-2b — dense MQA decoder [arXiv:2403.08295; hf].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000, GeGLU,
head_dim=256, tied embeddings, sqrt(d) embedding scale.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="gemma-2b",
        family="dense",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        d_ff=16384,
        vocab_size=256000,
        head_dim=256,
        act="geglu",
        tie_embeddings=True,
        embed_scale=True,
        block_pattern=(("attn", 1),),
    ),
    reduced=lambda: ArchConfig(
        name="gemma-2b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=192,
        vocab_size=256,
        head_dim=32,
        act="geglu",
        tie_embeddings=True,
        embed_scale=True,
        dtype="float32",
        block_pattern=(("attn", 1),),
    ),
)
