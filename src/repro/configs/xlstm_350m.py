"""xlstm-350m — sLSTM + mLSTM recurrent LM [arXiv:2405.04517; unverified].

24L d_model=1024 4H d_ff=0 (xLSTM blocks carry their own projections)
vocab=50304.  Block ratio mLSTM:sLSTM = 7:1 (xLSTM[7:1]).  Sub-quadratic:
long_500k runs (recurrent O(1) decode state).
"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(
    ArchConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        ssm=SSMConfig(state_size=16, conv_width=4, expand=2, chunk_size=256),
        block_pattern=(("mlstm", 7), ("slstm", 1)),
        subquadratic=True,
    ),
    reduced=lambda: ArchConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=256,
        dtype="float32",
        ssm=SSMConfig(state_size=8, conv_width=4, expand=2, chunk_size=8),
        block_pattern=(("mlstm", 3), ("slstm", 1)),
        subquadratic=True,
    ),
)
