"""Tensor-parallel sharding of one serving replica over a "model" mesh axis.

One engine replica (or one `serve.generate` pipeline) is split N ways with
the Megatron column/row-parallel discipline, derived from the SAME rule
table serving already trusts for training layouts (`parallel.sharding`):

  * ``wq`` / ``wk`` / ``wv`` / ``wi_gate`` / ``wi_up`` — column-parallel
    (output axis sliced; each shard owns ``n_heads/N`` heads and ``d_ff/N``
    hidden channels, so attention and the GLU nonlinearity stay shard-local),
  * ``attn/wo`` / ``mlp/wo`` — row-parallel (contraction axis sliced; each
    shard holds a PARTIAL output, summed with ``lax.psum`` before the
    residual add — the gated reduction points in ``models.blocks``),
  * embeddings / norms / ``head`` — replicated.  The rule table shards the
    vocab axis for training, but serving samples from the logits on the
    host, so the head stays replicated here and every shard finishes each
    layer (and the unembedding) with FULL activations.  Token sampling is
    therefore identical on every shard and the engine's host-side scheduler
    needs no changes.

Packed CIM operands shard by *slicing the stored bit planes* — see
``simulator.shard_operands`` — never by requantizing, so the dense and
packed layouts of one tensor agree shard-by-shard by construction
(``densify(shard(op)) == shard(densify(op))`` byte-for-byte).  The paged KV
pool partitions on the head axis for free: each shard's ``wk``/``wv`` slice
only ever *produces* its own ``n_kv_heads/N`` heads, so per-shard pools are
just the local-config pools stacked on a leading shard axis, sharing ONE
block table / slot schedule.

Execution: the shard axis is a *leading pytree axis*.  ``_spmd`` runs the
unmodified single-shard step either under ``jax.vmap`` with a bound
``axis_name`` (single-device emulation: ``lax.psum`` reduces over the vmap
axis — this is how the parity battery pins {1, 2, 4}-way sharding on one
CPU device) or under ``shard_map`` over a real ``Mesh`` of N devices (the
host-emulated ``--xla_force_host_platform_device_count`` mesh or real
accelerators), where the same psum lowers to an all-reduce.  Both paths run
the SAME jitted step functions with the same signatures as their unsharded
twins, so `launch.engine` only swaps the wrapper in.

Divisibility is checked per *component*, not per leaf: GQA/MQA means
``n_kv_heads`` can refuse a split that every leaf shape would accept (gemma
reduced holds one KV head — slicing ``wk``'s 32 columns 2-ways would cut
mid-head).  A component that cannot split degrades to replication (the
plan records why), never an error — the property-test battery drives
ragged head/column counts through this fallback.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import simulator
from repro.parallel import sharding as shrules

DEFAULT_AXIS = "model"

# component membership: the trailing "<sublayer>/<leaf>" of a param path.
# Directions (col = slice output axis -1, row = slice contraction axis -2)
# are cross-checked against sharding._RULES in plan_tp, not hard-coded
# trust: if the rule table ever disagrees, the component replicates.
_ATTN_LEAVES = {"wq": -1, "wk": -1, "wv": -1, "wo": -2}
_MLP_LEAVES = {"wi_gate": -1, "wi_up": -1, "wo": -2}
_ATTN_SUBLAYERS = ("attn", "self", "cross")
_MLP_SUBLAYERS = ("mlp", "shared")
_TP_KINDS = {"attn", "swa"}  # block kinds with psum gates (models.blocks)


@dataclasses.dataclass(frozen=True)
class TPPlan:
    """How one replica splits over ``n`` shards of mesh axis ``axis``.

    ``attn`` / ``mlp``: whether that component is sharded (False =
    replicated on every shard; the matching psum is disabled so replicated
    partial sums are not double-counted).  ``rules`` maps a component-
    qualified leaf suffix (``"attn/wo"``) to its slice axis; ``reasons``
    records why a component degraded to replication.
    """

    n: int
    axis: str = DEFAULT_AXIS
    attn: bool = False
    mlp: bool = False
    rules: Mapping[str, int] = dataclasses.field(default_factory=dict)
    reasons: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"shard count must be >= 1, got {self.n}")


def _rule_axis(name: str, stacked_shape: tuple[int, ...], axis: str, n: int) -> Optional[int]:
    """Slice axis the `parallel.sharding` rule table assigns ``name``.

    Resolved against a representative stacked path (how serving param trees
    name their leaves) and mapped back to a negative axis so the same rule
    applies to 2-D and scan-stacked 3-D leaves alike.  None = the table
    replicates this leaf at this mesh size.
    """
    spec = shrules._resolve(name, stacked_shape, {axis: n}, fsdp=False, fsdp_min=2**62)
    entries = tuple(spec)
    if axis not in entries:
        return None
    return entries.index(axis) - len(entries)


def plan_tp(cfg: ArchConfig, n: int, *, packed: bool = False, axis: str = DEFAULT_AXIS) -> TPPlan:
    """Plan an ``n``-way tensor-parallel split of ``cfg``.

    Per-component constraints (checked before consulting the rule table —
    leaf shapes alone would happily cut a grouped-query head in half):

    * attention: ``n_heads % n == 0`` and ``n_kv_heads % n == 0``; packed
      operands additionally need the row-parallel ``wo`` contraction slice
      ``(n_heads // n) * head_dim`` byte-aligned (``% 8``), since bit planes
      pack 8 rows per byte and shards slice stored bytes, never repack.
    * mlp: ``d_ff % n == 0``; packed needs ``(d_ff // n) % 8 == 0``.

    A failing component is *replicated* (never an error) with the reason
    recorded — the divisibility fallback law the property tests pin.
    """
    if n < 1:
        raise ValueError(f"shard count must be >= 1, got {n}")
    reasons: dict[str, str] = {}
    hd = cfg.resolved_head_dim
    kinds = set(cfg.layer_kinds())
    if cfg.encdec or not kinds <= _TP_KINDS:
        why = f"block kinds {sorted(kinds)} have no TP reduction gates"
        return TPPlan(n=n, axis=axis, reasons={"attn": why, "mlp": why})

    attn, mlp = True, True
    if cfg.n_heads % n:
        attn, reasons["attn"] = False, f"n_heads {cfg.n_heads} % {n} != 0"
    elif cfg.n_kv_heads % n:
        attn, reasons["attn"] = False, f"n_kv_heads {cfg.n_kv_heads} % {n} != 0"
    elif packed and ((cfg.n_heads // n) * hd) % 8:
        attn, reasons["attn"] = False, (
            f"packed wo K-slice {(cfg.n_heads // n) * hd} not byte-aligned"
        )
    if cfg.d_ff % n:
        mlp, reasons["mlp"] = False, f"d_ff {cfg.d_ff} % {n} != 0"
    elif packed and (cfg.d_ff // n) % 8:
        mlp, reasons["mlp"] = False, f"packed mlp K-slice {cfg.d_ff // n} not byte-aligned"

    # derive each leaf's slice axis from the rule table; any disagreement
    # (e.g. an axis-swap fallback moving the mesh axis somewhere this slicer
    # does not model) replicates the whole component
    shapes = {
        "attn/wq": (cfg.d_model, cfg.n_heads * hd),
        "attn/wk": (cfg.d_model, cfg.n_kv_heads * hd),
        "attn/wv": (cfg.d_model, cfg.n_kv_heads * hd),
        "attn/wo": (cfg.n_heads * hd, cfg.d_model),
        "mlp/wi_gate": (cfg.d_model, cfg.d_ff),
        "mlp/wi_up": (cfg.d_model, cfg.d_ff),
        "mlp/wo": (cfg.d_ff, cfg.d_model),
    }
    rules: dict[str, int] = {}
    for comp, leaves, on in (("attn", _ATTN_LEAVES, attn), ("mlp", _MLP_LEAVES, mlp)):
        if not on:
            continue
        want = {f"{comp}/{leaf}": ax for leaf, ax in leaves.items()}
        got = {
            key: _rule_axis(f"segments/0/{key}", (cfg.n_layers, *shapes[key]), axis, n)
            for key in want
        }
        if got != want:
            bad = sorted(k for k in want if got[k] != want[k])
            reasons[comp] = f"rule table resolves {bad} differently at n={n}"
            if comp == "attn":
                attn = False
            else:
                mlp = False
        else:
            rules.update(want)
    return TPPlan(n=n, axis=axis, attn=attn, mlp=mlp, rules=rules, reasons=reasons)


def local_config(cfg: ArchConfig, plan: TPPlan) -> ArchConfig:
    """The ArchConfig ONE shard runs: divided head/ff counts + psum gates.

    ``head_dim`` is pinned explicitly — its ``d_model // n_heads`` default
    would silently double under a halved head count.
    """
    kw: dict[str, Any] = {
        "tp_axis": plan.axis if (plan.attn or plan.mlp) else None,
        "tp_attn": plan.attn,
        "tp_mlp": plan.mlp,
    }
    if plan.attn:
        kw.update(
            n_heads=cfg.n_heads // plan.n,
            n_kv_heads=cfg.n_kv_heads // plan.n,
            head_dim=cfg.resolved_head_dim,
        )
    if plan.mlp:
        kw.update(d_ff=cfg.d_ff // plan.n)
    return dataclasses.replace(cfg, **kw)


def _leaf_rule(name: str, plan: TPPlan) -> Optional[int]:
    """Slice axis for a param leaf path, or None (replicated)."""
    parts = name.split("/")
    if len(parts) < 2:
        return None
    sub, leaf = parts[-2], parts[-1]
    if sub in _ATTN_SUBLAYERS:
        return plan.rules.get(f"attn/{leaf}")
    if sub in _MLP_SUBLAYERS:
        return plan.rules.get(f"mlp/{leaf}")
    return None


def shard_params(params: Any, plan: TPPlan, index: int) -> Any:
    """Materialize shard ``index``'s param tree.

    Dense leaves slice directly; packed/int8 CIM operand dicts route through
    ``simulator.shard_operands`` (stored-byte slicing, exact).  Replicated
    leaves are returned as-is (shared, not copied).
    """
    if not 0 <= index < plan.n:
        raise ValueError(f"shard index {index} outside [0, {plan.n})")
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: simulator.is_cim_operands(x)
    )
    out = []
    for path, leaf in flat:
        ax = _leaf_rule(shrules._path_name(path), plan)
        if ax is None or plan.n == 1:
            out.append(leaf)
        elif simulator.is_cim_operands(leaf):
            out.append(simulator.shard_operands(leaf, axis=ax, index=index, n=plan.n))
        else:
            dim = leaf.shape[ax]
            if dim % plan.n:
                raise ValueError(
                    f"{shrules._path_name(path)}: axis {ax} extent {dim} not "
                    f"divisible by {plan.n} (plan_tp should have replicated this)"
                )
            lo = index * (dim // plan.n)
            sl = [slice(None)] * leaf.ndim
            sl[ax] = slice(lo, lo + dim // plan.n)
            out.append(leaf[tuple(sl)])
    return jax.tree_util.tree_unflatten(treedef, out)


def stack_shards(shards: Sequence[Any]) -> Any:
    """Stack per-shard pytrees on a new leading shard axis."""
    return jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *shards)


def prepare_tp_params(params: Any, plan: TPPlan, prepare=None) -> Any:
    """Shard -> (optionally) prepare -> stack: the serving-ready TP tree.

    ``prepare`` defaults to ``steps.prepare_serving_params`` (the once-per-
    deployment packed->dense decompression on non-TPU backends).  Preparing
    AFTER slicing is exact: densify and stored-byte slicing commute.
    """
    if prepare is None:
        from repro.launch.steps import prepare_serving_params as prepare
    return stack_shards([prepare(shard_params(params, plan, i)) for i in range(plan.n)])


def tree_has_packed(params: Any) -> bool:
    """True if any leaf of ``params`` is a packed CIM operand dict."""
    found = False
    for leaf in jax.tree.leaves(
        params, is_leaf=lambda x: simulator.is_cim_operands(x)
    ):
        if simulator.is_cim_operands(leaf) and "planes_packed" in leaf:
            found = True
    return found


# ---------------------------------------------------------------------------
# SPMD execution of unmodified single-shard step functions
# ---------------------------------------------------------------------------

def _spmd(fn, plan: TPPlan, stacked_in: Sequence[bool], devices=None):
    """Run ``fn`` once per shard with ``plan.axis`` bound for its psums.

    ``stacked_in[i]`` marks positional arg ``i`` as carrying the leading
    shard axis (per-shard params / pools / caches); everything else is
    replicated (tokens, tables, keys).  Outputs all come back with the shard
    axis leading.

    ``devices=None`` -> ``jax.vmap`` with ``axis_name=plan.axis``: one
    device computes every shard, psum reduces over the vmap axis —
    numerically the SPMD program, bit-for-bit, which is what lets a
    single-CPU test pin multi-shard parity.  ``devices=[...]`` (len == n)
    -> ``shard_map`` over a 1-axis Mesh: shard i's slice lands on device i
    and psum lowers to a cross-device all-reduce.
    """
    if devices is None:
        in_axes = tuple(0 if s else None for s in stacked_in)
        return jax.vmap(fn, in_axes=in_axes, out_axes=0, axis_name=plan.axis)
    if len(devices) != plan.n:
        raise ValueError(f"need {plan.n} devices for {plan.n} shards, got {len(devices)}")
    mesh = Mesh(np.asarray(devices), (plan.axis,))
    in_specs = tuple(P(plan.axis) if s else P() for s in stacked_in)

    def body(*args):
        local = [
            jax.tree.map(lambda x: x[0], a) if s else a
            for a, s in zip(args, stacked_in)
        ]
        out = fn(*local)
        return jax.tree.map(lambda x: x[None], out)

    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(plan.axis))


def tp_step(fn, plan: TPPlan, stacked_in: Sequence[bool], stacked_out: Sequence[bool], devices=None):
    """Engine-step adapter: same signature as the unsharded step.

    Tuple outputs marked False in ``stacked_out`` are reduced to shard 0
    INSIDE the wrapper (they are replicated across shards — tokens, PRNG
    keys), so the engine's host scheduler reads exactly the shapes it
    always has; True outputs (the per-shard KV pools) keep their leading
    shard axis and flow back into the next dispatch.
    """
    inner = _spmd(fn, plan, stacked_in, devices)

    def wrapped(*args):
        out = inner(*args)
        return tuple(
            o if keep else jax.tree.map(lambda x: x[0], o)
            for o, keep in zip(out, stacked_out)
        )

    return wrapped


# ---------------------------------------------------------------------------
# Sharded lockstep generation (the serve.generate twin)
# ---------------------------------------------------------------------------

def make_tp_generator(
    cfg: ArchConfig, params: Any, batch, *, n: int, gen_len: int,
    greedy: bool = True, seed: int = 0, plan: Optional[TPPlan] = None,
    devices=None,
):
    """Compile an ``n``-way tensor-parallel prefill+decode pipeline.

    Mirrors ``serve.make_generator`` (same PRNG schedule, same sampling
    path, scan decode loop) with every dispatch ``_spmd``-wrapped; returns
    ``timed_run() -> (tokens, seconds)``.  Token streams match the solo
    single-device generator: bit-identical at ``n == 1`` (psum over a
    1-shard axis is the identity), and token-identical at ``n > 1`` — the
    repo's serving parity contract (logits only reassociate the psum).
    """
    import time

    from repro.launch.steps import cache_donation, make_decode_loop, make_prefill_step
    from repro.models import api

    if plan is None:
        plan = plan_tp(cfg, n, packed=tree_has_packed(params))
    elif plan.n != n:
        raise ValueError(f"plan is {plan.n}-way, asked for {n}")
    cfg_l = local_config(cfg, plan)
    tp_params = prepare_tp_params(params, plan)

    b, prompt_len = batch["tokens"].shape
    prefill = jax.jit(_spmd(make_prefill_step(cfg_l), plan, (True, False), devices))
    decode = jax.jit(
        _spmd(
            make_decode_loop(cfg_l, gen_len - 1, greedy=greedy),
            plan, (True, True, False, False, False), devices,
        ),
        donate_argnums=cache_donation(),
    )
    cache = jax.tree.map(
        lambda x: jnp.zeros((plan.n, *x.shape), x.dtype),
        api.init_cache(cfg_l, b, prompt_len + gen_len),
    )
    merge = jax.jit(
        _spmd(lambda c, pc: api.merge_prefill_cache(cfg_l, c, pc), plan, (True, True), devices)
    )
    key = jax.random.PRNGKey(seed)

    def pick(logits, key):
        if greedy:
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32), key
        key, sub = jax.random.split(key)
        return jax.random.categorical(sub, logits[:, -1])[:, None].astype(jnp.int32), key

    def run(key):
        logits, pf_cache = prefill(tp_params, batch)
        run_cache = merge(cache, pf_cache)
        # post-psum activations are replicated: every shard's logits are the
        # full unembedding, so shard 0 is THE logits (same for tokens below)
        tok, key = pick(logits[0], key)
        toks, _ = decode(tp_params, run_cache, tok, key, jnp.int32(prompt_len))
        tokens = jnp.concatenate([tok, toks[0]], axis=1)
        jax.block_until_ready(tokens)
        return tokens

    run(key)  # warmup: compile outside any timed region

    def timed_run():
        t0 = time.time()
        tokens = run(key)
        return tokens, time.time() - t0

    return timed_run


def tp_generate(
    cfg: ArchConfig, params: Any, batch, *, n: int, gen_len: int,
    greedy: bool = True, seed: int = 0, repeats: int = 1, plan: Optional[TPPlan] = None,
    devices=None,
):
    """Sharded twin of ``serve.generate``: returns (tokens, tok/s)."""
    timed_run = make_tp_generator(
        cfg, params, batch, n=n, gen_len=gen_len, greedy=greedy, seed=seed,
        plan=plan, devices=devices,
    )
    best = float("inf")
    for _ in range(max(1, repeats)):
        tokens, dt = timed_run()
        best = min(best, dt)
    return tokens, batch["tokens"].shape[0] * gen_len / best


# ---------------------------------------------------------------------------
# Per-shard crossbar pools + scrub coordination
# ---------------------------------------------------------------------------

def build_sharded_deployment(params: Any, spec, config, n: int, *, pools=None):
    """Deploy a model across ``n`` per-shard CrossbarPools.

    Pool *sections* live over SWS-sorted flat weights — a layout orthogonal
    to the serving (K, N) axes — so physical storage partitions by TENSOR,
    not by tensor-axis slice: eligible tensors round-robin across the shard
    pools in ``iter_weights`` order.  The per-tensor PRNG schedule is the
    global ``build_deployment`` schedule (one split per tensor in global
    iteration order), so under per-tensor pristine accounting
    (``pool.reset()`` between tensors, the planner's parity invariant (a))
    every tensor's plan — w_hat, stucking masks, transitions — is
    bit-identical to the unsharded deployment, and the summed wear of the
    shard pools equals the unsharded pool's exactly (the conservation law
    the TP battery pins).  With persistent pools the cross-tensor seams
    differ by construction — each tensor reprograms over a different
    predecessor than in the unsharded stream, exactly as two independent
    physical pools would — so only the PRNG schedule, not the achieved
    state, is partition-invariant there.

    Returns ``(plan, pools, owner)``: one merged DeploymentPlan covering
    every tensor (deploy_params-ready), the shard pools, and
    ``owner[name] -> shard`` for scrub/integrity routing.
    """
    from repro.core.planner import DeploymentPlan, analyze_tensor, iter_weights
    from repro.core.pool import CrossbarPool

    if n < 1:
        raise ValueError(f"shard count must be >= 1, got {n}")
    if pools is None:
        pools = [
            CrossbarPool(spec, config.crossbars, leveling=config.pool_leveling or "none")
            for _ in range(n)
        ]
    elif len(pools) != n:
        raise ValueError(f"need {n} pools, got {len(pools)}")
    key = jax.random.PRNGKey(config.seed)
    reports, deployed, owner = {}, {}, {}
    for i, (name, w) in enumerate(iter_weights(params, config)):
        key, sub = jax.random.split(key)
        report, w_hat = analyze_tensor(w, spec, config, sub, name=name, pool=pools[i % n])
        reports[name] = report
        deployed[name] = w_hat
        owner[name] = i % n
    plan = DeploymentPlan(spec=spec, config=config, reports=reports, deployed=deployed)
    return plan, pools, owner


class ShardedScrub:
    """Per-shard IntegrityManagers behind the ``Engine.attach_scrub`` duck
    type, with the round budget split round-robin so one mid-repair shard
    can never stall the replica: every ``scrub_round`` gives EVERY shard its
    budget slice (a shard deep in repairs spends its slice on repairs while
    the others keep scanning), and the merged report drives the engine's
    single repaired-plane refresh only once every shard is clean
    (``pending_faults`` sums across shards, and the engine refreshes at 0).
    """

    def __init__(self, managers: Sequence[Any]):
        if not managers:
            raise ValueError("ShardedScrub needs at least one IntegrityManager")
        self.managers = list(managers)
        self._next = 0  # rotate which shard scrubs first for budget fairness

    def pending_faults(self) -> int:
        return sum(m.pending_faults() for m in self.managers)

    def verify_all(self) -> bool:
        return all(m.verify_all() for m in self.managers)

    def scrub_round(self, budget_tiles: Optional[int] = None):
        n = len(self.managers)
        rep = None
        for j in range(n):
            m = self.managers[(self._next + j) % n]
            kw = {}
            if budget_tiles is not None:
                kw["budget_tiles"] = max(1, budget_tiles // n)
            r = m.scrub_round(**kw)
            if rep is None:
                rep = r
            else:
                # ScrubReport.merge treats ``pending`` as a level (last round
                # wins) — right for one manager over time, wrong across
                # DISTINCT pools, where the replica's pending work is the sum
                pend = rep.pending + r.pending
                rep.merge(r)
                rep.pending = pend
        self._next = (self._next + 1) % n
        return rep

    def rebuild_plan(self, plan):
        """Apply every shard's repaired reads onto one merged plan.

        Each manager only rebuilds tensors its own pool holds, so applying
        them in sequence touches disjoint ``deployed`` entries.
        """
        for m in self.managers:
            plan = m.rebuild_plan(plan)
        return plan
