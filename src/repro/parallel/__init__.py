"""Distribution: mesh construction, logical sharding rules, compression."""
