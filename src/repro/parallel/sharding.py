"""Name-rule param sharding with divisibility fallback (MaxText-style).

``param_sharding(params, mesh)`` walks a params pytree and assigns each leaf
a PartitionSpec from an ordered rule table keyed on the parameter's path.
Rules encode the Megatron-canonical tensor-parallel layout (column-parallel
up-projections, row-parallel down-projections, expert-parallel MoE); every
rule is checked for divisibility against the mesh axis size and degrades
through a fallback chain (alternate axis -> replicate), which is how e.g.
gemma-2b's 8 query heads survive a 16-way model axis (the head_dim=256 axis
shards instead via the fused (heads*head_dim) projection column).

Stacked layer parameters (under segments/encoder/decoder) get a leading
``None`` for the scan axis automatically.

``fsdp=True`` additionally shards the largest still-unsharded axis of big
params over the "data" axis (ZeRO-3 style) — a §Perf memory-term lever, off
in the paper-faithful baseline.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Ordered (regex, spec) rule table.  Spec entries name the *intended* mesh
# axis per tensor dim (ignoring the stacked-layer dim, handled separately);
# `None` means replicated.  Divisibility is enforced at resolution time.
_RULES: list[tuple[str, tuple[Optional[str], ...]]] = [
    # embeddings / unembeddings: shard the vocab axis
    (r"embed/table$", ("model", None)),
    (r"head/w$", (None, "model")),
    (r"meta$", (None, None)),
    # attention: column-parallel QKV, row-parallel output
    (r"(attn|self|cross)/wq$", (None, "model")),
    (r"(attn|self|cross)/wk$", (None, "model")),
    (r"(attn|self|cross)/wv$", (None, "model")),
    (r"(attn|self|cross)/wo$", ("model", None)),
    # MLA: the low-rank down-projections are row-parallel (input dim sharded;
    # the partial-sum all-reduce output is only rank-sized and cheap) so the
    # 60-layer latent projections don't replicate ~GBs per device.
    (r"mla/wq_a$", ("model", None)),
    (r"mla/wq_b$", (None, "model")),
    (r"mla/wkv_a$", ("model", None)),
    (r"mla/wk_b$", (None, "model")),
    (r"mla/wv_b$", (None, "model")),
    (r"mla/wo$", ("model", None)),
    # dense MLP / shared experts
    (r"(mlp|shared)/wi_gate$", (None, "model")),
    (r"(mlp|shared)/wi_up$", (None, "model")),
    (r"(mlp|shared)/wo$", ("model", None)),
    # routed experts: expert-parallel, fallback chain handles E % axis != 0
    (r"moe/wi_gate$", ("model", None, None)),
    (r"moe/wi_up$", ("model", None, None)),
    (r"moe/wo$", ("model", None, None)),
    (r"moe/router$", ("model", None)),  # row-parallel; (T, E) partial-sum AR is tiny
    # xLSTM / Mamba projections
    (r"w_up$", (None, "model")),
    (r"w_down$", ("model", None)),
    (r"(wq|wk|wv)$", (None, "model")),
    (r"in_proj$", (None, "model")),
    (r"out_proj$", ("model", None)),
    (r"x_proj$", ("model", None)),
    (r"dt_proj$", (None, "model")),
    (r"a_log$", ("model", None)),
    (r"d_skip$", ("model",)),
    (r"conv/w$", (None, "model")),
    (r"w_if$", (None, None)),
    (r"src_proj/w$", (None, "model")),
    (r"/w$", (None, "model")),  # generic dense (sLSTM fused gates, ...)
    (r"/r$", (None, None, "model")),
    (r"w_out$", ("model", None)),
    (r"dt_bias$", ("model",)),
]

_STACKED = re.compile(r"(^|/)(segments/\d+|encoder|decoder)(/|$)")

# MoE expert-parallel fallback: if E doesn't divide the model axis, shard the
# expert-ffn dim instead (TP within each expert) — DESIGN.md §5 (qwen 60e).
_MOE_FALLBACKS = {
    "moe/wi_gate": (None, None, "model"),
    "moe/wi_up": (None, None, "model"),
    "moe/wo": (None, "model", None),
}


def _path_name(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _fits(shape: tuple[int, ...], spec: tuple[Optional[str], ...], axis_sizes) -> bool:
    for dim, ax in zip(shape, spec):
        if ax is not None and dim % axis_sizes[ax] != 0:
            return False
    return True


def _resolve(
    name: str, shape: tuple[int, ...], axis_sizes: dict[str, int], *, fsdp: bool, fsdp_min: int
) -> P:
    stacked = bool(_STACKED.search(name))
    core_shape = shape[1:] if stacked else shape
    spec: tuple[Optional[str], ...] = tuple(None for _ in core_shape)
    for pat, rule in _RULES:
        if re.search(pat, name) and len(rule) == len(core_shape):
            candidates = [rule]
            for key, fb in _MOE_FALLBACKS.items():
                if name.endswith(key.split("/")[-1]) and key.split("/")[0] in name:
                    candidates.append(fb)
            # axis-swap fallback: if the intended dim is indivisible (e.g. a
            # 32001-row embedding on a 16-way axis), move the mesh axis to
            # another dim before giving up and replicating.
            used = [a for a in rule if a is not None]
            if len(used) == 1:
                ax = used[0]
                j = rule.index(ax)
                for i in range(len(core_shape)):
                    if i != j and rule[i] is None:
                        cand = list(rule)
                        cand[i], cand[j] = ax, None
                        candidates.append(tuple(cand))
            # generic fallback: drop the sharded axis entirely
            candidates.append(tuple(None for _ in core_shape))
            for cand in candidates:
                if _fits(core_shape, cand, axis_sizes):
                    spec = cand
                    break
            break
    spec = list(spec)
    if fsdp and "data" in axis_sizes and int(np.prod(core_shape)) >= fsdp_min:
        # ZeRO-3: shard the largest unsharded dim over "data"
        order = sorted(range(len(core_shape)), key=lambda i: -core_shape[i])
        for i in order:
            if spec[i] is None and core_shape[i] % axis_sizes["data"] == 0:
                spec[i] = "data"
                break
    if stacked:
        spec = [None, *spec]
    return P(*spec)


def param_pspecs(params: Any, mesh: Mesh, *, fsdp: bool = False, fsdp_min: int = 2**16):
    """PartitionSpec pytree matching ``params``."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec_for(path, leaf):
        return _resolve(_path_name(path), tuple(leaf.shape), axis_sizes, fsdp=fsdp, fsdp_min=fsdp_min)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(treedef, [spec_for(p, l) for p, l in flat])


def param_shardings(params: Any, mesh: Mesh, *, fsdp: bool = False):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_pspecs(params, mesh, fsdp=fsdp)
    )


# ---------------------------------------------------------------------------
# Activation / batch shardings
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes used for data parallelism (pod folds into data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_spec(mesh: Mesh, batch: int, ndim: int) -> P:
    """Spec for a (B, ...) input: batch over pod+data when divisible."""
    axes = batch_axes(mesh)
    size = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in axes]))
    if batch % size == 0:
        return P(axes, *(None,) * (ndim - 1))
    return P(*(None,) * ndim)


def cache_pspec(mesh: Mesh, shape: tuple[int, ...], axis_sizes: dict[str, int]) -> P:
    """Heuristic KV/state-cache sharding.

    Preference order: batch dim over pod+data; a heads-like dim over model;
    for unsharded-batch long-context caches, the sequence dim over data.
    shape layouts seen here: (L, B, H, S, D), (L, B, S, r), (B, H, D, D)...
    """
    axes = batch_axes(mesh)
    dp = int(np.prod([axis_sizes[a] for a in axes]))
    spec: list = [None] * len(shape)
    # find batch dim: first dim (or second when stacked-layer leading dim).
    # stacked caches always have ndim >= 3 with dim0 = n_layers.
    bdim = 1 if len(shape) >= 3 else 0
    sharded_batch = False
    if shape[bdim] % dp == 0:
        spec[bdim] = axes if len(axes) > 1 else axes[0]
        sharded_batch = True
    # model axis: largest remaining dim divisible by model size
    m = axis_sizes.get("model", 1)
    order = sorted(range(bdim + 1, len(shape)), key=lambda i: -shape[i])
    for i in order:
        if spec[i] is None and shape[i] % m == 0:
            spec[i] = "model"
            break
    if not sharded_batch:
        # long-context single-request: shard the longest remaining dim on data
        d = axis_sizes.get("data", 1)
        order = sorted(range(bdim + 1, len(shape)), key=lambda i: -shape[i])
        for i in order:
            if spec[i] is None and shape[i] % d == 0:
                spec[i] = "data"
                break
    return P(*spec)
