"""Int8 gradient compression with error feedback (collective-term lever).

At 1000+-node scale the gradient all-reduce dominates the collective term
of the train-step roofline; quantizing gradients to int8 before the
all-reduce cuts its wire bytes 4x vs f32 (2x vs bf16).  Plain quantization
biases updates, so we carry the quantization residual in an *error-feedback*
buffer (Karimireddy et al., 2019): the residual is added back before the
next quantization, making the scheme unbiased over time — training-loss
parity is asserted in tests/test_compression.py.

Implementation: per-leaf symmetric int8 with a per-leaf f32 scale.  The
all-reduce itself is driven by jit/GSPMD: compress -> psum(int32) ->
decompress happens inside the train step under shard_map, or — in the pure
pjit path used here — the compressed tensors simply make the GSPMD-inserted
all-reduce carry int8/int32 instead of f32 (the dry-run HLO shows the
narrower collective, EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _leaf_compress(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """-> (q int8, scale f32 scalar, new_err f32)."""
    g32 = g.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_err


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads: Any, err_state: Any) -> tuple[Any, Any, Any]:
    """Quantize a grad pytree -> (q_tree int8, scale_tree, new_err_state)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(err_state)[0]
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = _leaf_compress(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    un = jax.tree_util.tree_unflatten
    return un(treedef, qs), un(treedef, scales), un(treedef, errs)


def decompress(q_tree: Any, scale_tree: Any) -> Any:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree
    )


def compress_decompress(grads: Any, err_state: Any) -> tuple[Any, Any]:
    """One round-trip (the jit-visible form): grads' ~= grads, residual kept."""
    q, s, new_err = compress(grads, err_state)
    return decompress(q, s), new_err
