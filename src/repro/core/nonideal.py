"""Device-realistic non-idealities: stuck-at faults, drift, IR drop, remapping.

The paper's endurance accounting (and everything downstream of it in this
repo) assumes ideal crossbars: a programmed cell reads back exactly the bit
that was written.  Real memristive arrays do not behave this way — cells get
stuck at 0/1 (forming faults, endurance wear-out), conductances drift between
refresh cycles, and line resistance attenuates rows far from the driver
(IR drop).  X-CHANGR (see PAPERS.md) shows most of the resulting accuracy
loss is recoverable *without* repair hardware by remapping tensors across
crossbars so that important bits avoid known-faulty cells.

This module is the single home for those effects:

* ``FaultModel`` — the (deterministic, PRNG-keyed) fault distribution:
  stuck-at-0/1 rates, lognormal conductance drift sigma, IR-drop strength,
  and a hotspot mixture (a fraction of crossbars with multiplied fault
  rates — manufacturing variation, the setting where remapping pays).
* ``inject`` — sample a per-crossbar ``FaultState`` (packed stuck masks in
  the pool's canonical ``uint8[L, W, cols]`` layout).
* ``read_packed`` — the non-ideal read: ``(planes & ~stuck0) | stuck1``.
  At zero fault rate both masks are all-zero and the read is the identity,
  byte for byte — the zero-fault parity contract pinned by
  ``tests/test_nonideal.py``.
* ``damage_matrix`` / ``fault_aware_assignment`` — X-CHANGR-style
  chain→crossbar remapping: price the bit flips each chain would suffer on
  each physical crossbar (weighted by bit significance 2**col) and greedily
  steer the most damage-sensitive chains to the cleanest crossbars.
  Exposed as pool leveling ``"fault"``; the remap is priced through the
  ordinary ``price_pairs`` seam machinery, so it counts toward
  reprogramming cost like any other assignment.
* ``perturb_operands`` — the serving-side twin: perturb a packed operand
  dict (``simulator.packed_operands`` layout) with stuck masks, per-plane
  drift gains, and a deterministic IR-drop row attenuation, consumed by
  ``simulator.cim_linear`` / ``densify_operands`` so faulted serving and
  faulted pool reads share one arithmetic.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # CrossbarSpec lives in planner; avoid the import cycle
    from repro.core.planner import CrossbarSpec


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Fault distribution of a crossbar population (all rates per cell).

    ``stuck0``/``stuck1`` are stuck-at rates for magnitude bit cells (sign
    bits live in the digital periphery here, as in the paper's
    sign-magnitude arrays).  ``drift_sigma`` is the sigma of a lognormal
    per-bit-line conductance gain ``exp(sigma * N(0,1))``; ``ir_alpha``
    scales a deterministic monotone row attenuation ``1/(1 + alpha*r/R)``
    modelling line resistance.  ``hotspot_fraction`` of crossbars have
    their stuck rates multiplied by ``hotspot_mult`` (clipped to 1) —
    the heterogeneous-yield setting where fault-aware remapping wins.
    """

    stuck0: float = 0.0
    stuck1: float = 0.0
    drift_sigma: float = 0.0
    ir_alpha: float = 0.0
    hotspot_fraction: float = 0.0
    hotspot_mult: float = 1.0

    def __post_init__(self):
        # fail loudly at construction: a rate outside [0, 1] would silently
        # clip (or invert) inside the Bernoulli draws, and a negative
        # multiplier/sigma would produce nonsense masks downstream — every
        # entry point (pool.inject_faults, perturb_operands) goes through a
        # FaultModel, so this is the one validation choke point
        for field in ("stuck0", "stuck1", "hotspot_fraction"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultModel.{field} must be in [0, 1], got {v}")
        for field in ("drift_sigma", "ir_alpha"):
            v = getattr(self, field)
            if v < 0.0:
                raise ValueError(f"FaultModel.{field} must be >= 0, got {v}")
        if self.hotspot_mult < 0.0:
            raise ValueError(
                f"FaultModel.hotspot_mult must be >= 0, got {self.hotspot_mult}"
            )

    @property
    def ideal(self) -> bool:
        """True when every non-ideality is off (reads are exact)."""
        return (
            self.stuck0 == 0.0
            and self.stuck1 == 0.0
            and self.drift_sigma == 0.0
            and self.ir_alpha == 0.0
        )


@dataclasses.dataclass
class FaultState:
    """Sampled fault realization for one pool of ``L`` crossbars."""

    model: FaultModel
    stuck0: jax.Array  # uint8[L, W, cols] packed mask: cell reads 0
    stuck1: jax.Array  # uint8[L, W, cols] packed mask: cell reads 1
    hot: np.ndarray  # bool[L] which crossbars drew the hotspot multiplier

    def fault_cells(self) -> np.ndarray:
        """Faulty cells per crossbar -> int64[L] (for reports/benchmarks)."""
        both = jnp.unpackbits(self.stuck0 | self.stuck1, axis=1)
        return np.asarray(jnp.sum(both.astype(jnp.int32), axis=(1, 2)), np.int64)


def _pack_bits(bits: jax.Array) -> jax.Array:
    """bool[..., R, cols] -> uint8[..., ceil(R/8), cols] (pool byte order)."""
    return jnp.packbits(bits.astype(jnp.uint8), axis=-2)


def inject(
    spec: "CrossbarSpec", n_crossbars: int, model: FaultModel, key: jax.Array
) -> FaultState:
    """Sample a deterministic per-crossbar fault realization.

    Masks come back packed exactly like ``CrossbarPool`` state
    (``uint8[L, W, cols]``, rows MSB-first per byte); padding rows beyond
    ``spec.rows`` are forced fault-free so packed-word identities hold.
    Stuck-at-1 cells are made disjoint from stuck-at-0 (a cell has one
    defect); hotspot crossbars multiply both rates.
    """
    rows, cols = spec.rows, spec.cols
    words = -(-rows // 8)
    kh, k0, k1 = jax.random.split(key, 3)
    hot = jax.random.bernoulli(kh, float(model.hotspot_fraction), (n_crossbars,))
    mult = jnp.where(hot, float(model.hotspot_mult), 1.0)
    r0 = jnp.clip(float(model.stuck0) * mult, 0.0, 1.0)[:, None, None]
    r1 = jnp.clip(float(model.stuck1) * mult, 0.0, 1.0)[:, None, None]
    shape = (n_crossbars, words * 8, cols)
    valid = (jnp.arange(words * 8) < rows)[None, :, None]
    s0 = jax.random.bernoulli(k0, shape=shape, p=jnp.broadcast_to(r0, shape)) & valid
    s1 = jax.random.bernoulli(k1, shape=shape, p=jnp.broadcast_to(r1, shape)) & valid
    s1 = s1 & ~s0
    return FaultState(
        model=model, stuck0=_pack_bits(s0), stuck1=_pack_bits(s1),
        hot=np.asarray(hot),
    )


def read_packed(planes: jax.Array, stuck0: jax.Array, stuck1: jax.Array) -> jax.Array:
    """Non-ideal read of packed planes: stuck-at-0 clears, stuck-at-1 sets.

    With all-zero masks this is the bitwise identity — the zero-fault
    parity pin.  Shapes broadcast, so one mask can serve a batch of
    sections or one section per crossbar.
    """
    return (planes & ~stuck0) | stuck1


# ---------------------------------------------------------------------------
# X-CHANGR-style fault-aware remapping
# ---------------------------------------------------------------------------

def damage_matrix(
    packed: jax.Array,
    chains: Sequence[np.ndarray],
    state: FaultState,
) -> np.ndarray:
    """Significance-weighted bit-flip damage of every chain on every crossbar.

    ``damage[j, l]`` = sum over the sections of chain ``j`` of the bits a
    read from crossbar ``l`` would flip — stuck-at-0 cells holding a 1
    (``packed & stuck0``) plus stuck-at-1 cells holding a 0
    (``~packed & stuck1``) — each flip weighted ``2**col`` so high-order
    bit columns dominate, exactly the quantity remapping should minimize.
    Returns host ``int64[Lc, L]``.
    """
    s0, s1 = state.stuck0, state.stuck1
    flips = (packed[:, None] & s0[None]) | (~packed[:, None] & s1[None])
    pop = jax.lax.population_count(flips).astype(jnp.int32).sum(axis=2)  # [S, L, cols]
    w = 2 ** jnp.arange(pop.shape[-1], dtype=jnp.int32)
    per_sec = np.asarray(jnp.sum(pop * w, axis=-1), np.int64)  # [S, L]
    return np.stack([per_sec[np.asarray(c)].sum(axis=0) for c in chains])


def fault_aware_assignment(
    damage: np.ndarray, wear: np.ndarray | None = None
) -> np.ndarray:
    """Greedy chain→crossbar assignment minimizing read damage.

    Chains are seated in descending order of damage *spread* (the chain
    with the most to lose from a bad crossbar chooses first); each takes
    the free crossbar with minimum damage, ties broken toward least wear,
    then lowest index.  With zero damage everywhere (no faults) and no
    wear skew this degenerates to the identity assignment, so the
    ``"fault"`` leveling is a strict superset of ``"none"``.
    Returns ``int32[Lc]`` distinct crossbar ids.
    """
    lc, l = damage.shape
    if lc > l:
        raise ValueError(f"{lc} chains for {l} crossbars")
    wear = np.zeros(l, np.int64) if wear is None else np.asarray(wear, np.int64)
    spread = damage.max(axis=1) - damage.min(axis=1)
    order = np.argsort(-spread, kind="stable")
    free = np.ones(l, dtype=bool)
    out = np.zeros(lc, np.int32)
    for j in order:
        cand = np.flatnonzero(free)
        best = cand[np.lexsort((cand, wear[cand], damage[j, cand]))[0]]
        out[j] = best
        free[best] = False
    return out


# ---------------------------------------------------------------------------
# Serving-side perturbation (packed operand dicts)
# ---------------------------------------------------------------------------

def perturb_operands(
    op: dict[str, jax.Array], model: FaultModel, key: jax.Array
) -> dict[str, jax.Array]:
    """Perturb a packed serving operand dict with the model's non-idealities.

    Adds ``stuck0_packed``/``stuck1_packed`` masks in the serving plane
    layout (``uint8[..., cols, ceil(K/8), N]``), a lognormal per-bit-line
    ``plane_gain`` ``f32[..., cols, N]``, and a deterministic IR-drop
    ``row_atten`` ``f32[..., K]`` — all consumed by ``simulator.cim_linear``
    and ``simulator.densify_operands`` with identical arithmetic.  An
    ``ideal`` model returns ``op`` unchanged (same object), so the
    zero-fault serving graph is literally the clean graph.  Hotspot
    mixture does not apply here: serving operands carry no crossbar
    identity (that lives in the pool path).

    Codec-encoded operands (``core.planes.encode_operands``) perturb in
    their *stored* layout: masks and gains attach to physical planes as the
    hardware would, and logical decode (``plane_ids`` significance) happens
    after the masked read — consumers apply stuck masks first, then decode
    (post-decode fault semantics; see ``simulator.densify_operands``).
    Perturb AFTER encoding for this composition to hold.
    """
    if "planes_packed" not in op:
        raise ValueError("perturb_operands expects packed serving operands")
    if model.ideal:
        return op
    planes = op["planes_packed"]  # [..., cols, Wk, N]
    lead = planes.shape[:-3]
    cols, wk, n = planes.shape[-3:]
    k = op["kdim"].shape[-2]
    k0, k1, kg = jax.random.split(key, 3)
    out = dict(op)
    if model.stuck0 > 0.0 or model.stuck1 > 0.0:
        shape = lead + (cols, wk * 8, n)
        valid = (jnp.arange(wk * 8) < k)[:, None]
        s0 = jax.random.bernoulli(k0, min(model.stuck0, 1.0), shape) & valid
        s1 = jax.random.bernoulli(k1, min(model.stuck1, 1.0), shape) & valid & ~s0
        out["stuck0_packed"] = _pack_bits(s0)
        out["stuck1_packed"] = _pack_bits(s1)
    if model.drift_sigma > 0.0:
        out["plane_gain"] = jnp.exp(
            float(model.drift_sigma) * jax.random.normal(kg, lead + (cols, n))
        )
    if model.ir_alpha > 0.0:
        atten = 1.0 / (
            1.0 + float(model.ir_alpha) * jnp.arange(k, dtype=jnp.float32) / max(k - 1, 1)
        )
        out["row_atten"] = jnp.broadcast_to(atten, lead + (k,))
    return out
