"""Sorted Weight Sectioning (SWS) for crossbar reprogramming (§III of the paper).

Weights are sorted by magnitude *once, offline*, then partitioned into
crossbar-sized sections.  Consecutive sections in the sorted list hold weights
of near-identical magnitude, hence near-identical high-order bit patterns, so
programming them in order minimizes memristor state transitions.

Inference correctness is preserved by *index matching*: we keep the sort
permutation and its inverse so the deployed (permuted) flat weight vector can
be scattered back into the logical weight layout.  The paper notes this
requires an input buffer in hardware; in simulation it is an exact gather.

Beyond-paper (§7 of DESIGN.md): ``tsp_greedy_order`` replaces the magnitude
sort's *section order* with a nearest-neighbour walk on actual bit-pattern
Hamming distance — magnitude sorting is a proxy for this objective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitslice, cost


def sws_permutation(flat: jax.Array, *, descending: bool = False) -> jax.Array:
    """Sort permutation by |w| (ascending by default: small -> large).

    The direction does not change total chain cost (it reverses the chain);
    ascending matches the paper's Fig. 2 narrative of gradual small-to-large
    transitions.
    """
    key = jnp.abs(flat)
    if descending:
        key = -key
    return jnp.argsort(key, stable=True)


def inverse_permutation(perm: jax.Array) -> jax.Array:
    inv = jnp.zeros_like(perm)
    return inv.at[perm].set(jnp.arange(perm.shape[0], dtype=perm.dtype))


def sorted_sections(
    flat: jax.Array, rows: int, *, descending: bool = False
) -> tuple[jax.Array, jax.Array, int]:
    """Sort + section: returns (sections[S, rows], perm[n], n)."""
    perm = sws_permutation(flat, descending=descending)
    sections, n = bitslice.section(flat[perm], rows)
    return sections, perm, n


def restore_flat(sections: jax.Array, perm: jax.Array, n: int) -> jax.Array:
    """Undo sort + section: sections[S, rows] -> flat[n] in logical order."""
    sorted_flat = bitslice.unsection(sections, n)
    return sorted_flat[inverse_permutation(perm)]


def tsp_greedy_order(packed_planes: jax.Array, *, start: int = 0) -> jax.Array:
    """Beyond-paper: nearest-neighbour section order on true Hamming distance.

    packed_planes: uint8[S, words, cols] (from ``bitslice.pack_rows``).
    Returns an int32[S] visiting order.  O(S^2) distance evaluations done as a
    scan with a masked argmin; intended for per-tensor section counts up to a
    few thousand (typical LM matrices at rows=128).
    """
    s = packed_planes.shape[0]
    flat = packed_planes.reshape(s, -1)

    def dist_from(i):
        x = jax.lax.population_count(jnp.bitwise_xor(flat, flat[i][None, :]))
        return jnp.sum(x.astype(jnp.int32), axis=-1)

    def step(carry, _):
        current, visited = carry
        d = dist_from(current)
        d = jnp.where(visited, jnp.iinfo(jnp.int32).max, d)
        nxt = jnp.argmin(d).astype(jnp.int32)
        return (nxt, visited.at[nxt].set(True)), nxt

    visited0 = jnp.zeros((s,), dtype=jnp.bool_).at[start].set(True)
    (_, _), rest = jax.lax.scan(step, (jnp.int32(start), visited0), None, length=s - 1)
    return jnp.concatenate([jnp.array([start], dtype=jnp.int32), rest])


def section_norm_order(sections: jax.Array, *, descending: bool = False) -> jax.Array:
    """Order *pre-formed* sections by mean |w| (scheduling-only SWS variant).

    Used when the weight layout cannot be permuted element-wise (no index
    matching hardware): sections keep their natural membership and only the
    programming order is sorted.  Weaker than full SWS; provided for ablation.
    """
    key = jnp.mean(jnp.abs(sections), axis=-1)
    if descending:
        key = -key
    return jnp.argsort(key, stable=True)
