"""Sorted Weight Sectioning (SWS) for crossbar reprogramming (§III of the paper).

Weights are sorted by magnitude *once, offline*, then partitioned into
crossbar-sized sections.  Consecutive sections in the sorted list hold weights
of near-identical magnitude, hence near-identical high-order bit patterns, so
programming them in order minimizes memristor state transitions.

Inference correctness is preserved by *index matching*: we keep the sort
permutation and its inverse so the deployed (permuted) flat weight vector can
be scattered back into the logical weight layout.  The paper notes this
requires an input buffer in hardware; in simulation it is an exact gather.

Beyond-paper (§7 of DESIGN.md): ``tsp_greedy_order`` replaces the magnitude
sort's *section order* with a nearest-neighbour walk on actual bit-pattern
Hamming distance — magnitude sorting is a proxy for this objective.  It
operates on the planner's canonical *packed* uint8 planes
(``bitslice.section_planes_packed``); bool planes are packed on entry.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitslice, cost


_SORT_POOL = None  # lazily-created 2-thread pool for the split host sort
_SPLIT_SORT_MIN = 1 << 18  # below this, one np.argsort call wins


def _split_stable_argsort(keys: np.ndarray) -> np.ndarray:
    """Stable argsort via two threaded half-sorts + a vectorized stable merge.

    numpy's sort releases the GIL, so the two halves run truly in parallel.
    The merge ranks with ``searchsorted`` — ``side='left'`` for the left
    half, ``side='right'`` for the right half — which reproduces exactly the
    left-first tie order of a single stable sort.
    """
    global _SORT_POOL
    if _SORT_POOL is None:
        from concurrent.futures import ThreadPoolExecutor

        _SORT_POOL = ThreadPoolExecutor(max_workers=2)
    n = keys.shape[0]
    mid = n // 2
    lo, hi = keys[:mid], keys[mid:]
    f_lo = _SORT_POOL.submit(np.argsort, lo, kind="stable")
    p_hi = np.argsort(hi, kind="stable")
    p_lo = f_lo.result()
    k_lo, k_hi = lo[p_lo], hi[p_hi]
    pos_lo = np.searchsorted(k_hi, k_lo, side="left") + np.arange(mid, dtype=np.int64)
    pos_hi = np.searchsorted(k_lo, k_hi, side="right") + np.arange(n - mid, dtype=np.int64)
    perm = np.empty(n, dtype=np.int32)
    perm[pos_lo] = p_lo.astype(np.int32)
    perm[pos_hi] = (p_hi + mid).astype(np.int32)
    return perm


def _host_stable_argsort(nonneg: bool, with_inverse: bool):
    def cb(keys: np.ndarray):
        if nonneg and keys.dtype == np.float32 and not np.isnan(np.max(keys)):
            # Non-negative IEEE floats order like their bit patterns, and
            # numpy sorts uint32 keys measurably faster than float32.  NaNs
            # force the float path: a float stable sort treats all NaNs as
            # tied (original order kept) while bit patterns would order them
            # by payload, silently changing the permutation vs the device
            # sort.  (np.max propagates NaN, so this is a single cheap pass.)
            keys = np.ascontiguousarray(keys).view(np.uint32)
        if keys.shape[0] >= _SPLIT_SORT_MIN:
            perm = _split_stable_argsort(keys)
        else:
            perm = np.argsort(keys, kind="stable").astype(np.int32)
        if not with_inverse:
            return (perm,)
        inv = np.empty_like(perm)
        inv[perm] = np.arange(perm.shape[0], dtype=np.int32)
        return perm, inv

    return cb


def _usable_cores() -> int:
    """Host cores actually available to THIS process.

    ``sched_getaffinity`` respects container/cgroup CPU masks where
    ``os.cpu_count()`` reports the whole machine; a process pinned to one
    core must take the device sort (see :func:`_use_host_sort`) no matter
    how many cores the box has.
    """
    try:
        return len(os.sched_getaffinity(0)) or 1
    except (AttributeError, OSError):  # non-Linux
        return os.cpu_count() or 1


def _use_host_sort() -> bool:
    """Route to the numpy host-callback sort?

    Keys on the CPU backend AND actual execution threads (>1 usable core).
    Deliberately independent of ``jax.device_count()``: a host-emulated
    device mesh (``--xla_force_host_platform_device_count=N``) multiplies
    *devices*, not cores — N emulated devices on one core still deadlock a
    pending pure_callback exactly like the plain single-core case, and
    conversely one real device on many cores is safe.  Pinned by the
    regression test under the emulated mesh (tests/test_sws.py).
    """
    return jax.default_backend() == "cpu" and _usable_cores() > 1


def stable_argsort(
    keys: jax.Array, *, with_inverse: bool = False, nonneg: bool = False
) -> jax.Array:
    """Stable ascending argsort (+ optional inverse), fastest available route.

    On the CPU backend this is a ``pure_callback`` into numpy — XLA:CPU's
    comparison sort is ~4x slower than numpy's stable sort on large arrays,
    and computing the inverse on the host turns the planner's reconstruction
    scatter into a cheap gather.  On TPU/GPU the sort stays on-device.  Both
    routes are *stable*, so they yield the identical permutation — callers
    may mix them freely without changing any downstream result.  ``nonneg``
    asserts the keys are >= 0 (or NaN), unlocking a faster integer-keyed
    host sort with the same ordering (NaNs still sort last).

    Single-CPU hosts take the device route even on the CPU backend: with
    one execution thread, a pending host callback inside one dispatch can
    deadlock against a blocking wait on another (observed as a futex hang
    in the planner's pool path), and the callback's throughput advantage
    needs a second core anyway.  The routing guard (:func:`_use_host_sort`)
    counts usable HOST cores, never ``jax.device_count()`` — emulated
    host-platform devices add execution streams without adding the second
    core the callback needs.
    """
    if _use_host_sort():
        out_shapes = (jax.ShapeDtypeStruct(keys.shape, jnp.int32),) * (
            2 if with_inverse else 1
        )
        out = jax.pure_callback(
            _host_stable_argsort(nonneg, with_inverse),
            out_shapes,
            keys,
            vmap_method="sequential",
        )
        perm = out[0]
        inv = out[1] if with_inverse else perm
    else:
        perm = jnp.argsort(keys, stable=True).astype(jnp.int32)
        inv = inverse_permutation(perm) if with_inverse else perm
    return (perm, inv) if with_inverse else perm


def sws_permutation(flat: jax.Array, *, descending: bool = False) -> jax.Array:
    """Sort permutation by |w| (ascending by default: small -> large).

    The direction does not change total chain cost (it reverses the chain);
    ascending matches the paper's Fig. 2 narrative of gradual small-to-large
    transitions.
    """
    key = jnp.abs(flat)
    if descending:
        key = -key
    return stable_argsort(key, nonneg=not descending)


def inverse_permutation(perm: jax.Array) -> jax.Array:
    inv = jnp.zeros_like(perm)
    return inv.at[perm].set(jnp.arange(perm.shape[0], dtype=perm.dtype))


def sorted_sections(
    flat: jax.Array, rows: int, *, descending: bool = False
) -> tuple[jax.Array, jax.Array, int]:
    """Sort + section: returns (sections[S, rows], perm[n], n)."""
    perm = sws_permutation(flat, descending=descending)
    sections, n = bitslice.section(flat[perm], rows)
    return sections, perm, n


def restore_flat(sections: jax.Array, perm: jax.Array, n: int) -> jax.Array:
    """Undo sort + section: sections[S, rows] -> flat[n] in logical order."""
    sorted_flat = bitslice.unsection(sections, n)
    return sorted_flat[inverse_permutation(perm)]


def tsp_greedy_order(packed_planes: jax.Array, *, start: int = 0) -> jax.Array:
    """Beyond-paper: nearest-neighbour section order on true Hamming distance.

    packed_planes: uint8[S, words, cols] (from ``bitslice.pack_rows`` /
    ``bitslice.section_planes_packed``); bool[S, rows, cols] is packed here.
    Returns an int32[S] visiting order.  O(S^2) distance evaluations done as a
    scan with a masked argmin; intended for per-tensor section counts up to a
    few thousand (typical LM matrices at rows=128).
    """
    if packed_planes.dtype != jnp.uint8:
        packed_planes = bitslice.pack_rows(packed_planes)
    s = packed_planes.shape[0]
    flat = packed_planes.reshape(s, -1)

    def dist_from(i):
        x = jax.lax.population_count(jnp.bitwise_xor(flat, flat[i][None, :]))
        return jnp.sum(x.astype(jnp.int32), axis=-1)

    def step(carry, _):
        current, visited = carry
        d = dist_from(current)
        d = jnp.where(visited, jnp.iinfo(jnp.int32).max, d)
        nxt = jnp.argmin(d).astype(jnp.int32)
        return (nxt, visited.at[nxt].set(True)), nxt

    visited0 = jnp.zeros((s,), dtype=jnp.bool_).at[start].set(True)
    (_, _), rest = jax.lax.scan(step, (jnp.int32(start), visited0), None, length=s - 1)
    return jnp.concatenate([jnp.array([start], dtype=jnp.int32), rest])


def section_norm_order(sections: jax.Array, *, descending: bool = False) -> jax.Array:
    """Order *pre-formed* sections by mean |w| (scheduling-only SWS variant).

    Used when the weight layout cannot be permuted element-wise (no index
    matching hardware): sections keep their natural membership and only the
    programming order is sorted.  Weaker than full SWS; provided for ablation.
    """
    key = jnp.mean(jnp.abs(sections), axis=-1)
    if descending:
        key = -key
    return jnp.argsort(key, stable=True)
