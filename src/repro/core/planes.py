"""Pluggable plane codecs: how packed bit planes are *stored* on crossbars.

Everything upstream of this module treats the canonical packed planes
(``uint8[S, W, cols]``, ``bitslice.section_planes_packed``) as the literal
crossbar content.  That is one point in a design space: the column-similarity
reordering line of work (PAPERS.md, arXiv:2511.14202) stores each section's
bit columns in a *permuted* physical order so consecutive reprograms realign
similar columns onto the same bit line, and the near-constant high-order
planes that Sorted Weight Sectioning concentrates can be stored as one byte
plus a flag instead of ``W`` words.  This module makes the stored
representation an explicit, pluggable layer:

* ``PlaneSet`` — a pytree carrying the codec id, the stored payload words,
  and per-tile metadata (column orders, constant-tile flags/values).
* ``encode`` / ``PlaneSet.decode`` — the standing contract is byte identity:
  ``decode(encode(planes)) == planes`` for every codec (pinned by
  ``tests/test_planes.py``).
* ``PlaneSet.physical`` — the dense words the crossbar *actually holds*
  (for ``col_perm`` that is the permuted layout — which is where the
  reprogramming-transition reduction physically comes from; for the
  ``const_rle`` codecs it is the reconstructed full planes).  The pool
  prices seams, counts wear, and applies fault masks on these physical
  bits, so endurance accounting stays exact under every codec; logical
  planes are recovered *after* the (possibly faulty) read via
  ``logical_from_physical``.

Codecs:

* ``raw``        — identity: payload is the canonical packed planes.
* ``const_rle``  — constant-plane run-length: a (section, column) tile whose
  ``W`` payload bytes are all equal is stored as (flag, value) and its words
  are elided from the payload (zeroed here; ``payload_bytes`` prices the
  elision).  SWS makes high-order planes constant-zero for most sections, so
  this is where the deployment weight-traffic saving concentrates.
* ``col_perm``   — per-section column permutation: along each programming
  chain, a greedy minimum-cost matching (priced through the ordinary
  ``price_pairs`` Hamming path) chooses which logical plane each physical
  bit line stores so consecutive reprograms toggle fewer cells.  A chain
  keeps its permutations only when they beat the identity layout, so encoded
  transitions never exceed raw (the ``>= 1.0x`` CI gate is structural).
* ``col_perm_rle`` — ``col_perm`` then ``const_rle`` on the permuted words
  (transition reduction and payload compression together).

Serving-side twins (``encode_operands`` / operand dicts): the serving layout
(``uint8[..., cols, ceil(K/8), N]``) gets a plane-axis permutation
(``plane_ids``) and zero-tile flags (``plane_tile_nz``) consumed by
``kernels/cim_matmul`` (tile skipping) and ``simulator`` (decode), with the
same exactness contract: encoded operands densify/serve bit-identically to
raw ones.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedule
from repro.kernels.hamming import ops as hamming_ops

CODECS = ("raw", "const_rle", "col_perm", "col_perm_rle")

# serving-side zero-tile granularity: 16 packed bytes = 128 weight rows, the
# packed kernel's K block (ops.cim_matmul_packed, bk=128), so one flag maps
# to exactly one kernel tile
OPERAND_TILE_BYTES = 16


def _check_codec(codec: str) -> None:
    if codec not in CODECS:
        raise ValueError(f"unknown plane codec {codec!r}; choose from {CODECS}")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PlaneSet:
    """One tensor's sections in a codec-defined stored representation.

    ``payload`` is ``uint8[S, W, cols]`` stored words: for the ``*_rle``
    codecs, constant tiles are elided (zeroed) from it and carried in
    (``const_mask``, ``const_val``); for ``col_perm*``, stored column ``j``
    of section ``s`` holds logical plane ``col_order[s, j]``.
    """

    codec: str  # static
    payload: jax.Array  # uint8[S, W, cols]
    col_order: jax.Array | None = None  # int32[S, cols] stored pos -> logical plane
    const_mask: jax.Array | None = None  # bool[S, cols] tile is constant
    const_val: jax.Array | None = None  # uint8[S, cols] the constant byte

    def tree_flatten(self):
        return (self.payload, self.col_order, self.const_mask, self.const_val), (self.codec,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        payload, col_order, const_mask, const_val = children
        return cls(aux[0], payload, col_order, const_mask, const_val)

    # -- the two views ------------------------------------------------------

    def physical(self) -> jax.Array:
        """Dense stored words the crossbar holds -> uint8[S, W, cols].

        The pool programs, prices, wears, and fault-masks exactly these bits.
        For ``raw``/``col_perm`` this is the payload itself (same array —
        the raw path stays bit-identical by construction); the ``*_rle``
        codecs re-broadcast their constant tiles.
        """
        if self.const_mask is None:
            return self.payload
        const = jnp.broadcast_to(self.const_val[:, None, :], self.payload.shape)
        return jnp.where(self.const_mask[:, None, :], const, self.payload)

    def decode(self) -> jax.Array:
        """Logical canonical packed planes — byte-identical to the encoder
        input for every codec (the round-trip contract)."""
        return logical_from_physical(self.physical(), self.col_order)

    # -- accounting ---------------------------------------------------------

    def compression_stats(self) -> dict[str, int | float]:
        """Stored-representation size: payload words kept, metadata bytes.

        ``payload_bytes`` counts ``W`` bytes per non-elided (section, column)
        tile; ``meta_bytes`` prices the sideband exactly (1 byte per stored
        column order entry, 1 bit per constant flag, 1 byte per constant
        value).  ``raw_bytes`` is the uncompressed ``S * W * cols``.
        """
        s, w, cols = self.payload.shape
        raw_bytes = s * w * cols
        if self.const_mask is not None:
            kept = int(np.sum(~np.asarray(self.const_mask)))
            n_const = s * cols - kept
            payload_bytes = kept * w
            meta_bytes = -(-s * cols // 8) + n_const
        else:
            payload_bytes = raw_bytes
            meta_bytes = 0
        if self.col_order is not None:
            meta_bytes += s * cols
        total = payload_bytes + meta_bytes
        return {
            "raw_bytes": raw_bytes,
            "payload_bytes": payload_bytes,
            "meta_bytes": meta_bytes,
            "total_bytes": total,
            "ratio_vs_raw": raw_bytes / max(total, 1),
        }


def logical_from_physical(physical: jax.Array, col_order: jax.Array | None) -> jax.Array:
    """Invert a column permutation on dense stored words.

    The decode direction for whatever came back from the crossbar — the
    target planes or a (possibly stucked / fault-masked) ``achieved_read``:
    masks apply to the *stored* layout first, logical recovery happens after
    the read, mirroring the hardware order of operations.
    """
    if col_order is None:
        return physical
    # col_order is a permutation per section, so argsort is its inverse:
    # logical column c lives at stored position inv[c]
    inv = jnp.argsort(col_order, axis=-1).astype(jnp.int32)
    return jnp.take_along_axis(physical, inv[:, None, :], axis=2)


# ---------------------------------------------------------------------------
# Encoders
# ---------------------------------------------------------------------------

def _const_tiles(payload: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Detect constant (section, column) tiles: all ``W`` bytes equal."""
    mask = jnp.all(payload == payload[:, :1, :], axis=1)  # bool[S, cols]
    val = payload[:, 0, :]  # uint8[S, cols]
    elided = jnp.where(mask[:, None, :], jnp.uint8(0), payload)
    return elided, mask, val


def encode(
    packed: jax.Array,
    codec: str,
    *,
    chains: list[np.ndarray] | None = None,
    pin_cols: int = 0,
) -> PlaneSet:
    """Canonical packed planes ``uint8[S, W, cols]`` -> :class:`PlaneSet`.

    ``col_perm*`` needs ``chains`` (the programming schedule) — the column
    orders are planned along them, against each section's actual
    predecessor; ``pin_cols`` keeps the lowest columns at identity (see
    :func:`plan_col_order` — required under bit stucking).
    ``decode(encode(p)) == p`` byte-for-byte for every codec.
    """
    _check_codec(codec)
    packed = jnp.asarray(packed)
    if packed.dtype != jnp.uint8 or packed.ndim != 3:
        raise ValueError(f"expected canonical uint8[S, W, cols] planes, got {packed.dtype}{packed.shape}")
    if codec == "raw":
        return PlaneSet("raw", packed)
    if codec == "const_rle":
        payload, mask, val = _const_tiles(packed)
        return PlaneSet(codec, payload, None, mask, val)
    # col_perm / col_perm_rle
    if chains is None:
        raise ValueError(f"codec {codec!r} plans column orders along chains; pass chains=")
    col_order = plan_col_order(packed, chains, pin_cols=pin_cols)
    order_dev = jnp.asarray(col_order)
    stored = jnp.take_along_axis(packed, order_dev[:, None, :], axis=2)
    if codec == "col_perm":
        return PlaneSet(codec, stored, order_dev)
    payload, mask, val = _const_tiles(stored)
    return PlaneSet(codec, payload, order_dev, mask, val)


def _greedy_assign(m: np.ndarray, pin: int = 0) -> np.ndarray:
    """Greedy minimum-cost bipartite matching on a small square cost matrix.

    Repeatedly takes the globally cheapest free (row, col) pair —
    deterministic (np.argmin takes the first minimum).  ``out[j] = b``:
    stored position ``j`` takes logical plane ``b``.  The first ``pin``
    positions are fixed to identity before matching (see
    :func:`plan_col_order`).
    """
    n = m.shape[0]
    m = m.astype(np.int64).copy()
    big = np.iinfo(np.int64).max
    out = np.full(n, -1, np.int32)
    for j in range(min(pin, n)):
        out[j] = j
        m[j, :] = big
        m[:, j] = big
    for _ in range(n - min(pin, n)):
        j, b = np.unravel_index(np.argmin(m), m.shape)
        out[j] = b
        m[j, :] = big
        m[:, b] = big
    return out


def plan_col_order(
    packed: jax.Array, chains: list[np.ndarray], *, pin_cols: int = 0
) -> np.ndarray:
    """Chain-aware per-section column orders -> host int32[S, cols].

    For every chain step the full logical-column cross-distance matrix
    ``D[a, b] = hamming(prev[:, a], cur[:, b])`` is priced in ONE batched
    ``price_pairs`` call (the same Pallas-on-TPU / popcount-elsewhere path
    every other transition count takes), then a host greedy matching walks
    each chain: stored slot ``j``'s cost of taking logical plane ``b`` is
    ``D[prev_order[j], b]``, so choices compose along the chain.  The first
    section of every chain keeps the identity order (its seam reprograms
    unknown prior pool content — nothing to match against at plan time), and
    a chain reverts wholesale to identity when its matched layout does not
    beat the raw one, which makes the encoded transition total <= raw's by
    construction for any pool state.

    ``pin_cols`` fixes the lowest ``pin_cols`` logical columns at their
    identity positions.  Bit stucking (§IV) deliberately under-programs the
    *stored* lowest-order column(s), relying on them holding the logical
    LSBs whose error is bounded; a permutation that parks a high-order
    plane there would turn that bounded LSB error into a high-order one.
    The planner pins ``stuck_cols`` whenever ``p_stuck < 1``.  The cost is
    negligible: the LSB column is ~Bernoulli(0.5) and uncorrelated, so
    matching it to anything saves essentially nothing.
    """
    packed = jnp.asarray(packed)
    s, w, cols = packed.shape
    pin_cols = min(max(int(pin_cols), 0), cols)
    order = np.tile(np.arange(cols, dtype=np.int32), (s, 1))
    prev_i, cur_i = schedule.chain_pairs(chains, include_initial=False)
    t_total = prev_i.shape[0]
    if t_total == 0:
        return order

    # D[t, a, b] = popcount(packed[prev_t][:, a] ^ packed[cur_t][:, b])
    at = jnp.moveaxis(packed[prev_i], -1, 1)  # [T, cols, W]
    bt = jnp.moveaxis(packed[cur_i], -1, 1)
    a_full = jnp.broadcast_to(at[:, :, None, :], (t_total, cols, cols, w))
    b_full = jnp.broadcast_to(bt[:, None, :, :], (t_total, cols, cols, w))
    d = np.asarray(
        hamming_ops.price_pairs(
            a_full.reshape(t_total * cols * cols, w, 1),
            b_full.reshape(t_total * cols * cols, w, 1),
        ),
        np.int64,
    ).reshape(t_total, cols, cols)

    idx = np.arange(cols)
    t = 0
    for ch in chains:
        ch = np.asarray(ch, dtype=np.int64)
        prev_order = idx.copy()
        raw_cost = 0
        new_cost = 0
        chain_orders: list[np.ndarray] = []
        for _ in range(len(ch) - 1):
            dm = d[t]
            t += 1
            raw_cost += int(dm[idx, idx].sum())
            m = dm[prev_order, :]  # m[j, b] = D[prev_order[j], b]
            cur_order = _greedy_assign(m, pin_cols)
            new_cost += int(m[idx, cur_order].sum())
            chain_orders.append(cur_order)
            prev_order = cur_order
        if new_cost < raw_cost:
            for step, co in enumerate(chain_orders):
                order[ch[step + 1]] = co
    return order


# ---------------------------------------------------------------------------
# Serving-operand twins (simulator.packed_operands layout)
# ---------------------------------------------------------------------------

def _tile_nz(planes: jax.Array) -> jax.Array:
    """Zero-tile flags for serving planes ``uint8[..., cols, Kw, N]``.

    One flag per (plane, 128-row K block): ``uint8[..., cols, ceil(Kw/16)]``,
    1 iff any byte in the tile (across all N) is nonzero.  Matches the packed
    kernel's (plane, K-block) work unit, so a 0 flag is a skippable tile.
    """
    kw = planes.shape[-2]
    pad = (-kw) % OPERAND_TILE_BYTES
    if pad:
        planes = jnp.pad(planes, [(0, 0)] * (planes.ndim - 2) + [(0, pad), (0, 0)])
    shaped = planes.reshape(
        planes.shape[:-2] + (-1, OPERAND_TILE_BYTES) + planes.shape[-1:]
    )
    return jnp.any(shaped != 0, axis=(-2, -1)).astype(jnp.uint8)


def encode_operands(op: dict[str, jax.Array], codec: str) -> dict[str, jax.Array]:
    """Apply a codec to a packed serving operand dict (exactness-preserving).

    * ``col_perm*`` reorders the plane axis by descending bit density and
      records ``plane_ids`` (stored plane ``p`` holds logical plane
      ``plane_ids[p]``); consumers weight plane ``p`` by ``2**plane_ids[p]``,
      so decode is exact.
    * ``*_rle`` adds ``plane_tile_nz`` zero-tile flags — the payload needs no
      rewrite (zero tiles are already zero bytes); the flags drive the
      kernel's tile skipping and the roofline's compressed-traffic pricing.

    Must run *before* ``nonideal.perturb_operands``: fault masks attach to
    the stored layout, and logical decode happens after the masked read.
    """
    _check_codec(codec)
    if codec == "raw":
        return op
    if "planes_packed" not in op:
        raise ValueError("encode_operands expects packed serving operands")
    out = dict(op)
    planes = op["planes_packed"]  # [..., cols, Kw, N]
    if codec in ("col_perm", "col_perm_rle"):
        ones = jnp.sum(
            jax.lax.population_count(planes).astype(jnp.int32), axis=(-2, -1)
        )  # [..., cols]
        plane_ids = jnp.argsort(-ones, axis=-1, stable=True).astype(jnp.int32)
        planes = jnp.take_along_axis(planes, plane_ids[..., :, None, None], axis=-3)
        out["plane_ids"] = plane_ids
        out["planes_packed"] = planes
    if codec in ("const_rle", "col_perm_rle"):
        out["plane_tile_nz"] = _tile_nz(planes)
    return out


def operand_payload_bytes(op: dict[str, jax.Array]) -> dict[str, int]:
    """Weight bytes a decode step reads from an encoded operand dict.

    Zero tiles flagged in ``plane_tile_nz`` are not read (their contribution
    is identically zero); the sign mask and the codec sideband are.  Without
    flags this reduces to the packed representation's byte count.
    """
    planes = op["planes_packed"]
    n = planes.shape[-1]
    sign_bytes = int(np.prod(op["sign_packed"].shape))
    meta = 0
    if "plane_ids" in op:
        meta += int(np.prod(op["plane_ids"].shape))
    if "plane_tile_nz" in op:
        flags = np.asarray(op["plane_tile_nz"])
        meta += flags.size
        # the last K-tile may be ragged: count the bytes it actually holds
        kw = planes.shape[-2]
        n_tiles = flags.shape[-1]
        tile_bytes = np.minimum(
            OPERAND_TILE_BYTES, kw - OPERAND_TILE_BYTES * np.arange(n_tiles)
        )
        plane_bytes = int((flags * tile_bytes).sum()) * n
    else:
        plane_bytes = int(np.prod(planes.shape))
    return {
        "plane_bytes": plane_bytes,
        "sign_bytes": sign_bytes,
        "meta_bytes": meta,
        "total_bytes": plane_bytes + sign_bytes + meta,
    }
