"""Quantization and bit-plane slicing for bit-sliced CIM crossbars.

A bit-sliced crossbar of geometry ``rows x cols`` stores ``rows`` weights, one
per crossbar row, as ``cols``-bit unsigned magnitudes: column ``j`` is the
power-of-two multiplier ``2**j``.  Convention used throughout this package:

* plane axis is the **last** axis; index ``0`` is the **lowest-order column**
  (LSB) — the column the paper's bit-stucking targets.
* ``sign_magnitude`` encoding: ``w ~= sign * scale * q`` with ``q`` in
  ``[0, 2**cols - 1]``.  Signs are applied digitally (differential crossbar
  pairs); sorting by ``|w|`` therefore sorts the stored bit patterns, which is
  what Sorted Weight Sectioning exploits.
* ``offset_binary`` encoding (beyond-paper, §7 of DESIGN.md): ``w ~= scale * q
  + offset`` with all-positive ``q``.  The offset term is a rank-1 digital
  correction at matmul time: ``x @ W = scale * (x @ Q) + sum(x) * offset``.

All functions are pure JAX and jit-able.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

Encoding = Literal["sign_magnitude", "offset_binary"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quantized:
    """A flat quantized tensor ready for sectioning.

    Attributes:
      q:      int32[n]  unsigned magnitudes in [0, 2**cols - 1].
      sign:   int8[n]   +1/-1 for sign_magnitude; all +1 for offset_binary.
      scale:  f32[]     dequantization scale.
      offset: f32[]     dequantization offset (0 for sign_magnitude).
      cols:   static    bitwidth.
      encoding: static  encoding name.
    """

    q: jax.Array
    sign: jax.Array
    scale: jax.Array
    offset: jax.Array
    cols: int
    encoding: str

    def tree_flatten(self):
        return (self.q, self.sign, self.scale, self.offset), (self.cols, self.encoding)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, sign, scale, offset = children
        cols, encoding = aux
        return cls(q=q, sign=sign, scale=scale, offset=offset, cols=cols, encoding=encoding)


def quantize(w: jax.Array, cols: int, encoding: Encoding = "sign_magnitude") -> Quantized:
    """Quantize a tensor (any shape; flattened) to ``cols``-bit crossbar form."""
    flat = jnp.ravel(w).astype(jnp.float32)
    levels = jnp.float32(2**cols - 1)
    if encoding == "sign_magnitude":
        amax = jnp.maximum(jnp.max(jnp.abs(flat)), jnp.finfo(jnp.float32).tiny)
        scale = amax / levels
        q = jnp.clip(jnp.round(jnp.abs(flat) / scale), 0, levels).astype(jnp.int32)
        sign = jnp.where(flat < 0, -1, 1).astype(jnp.int8)
        offset = jnp.float32(0.0)
    elif encoding == "offset_binary":
        lo, hi = jnp.min(flat), jnp.max(flat)
        rng = jnp.maximum(hi - lo, jnp.finfo(jnp.float32).tiny)
        scale = rng / levels
        q = jnp.clip(jnp.round((flat - lo) / scale), 0, levels).astype(jnp.int32)
        sign = jnp.ones_like(q, dtype=jnp.int8)
        offset = lo
    else:
        raise ValueError(f"unknown encoding: {encoding!r}")
    return Quantized(q=q, sign=sign, scale=scale, offset=offset, cols=cols, encoding=encoding)


def dequantize(qt: Quantized) -> jax.Array:
    """Inverse of :func:`quantize` (returns the flat tensor)."""
    mag = qt.q.astype(jnp.float32) * qt.scale
    if qt.encoding == "sign_magnitude":
        return mag * qt.sign.astype(jnp.float32)
    return mag + qt.offset


def dequantize_from_planes(
    planes: jax.Array, sign: jax.Array, scale: jax.Array, offset: jax.Array
) -> jax.Array:
    """Reassemble weights from (possibly error-injected) bit planes.

    planes: bool/int[..., cols] with plane 0 = LSB.  Returns f32[...].
    """
    cols = planes.shape[-1]
    weights_of_two = (2 ** jnp.arange(cols, dtype=jnp.int32)).astype(jnp.int32)
    q = jnp.sum(planes.astype(jnp.int32) * weights_of_two, axis=-1)
    return q.astype(jnp.float32) * scale * sign.astype(jnp.float32) + offset


@partial(jax.jit, static_argnames=("cols",))
def bitplanes(q: jax.Array, cols: int) -> jax.Array:
    """Extract bit planes: int[...,] -> bool[..., cols]; plane 0 = LSB."""
    shifts = jnp.arange(cols, dtype=q.dtype)
    return ((q[..., None] >> shifts) & 1).astype(jnp.bool_)


def pack_rows(planes: jax.Array) -> jax.Array:
    """Pack the rows axis of bool[S, rows, cols] into uint8 words.

    Returns uint8[S, ceil(rows/8), cols].  Used for XOR+popcount transition
    counting (8x less data movement than bool planes).
    """
    s, rows, cols = planes.shape
    pad = (-rows) % 8
    if pad:
        planes = jnp.pad(planes, ((0, 0), (0, pad), (0, 0)))
    # jnp.packbits packs along the chosen axis, MSB-first within a byte.
    return jnp.packbits(planes.astype(jnp.uint8), axis=1)


def unpack_rows(packed: jax.Array, rows: int) -> jax.Array:
    """Inverse of :func:`pack_rows` -> bool[S, rows, cols]."""
    planes = jnp.unpackbits(packed, axis=1, count=rows)
    return planes.astype(jnp.bool_)


def section(flat: jax.Array, rows: int) -> tuple[jax.Array, int]:
    """Partition a flat array into crossbar sections of ``rows`` weights.

    Zero-pads the tail.  Returns (sections[S, rows], original_length).
    Zero padding is exact for both encodings' *transition* accounting: q=0
    rows have no active memristors in sign_magnitude, and in offset_binary the
    padding is sliced off before dequantization so its value never matters.
    """
    n = flat.shape[0]
    pad = (-n) % rows
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, rows), n


def unsection(sections: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`section`: drop padding, return flat[n]."""
    return sections.reshape(-1)[:n]


def section_planes(q: jax.Array, rows: int, cols: int) -> tuple[jax.Array, int]:
    """int32[n] magnitudes -> bool[S, rows, cols] section bit planes."""
    sec, n = section(q, rows)
    return bitplanes(sec, cols), n
