"""Quantization and bit-plane slicing for bit-sliced CIM crossbars.

A bit-sliced crossbar of geometry ``rows x cols`` stores ``rows`` weights, one
per crossbar row, as ``cols``-bit unsigned magnitudes: column ``j`` is the
power-of-two multiplier ``2**j``.  Convention used throughout this package:

* plane axis is the **last** axis; index ``0`` is the **lowest-order column**
  (LSB) — the column the paper's bit-stucking targets.
* ``sign_magnitude`` encoding: ``w ~= sign * scale * q`` with ``q`` in
  ``[0, 2**cols - 1]``.  Signs are applied digitally (differential crossbar
  pairs); sorting by ``|w|`` therefore sorts the stored bit patterns, which is
  what Sorted Weight Sectioning exploits.
* ``offset_binary`` encoding (beyond-paper, §7 of DESIGN.md): ``w ~= scale * q
  + offset`` with all-positive ``q``.  The offset term is a rank-1 digital
  correction at matmul time: ``x @ W = scale * (x @ Q) + sum(x) * offset``.

All functions are pure JAX and jit-able.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

Encoding = Literal["sign_magnitude", "offset_binary"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quantized:
    """A flat quantized tensor ready for sectioning.

    Attributes:
      q:      int32[n]  unsigned magnitudes in [0, 2**cols - 1].
      sign:   int8[n]   +1/-1 for sign_magnitude; all +1 for offset_binary.
      scale:  f32[]     dequantization scale.
      offset: f32[]     dequantization offset (0 for sign_magnitude).
      cols:   static    bitwidth.
      encoding: static  encoding name.
    """

    q: jax.Array
    sign: jax.Array
    scale: jax.Array
    offset: jax.Array
    cols: int
    encoding: str

    def tree_flatten(self):
        return (self.q, self.sign, self.scale, self.offset), (self.cols, self.encoding)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, sign, scale, offset = children
        cols, encoding = aux
        return cls(q=q, sign=sign, scale=scale, offset=offset, cols=cols, encoding=encoding)


def quantize(w: jax.Array, cols: int, encoding: Encoding = "sign_magnitude") -> Quantized:
    """Quantize a tensor (any shape; flattened) to ``cols``-bit crossbar form."""
    flat = jnp.ravel(w).astype(jnp.float32)
    levels = jnp.float32(2**cols - 1)
    # Explicit reciprocal multiply: XLA rewrites division-by-constant to a
    # reciprocal multiply in some compilation contexts but not others, which
    # would make eager and jitted quantization differ by 1 ULP in ``scale``.
    # A literal constant multiply is bit-deterministic everywhere, keeping
    # the planner's packed (jitted) and bool (eager) paths bit-identical.
    inv_levels = jnp.float32(1.0 / (2**cols - 1))
    if encoding == "sign_magnitude":
        amax = jnp.maximum(jnp.max(jnp.abs(flat)), jnp.finfo(jnp.float32).tiny)
        scale = amax * inv_levels
        q = jnp.clip(jnp.round(jnp.abs(flat) / scale), 0, levels).astype(jnp.int32)
        sign = jnp.where(flat < 0, -1, 1).astype(jnp.int8)
        offset = jnp.float32(0.0)
    elif encoding == "offset_binary":
        lo, hi = jnp.min(flat), jnp.max(flat)
        rng = jnp.maximum(hi - lo, jnp.finfo(jnp.float32).tiny)
        scale = rng * inv_levels
        q = jnp.clip(jnp.round((flat - lo) / scale), 0, levels).astype(jnp.int32)
        sign = jnp.ones_like(q, dtype=jnp.int8)
        offset = lo
    else:
        raise ValueError(f"unknown encoding: {encoding!r}")
    return Quantized(q=q, sign=sign, scale=scale, offset=offset, cols=cols, encoding=encoding)


def dequantize(qt: Quantized) -> jax.Array:
    """Inverse of :func:`quantize` (returns the flat tensor)."""
    mag = qt.q.astype(jnp.float32) * qt.scale
    if qt.encoding == "sign_magnitude":
        return mag * qt.sign.astype(jnp.float32)
    return mag + qt.offset


def dequantize_from_planes(
    planes: jax.Array, sign: jax.Array, scale: jax.Array, offset: jax.Array
) -> jax.Array:
    """Reassemble weights from (possibly error-injected) bit planes.

    planes: bool/int[..., cols] with plane 0 = LSB.  Returns f32[...].

    NOTE: the float result is only bit-reproducible *per compiled context* —
    XLA may contract the multiply chain with the offset add into an FMA, and
    whether it does depends on the surrounding fusion, so eager calls and
    differently-fused jits can disagree in the last ULP.  Callers needing
    bit-identical floats across call sites must route every call through ONE
    shared jitted entry (see ``planner._dequant_slots``, used by both
    planner impls) instead of inlining this into larger jits.
    """
    cols = planes.shape[-1]
    weights_of_two = (2 ** jnp.arange(cols, dtype=jnp.int32)).astype(jnp.int32)
    q = jnp.sum(planes.astype(jnp.int32) * weights_of_two, axis=-1)
    return q.astype(jnp.float32) * scale * sign.astype(jnp.float32) + offset


@partial(jax.jit, static_argnames=("cols",))
def bitplanes(q: jax.Array, cols: int) -> jax.Array:
    """Extract bit planes: int[...,] -> bool[..., cols]; plane 0 = LSB."""
    shifts = jnp.arange(cols, dtype=q.dtype)
    return ((q[..., None] >> shifts) & 1).astype(jnp.bool_)


def pack_rows(planes: jax.Array) -> jax.Array:
    """Pack the rows axis of bool[S, rows, cols] into uint8 words.

    Returns uint8[S, ceil(rows/8), cols].  Used for XOR+popcount transition
    counting (8x less data movement than bool planes).
    """
    s, rows, cols = planes.shape
    pad = (-rows) % 8
    if pad:
        planes = jnp.pad(planes, ((0, 0), (0, pad), (0, 0)))
    # jnp.packbits packs along the chosen axis, MSB-first within a byte.
    return jnp.packbits(planes.astype(jnp.uint8), axis=1)


def unpack_rows(packed: jax.Array, rows: int) -> jax.Array:
    """Inverse of :func:`pack_rows` -> bool[S, rows, cols]."""
    planes = jnp.unpackbits(packed, axis=1, count=rows)
    return planes.astype(jnp.bool_)


def pack_axis0(mask: jax.Array) -> jax.Array:
    """Pack axis 0 of bool[rows, k] into uint8[ceil(rows/8), k] words.

    Same MSB-first byte convention as :func:`pack_rows`; used to apply
    per-row Bernoulli masks directly to packed planes (bit stucking).
    """
    rows = mask.shape[0]
    pad = (-rows) % 8
    if pad:
        mask = jnp.pad(mask, ((0, pad),) + ((0, 0),) * (mask.ndim - 1))
    return jnp.packbits(mask.astype(jnp.uint8), axis=0)


def section_planes_packed(q: jax.Array, rows: int, cols: int) -> jax.Array:
    """int32[S*rows] magnitudes -> packed uint8[S, ceil(rows/8), cols] planes.

    The canonical planner representation: one packbits per tensor, after
    which all pricing (cost/schedule/stucking) runs on packed words.
    ``q`` must already be padded to a multiple of ``rows``.
    """
    return pack_rows(bitplanes(q.reshape(-1, rows), cols))


@partial(jax.jit, static_argnames=("cols",))
def pack_linear_planes(q: jax.Array, cols: int) -> jax.Array:
    """int[..., K, N] magnitudes -> packed uint8[..., cols, ceil(K/8), N].

    The *serving* operand layout (kernels/cim_matmul packed mode): the plane
    axis comes first (plane 0 = LSB, same column order as every other packed
    representation here), and the contraction axis K is packed MSB-first into
    bytes — the byte convention :func:`pack_rows` uses, so pool state and
    serving operands share one bit order.  K-padding bits are zero (pristine
    cells) and the matching activation rows are zero-padded by the kernel
    wrapper, so padding never contributes to a dot product.
    """
    planes = bitplanes(q, cols)  # [..., K, N, cols]
    planes = jnp.moveaxis(planes, -1, -3)  # [..., cols, K, N]
    return jnp.packbits(planes.astype(jnp.uint8), axis=-2)


@jax.jit
def pack_linear_sign(sign: jax.Array) -> jax.Array:
    """+1/-1 int8[..., K, N] -> packed sign bits uint8[..., ceil(K/8), N].

    Bit convention: 1 = negative weight (sign applied digitally after the
    magnitude reconstruction, mirroring differential crossbar pairs).  Same
    MSB-first K packing as :func:`pack_linear_planes`; padding bits are zero,
    i.e. +1, which multiplies only zero-magnitude padding cells.
    """
    return jnp.packbits((sign < 0).astype(jnp.uint8), axis=-2)


def encode_planes(packed: jax.Array, codec: str = "raw", *, chains=None, pin_cols=0):
    """Canonical packed planes -> stored :class:`~repro.core.planes.PlaneSet`.

    The codec layer's entry point from the slicing side: what used to be
    "pack is the stored form" becomes pack -> encode.  ``codec`` is one of
    :data:`repro.core.planes.CODECS`; ``col_perm*`` codecs additionally need
    the programming ``chains`` to plan column orders against each section's
    actual predecessor.  ``decode_planes(encode_planes(p, c)) == p``
    byte-for-byte for every codec.
    """
    from repro.core import planes  # deferred: planes imports schedule -> bitslice

    return planes.encode(packed, codec, chains=chains, pin_cols=pin_cols)


def decode_planes(plane_set) -> jax.Array:
    """Stored :class:`~repro.core.planes.PlaneSet` (or a raw packed array)
    -> canonical packed uint8[S, ceil(rows/8), cols] planes."""
    if isinstance(plane_set, jax.Array) or not hasattr(plane_set, "decode"):
        return plane_set
    return plane_set.decode()


def section(flat: jax.Array, rows: int) -> tuple[jax.Array, int]:
    """Partition a flat array into crossbar sections of ``rows`` weights.

    Zero-pads the tail.  Returns (sections[S, rows], original_length).
    Zero padding is exact for both encodings' *transition* accounting: q=0
    rows have no active memristors in sign_magnitude, and in offset_binary the
    padding is sliced off before dequantization so its value never matters.
    """
    n = flat.shape[0]
    pad = (-n) % rows
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, rows), n


def unsection(sections: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`section`: drop padding, return flat[n]."""
    return sections.reshape(-1)[:n]


def section_planes(q: jax.Array, rows: int, cols: int) -> tuple[jax.Array, int]:
    """int32[n] magnitudes -> bool[S, rows, cols] section bit planes."""
    sec, n = section(q, rows)
    return bitplanes(sec, cols), n
