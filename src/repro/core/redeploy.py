"""Beyond-paper: incremental re-deployment across training checkpoints.

During training the deployed weights drift; refreshing the crossbars with a
new checkpoint is itself a reprogramming workload.  The paper prices only
streaming a *fixed* model through a crossbar pool; here we extend the same
transition accounting to checkpoint-to-checkpoint deltas, with and without
SWS.  SWS helps twice: (a) sorted sections change slowly between adjacent
checkpoints (ranks of |w| are stable), and (b) the per-element delta in a
sorted layout concentrates in low-order bits, which combine with bit
stucking (``p``) for further savings.

This module is used by ``runtime.TrainLoop`` when ``redeploy_every > 0`` and
by ``benchmarks/redeploy_delta.py``.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.core import bitslice, cost, schedule
from repro.core.planner import CrossbarSpec, PlannerConfig, _perm_full

if TYPE_CHECKING:
    from repro.core.pool import CrossbarPool


@dataclasses.dataclass
class RedeployReport:
    name: str
    transitions_natural: int  # reprogram in-place, natural layout
    transitions_sws: int  # reprogram in-place, SWS layout (old perm kept)
    n_bits: int  # physical memristors holding real weights (upper bound on transitions)
    # streaming-chain costs of the NEW checkpoint through a crossbar pool:
    chain_natural: int = 0  # natural layout
    chain_stale_sws: int = 0  # the OLD checkpoint's sort order (index map kept)
    chain_fresh_sws: int = 0  # re-sorted on the new weights (new index map)
    chain_pool: int = 0  # stale-SWS refresh through a persistent CrossbarPool

    @property
    def sws_delta_speedup(self) -> float:
        """In-place rewrite cost ratio.  NOTE: summed per-element Hamming
        distance is permutation-invariant, so this is 1.0 by construction —
        kept as a sanity check that the index-matching bookkeeping is exact.
        The *streaming* metrics below are where layout matters."""
        return self.transitions_natural / max(self.transitions_sws, 1)

    @property
    def stale_sort_speedup(self) -> float:
        """Streaming speedup of keeping the old sort across a checkpoint.

        The deployment-relevant question: after weight drift, is the stale
        SWS order still near-optimal (so the index map need not be rebuilt)?
        Compare against ``fresh_sort_speedup`` for the re-sort headroom."""
        return self.chain_natural / max(self.chain_stale_sws, 1)

    @property
    def fresh_sort_speedup(self) -> float:
        return self.chain_natural / max(self.chain_fresh_sws, 1)


def delta_cost(
    w_old: jax.Array,
    w_new: jax.Array,
    spec: CrossbarSpec = CrossbarSpec(),
    config: PlannerConfig = PlannerConfig(),
    name: str = "w",
    *,
    pool: "CrossbarPool | None" = None,
) -> RedeployReport:
    """Price reprogramming crossbars holding ``w_old`` to hold ``w_new``.

    The SWS path keeps the *old* checkpoint's permutation (re-sorting every
    checkpoint would defeat index-matching stability); the shared scale is
    re-fit on the new tensor, matching what a deployment refresh would do.

    With ``pool``, the refresh additionally *programs* the new checkpoint
    (stale-SWS layout, full reprogramming) through the persistent
    ``CrossbarPool``: ``chain_pool`` prices the multi-crossbar stream from
    whatever the pool currently holds — the previous checkpoint after the
    first call — and the pool's wear counters absorb the refresh, so a
    training run's cumulative cell wear is tracked across checkpoints
    instead of being re-priced from pristine every time.
    """
    rows, cols = spec.rows, spec.cols
    fo = jnp.ravel(w_old).astype(jnp.float32)
    fn = jnp.ravel(w_new).astype(jnp.float32)
    pad = (-fo.shape[0]) % rows
    fo_p, fn_p = jnp.pad(fo, (0, pad)), jnp.pad(fn, (0, pad))

    qo = jnp.pad(bitslice.quantize(fo, cols, spec.encoding).q, (0, pad))
    qn = jnp.pad(bitslice.quantize(fn, cols, spec.encoding).q, (0, pad))

    def transitions(perm):
        po = bitslice.bitplanes(qo[perm].reshape(-1, rows), cols)
        pn = bitslice.bitplanes(qn[perm].reshape(-1, rows), cols)
        return int(jnp.sum(cost.pair_transitions(po, pn)))

    def chain(perm):
        pn = bitslice.bitplanes(qn[perm].reshape(-1, rows), cols)
        return int(cost.chain_transitions(pn))

    ident = jnp.arange(fo_p.shape[0], dtype=jnp.int32)
    natural = transitions(ident)
    perm_stale = _perm_full(fo_p, spec, config, qo)
    perm_fresh = _perm_full(fn_p, spec, config, qn)

    chain_pool = 0
    if pool is not None:
        s = fo_p.shape[0] // rows
        l = max(1, min(config.crossbars, s))
        chains = schedule.make_chains(s, l, config.schedule)
        if pool.tensors_seen == 0:
            # a pristine pool has never physically held w_old: seat it first,
            # so (a) the refresh seams come from resident content rather than
            # zeros and (b) the wear counters include the initial
            # deployment's writes — otherwise the cumulative lifetime is
            # understated by one full deployment
            pool.program(
                bitslice.section_planes_packed(qo[perm_stale], rows, cols),
                chains, p_stuck=1.0,
                leveling=config.pool_leveling, name=f"{name}@deploy",
            )
        packed_new = bitslice.section_planes_packed(qn[perm_stale], rows, cols)
        prep = pool.program(
            packed_new, chains, p_stuck=1.0,
            leveling=config.pool_leveling, name=name,
        )
        chain_pool = prep.transitions_full

    return RedeployReport(
        name=name,
        transitions_natural=natural,
        transitions_sws=transitions(perm_stale),
        # unpadded count: zero-padding never transitions, so padded cells
        # would only slacken the bound
        n_bits=int(fo.shape[0]) * cols,
        chain_natural=chain(ident),
        chain_stale_sws=chain(perm_stale),
        chain_fresh_sws=chain(perm_fresh),
        chain_pool=chain_pool,
    )
