"""The paper's primary contribution: efficient crossbar reprogramming.

Pipeline (all pure JAX):
  bitslice  — quantize + bit-plane slice weights into crossbar sections
  cost      — transition counting (Eq. 1), per-column breakdowns
  sws       — Sorted Weight Sectioning + beyond-paper TSP section ordering
  schedule  — stride-1 / stride-L multi-crossbar schedules, thread balancing
  stucking  — bit-stucking walks with exact achieved-state tracking
  planner   — params pytree -> DeploymentPlan (metrics + deployed weights)
  simulator — CIM forward simulation + accuracy-preservation probes
  redeploy  — beyond-paper checkpoint-to-checkpoint delta reprogramming
"""
from repro.core.planner import (
    CrossbarSpec,
    DeploymentPlan,
    PlannerConfig,
    TensorReport,
    analyze_tensor,
    build_deployment,
    deploy_params,
)

__all__ = [
    "CrossbarSpec",
    "DeploymentPlan",
    "PlannerConfig",
    "TensorReport",
    "analyze_tensor",
    "build_deployment",
    "deploy_params",
]
