"""Bit-stucking-based reprogramming (§IV of the paper).

In bell-shaped weight distributions the lowest-order bit column is
~Bernoulli(0.5): it is both the *most transition-heavy* column (uncorrelated
bits flip on every reprogram with probability ~0.5) and the *least important*
one (smallest power-of-two multiplier).  Bit stucking programs only a random
fraction ``p`` of the transitional memristors in the lowest-order column(s);
the remaining memristors keep their stale state, injecting a bounded LSB
error into the deployed weights.

``stuck_chain`` is the exact physical walk: it carries the crossbar state
along the programming chain, counts actually-programmed transitions, and
emits the *achieved* bit planes per section — the planes a model would really
compute with, used by ``core.simulator`` to price the accuracy impact.

p=1 reproduces full reprogramming (no error); p=0 sticks the column at its
initial state forever (the paper's Fig. 9 extreme).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("stuck_cols", "include_initial"))
def stuck_chain(
    planes: jax.Array,
    order: jax.Array,
    p: jax.Array | float,
    key: jax.Array,
    *,
    stuck_cols: int = 1,
    include_initial: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Walk one crossbar through ``order`` with bit stucking.

    Args:
      planes: bool[S, rows, cols] ideal section bit planes (plane 0 = LSB).
      order:  int[T] programming order (indices into S).
      p:      probability of actually programming a transitional memristor in
              the stuck columns.
      key:    PRNG key (one subkey per programming step).
      stuck_cols: how many lowest-order columns are subject to stucking.
      include_initial: count the first program from the pristine crossbar.

    Returns:
      total:    int32[] programmed transitions over the walk.
      achieved: bool[S, rows, cols] the state the crossbar actually held when
                each section was resident (scattered back to section index;
                sections not visited by this chain keep their ideal planes).
    """
    s, rows, cols = planes.shape
    t = order.shape[0]
    seq = planes[order]
    keys = jax.random.split(key, t)
    p = jnp.asarray(p, dtype=jnp.float32)

    def step(state, inp):
        target, k = inp
        trans = jnp.logical_xor(state, target)
        program = trans
        if stuck_cols > 0:
            mask = jax.random.bernoulli(k, p, shape=(rows, stuck_cols))
            stuck_part = jnp.logical_and(trans[:, :stuck_cols], mask)
            program = jnp.concatenate([stuck_part, trans[:, stuck_cols:]], axis=1)
        new_state = jnp.where(program, target, state)
        return new_state, (new_state, jnp.sum(program, dtype=jnp.int32))

    state0 = jnp.zeros((rows, cols), dtype=jnp.bool_)
    _, (states, counts) = jax.lax.scan(step, state0, (seq, keys))
    total = jnp.sum(counts) if include_initial else jnp.sum(counts[1:])
    achieved = planes.at[order].set(states)
    return total, achieved


def stuck_schedule(
    planes: jax.Array,
    chains: list[jax.Array],
    p: jax.Array | float,
    key: jax.Array,
    *,
    stuck_cols: int = 1,
    include_initial: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Run ``stuck_chain`` over every crossbar chain of a schedule (vmapped).

    Chains are padded to equal length by repeating their last section —
    reprogramming a crossbar with its current contents costs exactly zero
    transitions and leaves the achieved state unchanged, so the padding is
    free and exact.

    Returns (total int32[], achieved bool[S, rows, cols]).
    """
    max_len = max(int(c.shape[0]) for c in chains)
    padded = jnp.stack(
        [jnp.concatenate([c, jnp.full((max_len - c.shape[0],), c[-1], dtype=c.dtype)]) for c in chains]
    )
    keys = jax.random.split(key, len(chains))

    totals, achieved_all = jax.vmap(
        lambda o, k: stuck_chain(
            planes, o, p, k, stuck_cols=stuck_cols, include_initial=include_initial
        )
    )(padded, keys)

    # Each section belongs to exactly one chain in both stride schedules, so
    # combining per-chain achieved planes is a select on 'was visited here'.
    achieved = planes
    for i, c in enumerate(chains):
        achieved = achieved.at[c].set(achieved_all[i][c])
    return jnp.sum(totals), achieved


def expected_saving_fraction(
    planes: jax.Array, order: jax.Array, p: float, *, stuck_cols: int = 1
) -> jax.Array:
    """Analytic expected fraction of chain transitions avoided by stucking.

    savings ~= (1 - p) * (transitions in stuck cols) / (total transitions).
    Useful as a napkin check against the measured ``stuck_chain`` totals.
    """
    seq = planes[order]
    diffs = jnp.logical_xor(seq[1:], seq[:-1]).astype(jnp.float32)
    col = jnp.sum(diffs, axis=(0, 1))
    total = jnp.maximum(jnp.sum(col), 1.0)
    return (1.0 - p) * jnp.sum(col[:stuck_cols]) / total
