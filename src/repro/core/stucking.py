"""Bit-stucking-based reprogramming (§IV of the paper).

In bell-shaped weight distributions the lowest-order bit column is
~Bernoulli(0.5): it is both the *most transition-heavy* column (uncorrelated
bits flip on every reprogram with probability ~0.5) and the *least important*
one (smallest power-of-two multiplier).  Bit stucking programs only a random
fraction ``p`` of the transitional memristors in the lowest-order column(s);
the remaining memristors keep their stale state, injecting a bounded LSB
error into the deployed weights.

``stuck_chain`` is the exact physical walk: it carries the crossbar state
along the programming chain, counts actually-programmed transitions, and
emits the *achieved* bit planes per section — the planes a model would really
compute with, used by ``core.simulator`` to price the accuracy impact.

p=1 reproduces full reprogramming (no error); p=0 sticks the column at its
initial state forever (the paper's Fig. 9 extreme).

Two implementations share one PRNG discipline (one subkey per programming
step, Bernoulli mask drawn as bool[rows, stuck_cols]) and are therefore
bit-exact with each other:

  * ``stuck_chain`` / ``stuck_schedule`` — bool planes; the readable oracle.
  * ``stuck_chain_packed`` / ``stuck_schedule_packed`` — canonical packed
    uint8 planes (``bitslice.section_planes_packed``); the mask is packed
    with the same MSB-first convention and applied word-wise, the state
    update is a pure XOR (``program ⊆ trans``), and counting is popcount.
    This is the planner's fast path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bitslice
from repro.core.cost import _popcount_i32


def _pad_chains(
    chains: list[jax.Array], key: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Pad chains to equal length + validity mask + per-chain keys.

    Returns (padded int[L, T], valid bool[L, T], keys [L, 2]).  Padding
    repeats a chain's last section; ``valid`` is False on padded steps, and
    the walks skip programming there entirely (``program = 0``), so padding
    is exactly free: no counted transitions, no state change, no extra
    stuck-bit retries (under p < 1 an *unmasked* padded step would redraw a
    Bernoulli mask and keep reprogramming residual stuck bits — a modeling
    artifact, and a source of duplicate scatter writes with differing
    values).  Shared by the bool and packed schedule walks so their padding
    and PRNG key schedule stay identical — the bit-exactness contract
    between the two implementations depends on this block never diverging.
    """
    max_len = max(int(c.shape[0]) for c in chains)
    padded = jnp.stack(
        [jnp.concatenate([c, jnp.full((max_len - c.shape[0],), c[-1], dtype=c.dtype)]) for c in chains]
    )
    valid = jnp.stack(
        [jnp.arange(max_len) < int(c.shape[0]) for c in chains]
    )
    return padded, valid, jax.random.split(key, len(chains))


@partial(jax.jit, static_argnames=("stuck_cols", "include_initial"))
def stuck_chain(
    planes: jax.Array,
    order: jax.Array,
    p: jax.Array | float,
    key: jax.Array,
    *,
    stuck_cols: int = 1,
    include_initial: bool = True,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Walk one crossbar through ``order`` with bit stucking.

    Args:
      planes: bool[S, rows, cols] ideal section bit planes (plane 0 = LSB).
      order:  int[T] programming order (indices into S).
      p:      probability of actually programming a transitional memristor in
              the stuck columns.
      key:    PRNG key (one subkey per programming step).
      stuck_cols: how many lowest-order columns are subject to stucking.
      include_initial: count the first program from the pristine crossbar.
      valid: optional bool[T]; False marks schedule-padding steps, which are
             skipped entirely (no programming, no counted transitions).

    Returns:
      total:    int32[] programmed transitions over the walk.
      achieved: bool[S, rows, cols] the state the crossbar actually held when
                each section was resident (scattered back to section index;
                sections not visited by this chain keep their ideal planes).
    """
    s, rows, cols = planes.shape
    t = order.shape[0]
    seq = planes[order]
    keys = jax.random.split(key, t)
    p = jnp.asarray(p, dtype=jnp.float32)
    valid_t = jnp.ones((t,), jnp.bool_) if valid is None else valid

    def step(state, inp):
        target, k, v = inp
        trans = jnp.logical_xor(state, target)
        program = trans
        if stuck_cols > 0:
            mask = jax.random.bernoulli(k, p, shape=(rows, stuck_cols))
            stuck_part = jnp.logical_and(trans[:, :stuck_cols], mask)
            program = jnp.concatenate([stuck_part, trans[:, stuck_cols:]], axis=1)
        program = jnp.logical_and(program, v)
        new_state = jnp.where(program, target, state)
        return new_state, (new_state, jnp.sum(program, dtype=jnp.int32))

    state0 = jnp.zeros((rows, cols), dtype=jnp.bool_)
    _, (states, counts) = jax.lax.scan(step, state0, (seq, keys, valid_t))
    total = jnp.sum(counts) if include_initial else jnp.sum(counts[1:])
    achieved = planes.at[order].set(states)
    return total, achieved


def _walk_packed(
    packed: jax.Array,
    order: jax.Array,
    p: jax.Array | float,
    key: jax.Array,
    *,
    rows: int,
    stuck_cols: int,
    include_initial: bool,
    valid: jax.Array | None = None,
    state0: jax.Array | None = None,
    with_wear: bool = False,
) -> tuple[jax.Array, ...]:
    """One packed chain walk -> (total int32[], states uint8[T, W, cols]).

    ``states[t]`` is the crossbar content while section ``order[t]`` was
    resident — the walk's raw output, before scattering back to section
    index (kept separate so vmapped schedules can combine all chains with a
    single scatter instead of one full-plane copy per chain).  ``valid``
    marks schedule-padding steps exactly as in :func:`stuck_chain`.

    ``state0`` is the crossbar's state *before* the first program (defaults
    to pristine all-zero); ``core.pool`` passes the persistent pool state so
    the first program is a cross-tensor seam.  ``with_wear=True`` additionally
    accumulates per-cell programmed-transition counts and returns the
    extended tuple (total, states, counts int32[T], wear int32[rows, cols]).
    Neither option perturbs the PRNG discipline: the per-step key schedule
    and mask draws are identical for every combination, which is what keeps
    the packed walk bit-exact with the bool oracle and the pool walk
    bit-exact with the pristine one when ``state0`` is zero.
    """
    t = order.shape[0]
    seq = packed[order]
    keys = jax.random.split(key, t)
    p = jnp.asarray(p, dtype=jnp.float32)
    valid_t = jnp.ones((t,), jnp.bool_) if valid is None else valid

    def step(carry, inp):
        state, wear = carry
        target, k, v = inp
        trans = jnp.bitwise_xor(state, target)
        program = trans
        if stuck_cols > 0:
            mask = jax.random.bernoulli(k, p, shape=(rows, stuck_cols))
            mask_w = bitslice.pack_axis0(mask)  # uint8[W, stuck_cols]
            stuck_part = jnp.bitwise_and(trans[:, :stuck_cols], mask_w)
            program = jnp.concatenate([stuck_part, trans[:, stuck_cols:]], axis=1)
        program = jnp.where(v, program, jnp.uint8(0))
        new_state = jnp.bitwise_xor(state, program)  # program ⊆ trans
        if with_wear:
            wear = wear + jnp.unpackbits(program, axis=0, count=rows).astype(jnp.int32)
        return (new_state, wear), (new_state, jnp.sum(_popcount_i32(program)))

    init_state = jnp.zeros(packed.shape[1:], dtype=jnp.uint8) if state0 is None else state0
    cols = packed.shape[-1]
    wear0 = jnp.zeros((rows, cols), jnp.int32) if with_wear else jnp.zeros((), jnp.int32)
    (_, wear), (states, counts) = jax.lax.scan(
        step, (init_state, wear0), (seq, keys, valid_t)
    )
    total = jnp.sum(counts) if include_initial else jnp.sum(counts[1:])
    if with_wear:
        return total, states, counts, wear
    return total, states


@partial(jax.jit, static_argnames=("rows", "stuck_cols", "include_initial"))
def stuck_chain_packed(
    packed: jax.Array,
    order: jax.Array,
    p: jax.Array | float,
    key: jax.Array,
    *,
    rows: int,
    stuck_cols: int = 1,
    include_initial: bool = True,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """:func:`stuck_chain` on packed planes uint8[S, W, cols].

    ``rows`` is the *logical* row count (the packed axis holds ceil(rows/8)
    byte words); the Bernoulli mask is drawn with the exact shape and key
    schedule of the bool path, so results are bit-exact with it.  Row-padding
    bits inside the words are zero on every chain state, hence never
    transitional and never programmed.

    Returns (total int32[], achieved uint8[S, W, cols]).
    """
    total, states = _walk_packed(
        packed, order, p, key,
        rows=rows, stuck_cols=stuck_cols, include_initial=include_initial, valid=valid,
    )
    achieved = packed.at[order].set(states)
    return total, achieved


def stuck_schedule(
    planes: jax.Array,
    chains: list[jax.Array],
    p: jax.Array | float,
    key: jax.Array,
    *,
    stuck_cols: int = 1,
    include_initial: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Run ``stuck_chain`` over every crossbar chain of a schedule (vmapped).

    Chain padding + key schedule come from :func:`_pad_chains` (shared with
    the packed variant).

    Returns (total int32[], achieved bool[S, rows, cols]).
    """
    padded, valid, keys = _pad_chains(chains, key)

    totals, achieved_all = jax.vmap(
        lambda o, v, k: stuck_chain(
            planes, o, p, k, stuck_cols=stuck_cols, include_initial=include_initial, valid=v
        )
    )(padded, valid, keys)

    # Each section belongs to exactly one chain in both stride schedules, so
    # combining per-chain achieved planes is a select on 'was visited here'.
    achieved = planes
    for i, c in enumerate(chains):
        achieved = achieved.at[c].set(achieved_all[i][c])
    return jnp.sum(totals), achieved


def stuck_schedule_packed(
    packed: jax.Array,
    chains: list[jax.Array],
    p: jax.Array | float,
    key: jax.Array,
    *,
    rows: int,
    stuck_cols: int = 1,
    include_initial: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """:func:`stuck_schedule` on packed planes (same padding + key schedule).

    Returns (chain_totals int32[L], achieved uint8[S, W, cols]) — bit-exact
    with the bool path given the same key (``sum(chain_totals)`` equals the
    bool path's scalar total).  Per-chain totals are returned, unlike the
    seed bool API, so callers can aggregate on the host in int64: a
    whole-tensor total can exceed int32 at extreme scale, while one chain's
    total (chain length x rows x cols bits) stays far below it.
    """
    padded, valid, keys = _pad_chains(chains, key)

    totals, states_all = jax.vmap(
        lambda o, v, k: _walk_packed(
            packed, o, p, k, rows=rows, stuck_cols=stuck_cols,
            include_initial=include_initial, valid=v,
        )
    )(padded, valid, keys)

    # Each section belongs to exactly one chain; padded steps are masked
    # no-ops (see _pad_chains), so duplicate indices in this scatter carry
    # values identical to the last real visit and one scatter combines all
    # chains regardless of JAX's duplicate-write ordering.
    achieved = packed.at[padded.reshape(-1)].set(
        states_all.reshape((-1,) + packed.shape[1:])
    )
    return totals, achieved


def expected_saving_fraction(
    planes: jax.Array, order: jax.Array, p: float, *, stuck_cols: int = 1
) -> jax.Array:
    """Analytic expected fraction of chain transitions avoided by stucking.

    savings ~= (1 - p) * (transitions in stuck cols) / (total transitions).
    Useful as a napkin check against the measured ``stuck_chain`` totals.
    """
    seq = planes[order]
    diffs = jnp.logical_xor(seq[1:], seq[:-1]).astype(jnp.float32)
    col = jnp.sum(diffs, axis=(0, 1))
    total = jnp.maximum(jnp.sum(col), 1.0)
    return (1.0 - p) * jnp.sum(col[:stuck_cols]) / total
