"""CIM crossbar forward simulation + model fidelity probes.

``cim_linear`` computes a linear layer the way the analog array does: as a
sum over bit columns of {0,1}-plane dot products scaled by powers of two
(sign applied digitally for sign_magnitude; rank-1 offset correction for
offset_binary).  Two operand representations are supported:

  * **int8 signed planes** (``splanes`` int8[cols, K, N], sign folded in) —
    the original simulation/parity surface; one byte of traffic per bit cell.
  * **packed planes** (``planes_packed`` uint8[cols, ceil(K/8), N] +
    ``sign_packed`` uint8[ceil(K/8), N]) — the *serving* representation: the
    same canonical bit-packed words the planner and ``CrossbarPool`` hold,
    one bit of traffic per bit cell (~8x less weight HBM read).

Kernel dispatch policy (mirrors ``kernels.hamming.ops.price_pairs``): with
``use_kernel=True`` the compiled Pallas kernel runs on TPU; on every other
backend the portable jnp reference does — interpret-mode Pallas runs the grid
in Python and would be orders of magnitude slower than the fallback.
Numerically every path equals ``x @ w_hat`` for the dequantized planes — the
value of the simulation is that *error-injected* planes (bit stucking,
stuck-at faults) flow through the same path the hardware would use.

``logit_kl`` / ``output_mse`` are the accuracy-preservation probes used by
the benchmarks when a labelled task is unavailable (DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitslice
from repro.core import planes as planes_mod
from repro.core.planner import CrossbarSpec, DeploymentPlan, PlannerConfig, analyze_tensor
from repro.kernels._util import on_tpu


def int8_plane_operands(
    q: jax.Array, sign: jax.Array, scale: jax.Array, offset: jax.Array, cols: int
) -> dict[str, jax.Array]:
    """Magnitudes + signs [..., K, N] -> int8 signed-plane operands.

    Signed planes in {-1, 0, 1}: sign folded in so the matmul core is a plain
    integer dot product per column (kernels/cim_matmul contract: splanes is
    [..., cols, K, N] with plane 0 = LSB).  Array-only dict (jit-safe as a
    params-pytree leaf); leading dims of ``q`` become leading dims of every
    entry, ``scale``/``offset`` broadcast to them.
    """
    planes = bitslice.bitplanes(q, cols)  # [..., K, N, cols]
    splanes = jnp.moveaxis(planes.astype(jnp.int8) * sign[..., None], -1, -3)
    lead = q.shape[:-2]
    return {
        "splanes": splanes,
        "scale": jnp.broadcast_to(jnp.asarray(scale, jnp.float32), lead),
        "offset": jnp.broadcast_to(jnp.asarray(offset, jnp.float32), lead),
    }


def packed_operands(
    q: jax.Array, sign: jax.Array, scale: jax.Array, offset: jax.Array, cols: int
) -> dict[str, jax.Array]:
    """Magnitudes + signs [..., K, N] -> bit-packed serving operands.

    ``planes_packed`` uint8[..., cols, ceil(K/8), N] (plane 0 = LSB, K packed
    MSB-first per byte) and ``sign_packed`` uint8[..., ceil(K/8), N] (bit 1 =
    negative) — see ``bitslice.pack_linear_planes``.  Array-only dict; leading
    dims as in :func:`int8_plane_operands`.  Tensor-parallel shards are built
    by slicing this dict with :func:`shard_operands` (column- or row-parallel)
    — exact, no repacking — so dense and packed layouts agree by construction.
    """
    lead = q.shape[:-2]
    return {
        "planes_packed": bitslice.pack_linear_planes(q, cols),
        "sign_packed": bitslice.pack_linear_sign(sign),
        "scale": jnp.broadcast_to(jnp.asarray(scale, jnp.float32), lead),
        "offset": jnp.broadcast_to(jnp.asarray(offset, jnp.float32), lead),
        # zero-byte K marker: the true (pre-padding) contraction length lives
        # in this array's static shape, so jitted consumers (densify, refs)
        # can slice the 8-padded K axis without a non-array pytree leaf
        "kdim": jnp.zeros(lead + q.shape[-2:-1] + (0,), jnp.float32),
    }


def operands_from_dense(
    w_hat: jax.Array,
    scale: jax.Array | float,
    offset: jax.Array | float,
    encoding: str,
    cols: int,
    materialize: str = "packed",
    codec: str = "raw",
) -> dict[str, jax.Array]:
    """Recover crossbar operands from achieved dense weights ``w_hat``.

    ``w_hat`` must be exactly representable under (scale, offset, encoding) —
    true for any planner-deployed tensor, stucking included.  The integer
    magnitude is recovered by rounding: q <= 2**cols - 1 keeps the float
    error of ``q*scale/scale`` far below 0.5, so the round is exact.

    ``codec`` applies the serving-side plane codec (``planes.encode_operands``)
    to packed operands — an exact re-encoding (plane-axis reorder + zero-tile
    flags), so every consumer decodes bit-identical weights.  Only the packed
    materialization has a stored-plane layout to encode.
    """
    if codec != "raw" and materialize != "packed":
        raise ValueError(
            f"codec {codec!r} encodes packed serving operands; materialize "
            f"{materialize!r} has no stored-plane layout"
        )
    w32 = w_hat.astype(jnp.float32)
    scale = jnp.asarray(scale, jnp.float32)
    offset = jnp.asarray(offset, jnp.float32)
    levels = float(2**cols - 1)
    if encoding == "sign_magnitude":
        q = jnp.clip(jnp.round(jnp.abs(w32) / scale), 0, levels).astype(jnp.int32)
        # signbit, not `< 0`: a q=0 cell with negative sign dequantizes to
        # -0.0, and recovering its sign keeps the re-encoding bit-exact
        sign = jnp.where(jnp.signbit(w32), -1, 1).astype(jnp.int8)
    elif encoding == "offset_binary":
        q = jnp.clip(jnp.round((w32 - offset) / scale), 0, levels).astype(jnp.int32)
        sign = jnp.ones_like(q, dtype=jnp.int8)
    else:
        raise ValueError(f"unknown encoding: {encoding!r}")
    build = packed_operands if materialize == "packed" else int8_plane_operands
    op = build(q, sign, scale, offset, cols)
    if codec != "raw":
        op = planes_mod.encode_operands(op, codec)
    return op


def is_cim_operands(w) -> bool:
    """True if ``w`` is a crossbar operand dict rather than a dense array."""
    return isinstance(w, dict) and ("planes_packed" in w or "splanes" in w)


def shard_operands(op: dict[str, jax.Array], *, axis: int, index: int, n: int) -> dict[str, jax.Array]:
    """Slice a crossbar operand dict along one logical weight axis — shard
    ``index`` of ``n`` for a tensor-parallel layout (column-parallel slices
    ``axis=-1``/N, row-parallel slices ``axis=-2``/K).

    Exactness contract: ``densify_operands(shard_operands(op, ...)) ==
    densify_operands(op)[..., slice]`` byte-for-byte — no repacking, no
    requantization.  The bit planes store K packed 8-per-byte, so a K slice
    must land on byte boundaries: ``(K // n) % 8 == 0`` is required (the TP
    planner, ``parallel.tp.plan_tp``, only emits packed K-sharding when this
    holds and degrades to replication otherwise).  Per-field rules:

    * ``planes_packed`` / ``stuck0_packed`` / ``stuck1_packed``
      uint8[..., cols, K8, N] and ``sign_packed`` uint8[..., K8, N]: slice N
      on the last axis, or bytes ``k0//8:k1//8`` of the packed-K axis.
    * ``kdim`` [..., K, 0]: the zero-width true-K marker — slice its K axis
      on K shards so consumers recover the shard-local contraction length.
    * ``plane_ids`` [..., cols]: the col_perm plane order is a property of
      the plane AXIS, untouched by either slicing — passes through.
    * ``plane_tile_nz`` [..., cols, ceil(K8/16)]: flags are reduced over N,
      so an N slice keeps them (conservative: a tile zero only in this shard
      still reads as nonzero — a missed skip, never a wrong read); a K slice
      realigns the 16-byte tile grid, so the flags are DROPPED (they are a
      kernel skip hint, absence just disables skipping).
    * ``row_atten`` [..., K]: IR-drop folds into activations per input row —
      slice on K shards, replicate on N shards.
    * ``scale`` / ``offset`` / ``plane_gain``: per-tensor (or per-plane)
      scalars — replicated.

    ``splanes`` int8[..., cols, K, N] dicts shard too (no byte constraint).
    """
    if axis not in (-1, -2):
        raise ValueError(f"axis must be -1 (N) or -2 (K), got {axis}")
    if not 0 <= index < n:
        raise ValueError(f"shard index {index} outside [0, {n})")
    packed = "planes_packed" in op
    planes = op["planes_packed"] if packed else op["splanes"]
    if axis == -1:
        dim = planes.shape[-1]
    else:
        dim = op["kdim"].shape[-2] if packed else planes.shape[-2]
    if dim % n:
        raise ValueError(f"axis {axis} extent {dim} not divisible by {n} shards")
    lo, hi = index * (dim // n), (index + 1) * (dim // n)
    if packed and axis == -2 and (lo % 8 or hi % 8):
        raise ValueError(
            f"packed K shard [{lo}:{hi}) not byte-aligned (K//n must be % 8)"
        )
    out = {}
    for name, arr in op.items():
        if name in ("scale", "offset", "plane_gain", "plane_ids"):
            out[name] = arr
        elif name == "plane_tile_nz":
            if axis == -1:
                out[name] = arr  # N-reduced flags: conservative, still honest
        elif name == "row_atten":
            out[name] = arr[..., lo:hi] if axis == -2 else arr
        elif name == "kdim":
            out[name] = arr[..., lo:hi, :] if axis == -2 else arr
        elif axis == -1:
            out[name] = arr[..., lo:hi]
        elif name == "sign_packed":
            out[name] = arr[..., lo // 8 : hi // 8, :]
        else:  # planes_packed / stuck0_packed / stuck1_packed / splanes
            sl = (lo // 8, hi // 8) if packed else (lo, hi)
            out[name] = arr[..., sl[0] : sl[1], :]
    return out


def densify_operands(op: dict[str, jax.Array]) -> jax.Array:
    """Packed operand dict -> dense achieved weights f32[..., K, N].

    The once-per-dispatch decompression the serving steps use on backends
    without the packed Pallas kernel (see ``launch.steps``): unpack, weight,
    sign, scale, offset — exactly ``bitslice.dequantize`` of the achieved
    planes, so serving tokens match the dense materialization.

    Non-ideal operand dicts (``core.nonideal.perturb_operands``) densify to
    the weights a *faulty read* yields: stuck masks applied to the packed
    words, drift gains folded into the plane weighting, and the IR-drop row
    attenuation folded into the rows — ``x @ (diag(a) W) == (x * a) @ W``,
    so this matches ``cim_linear``'s activation-side fold exactly.
    """
    from repro.kernels.cim_matmul import ref as cim_ref

    planes = op["planes_packed"]
    if planes.ndim > 3:  # stacked layers / experts
        return jax.vmap(densify_operands)(op)
    if "stuck0_packed" in op:
        planes = (planes & ~op["stuck0_packed"]) | op["stuck1_packed"]
    k = op["kdim"].shape[-2]
    # plane_ids (col_perm serving codec) decodes AFTER the stuck-mask read:
    # faults attach to stored bit lines, significance to logical planes
    w = cim_ref.unpack_weights(
        planes, op["sign_packed"], k, op.get("plane_gain"), op.get("plane_ids")
    )
    w = w * op["scale"] + op["offset"]
    if "row_atten" in op:
        w = w * op["row_atten"][..., :, None]
    return w


def densify_packed(params):
    """Replace every *packed* operand dict in a params pytree with its dense
    achieved weights; int8 ``splanes`` dicts (the faithful per-step bit-slice
    simulation baseline) and dense leaves pass through untouched."""

    def walk(tree):
        if isinstance(tree, dict):
            if "planes_packed" in tree:
                return densify_operands(tree)
            return {kk: walk(v) for kk, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return tree

    return walk(params)


def prepare_linear(
    w: jax.Array,
    spec: CrossbarSpec = CrossbarSpec(),
    *,
    materialize: str = "int8",
    codec: str = "raw",
) -> dict[str, jax.Array]:
    """Quantize a [K, N] weight matrix into crossbar operands for cim_linear.

    Sections here are per (row-block of K): the natural, unpermuted layout —
    this is the *execution* path (what the array computes), independent of the
    *programming order* optimizations which live in the planner.
    ``materialize="int8"`` keeps the original signed int8 planes (plus the
    ``encoding`` tag, for parity with older callers); ``"packed"`` returns the
    bit-packed serving operands, optionally codec-encoded
    (``planes.encode_operands`` — exact, see ``operands_from_dense``).
    """
    if w.ndim != 2:
        raise ValueError("prepare_linear expects a 2-D weight")
    if codec != "raw" and materialize != "packed":
        raise ValueError(
            f"codec {codec!r} encodes packed serving operands; materialize "
            f"{materialize!r} has no stored-plane layout"
        )
    qt = bitslice.quantize(w, spec.cols, spec.encoding)
    q = qt.q.reshape(w.shape)
    sign = qt.sign.reshape(w.shape)
    if materialize == "packed":
        op = packed_operands(q, sign, qt.scale, qt.offset, spec.cols)
        if codec != "raw":
            op = planes_mod.encode_operands(op, codec)
        return op
    if materialize != "int8":
        raise ValueError(f"unknown materialize: {materialize!r}")
    ops = int8_plane_operands(q, sign, qt.scale, qt.offset, spec.cols)
    ops["encoding"] = spec.encoding
    return ops


def cim_linear(x: jax.Array, operands: dict[str, jax.Array], *, use_kernel: bool = False) -> jax.Array:
    """y = x @ w_hat computed bit-plane by bit-plane (crossbar dataflow).

    ``use_kernel=True`` runs the compiled Pallas kernel on TPU and the
    portable jnp reference elsewhere (dispatch policy above); packed operands
    take the bit-packed kernel/ref, int8 operands the plane einsum paths.

    Non-ideal operand dicts (``core.nonideal.perturb_operands``) read
    through the fault masks — ``(planes & ~stuck0) | stuck1`` — fold the
    IR-drop ``row_atten`` into the activations (``x @ diag(a)W == (x*a)@W``,
    so the rank-1 offset correction below stays consistent), and route
    drift ``plane_gain`` through the portable ref: the Pallas kernel's
    unpack loop carries exact power-of-two weights only, so drifted reads
    always take the reference path (clean reads keep the kernel).
    """
    from repro.kernels.cim_matmul import ops as cim_ops
    from repro.kernels.cim_matmul import ref as cim_ref

    kernel = use_kernel and on_tpu()
    if "planes_packed" in operands:
        planes = operands["planes_packed"]
        if "stuck0_packed" in operands:
            planes = (planes & ~operands["stuck0_packed"]) | operands["stuck1_packed"]
        if "row_atten" in operands:
            x = x * operands["row_atten"]
        gain = operands.get("plane_gain")
        pids = operands.get("plane_ids")
        if gain is not None or pids is not None:
            # permuted plane axis (col_perm codec) and drifted gains both
            # need per-plane weights the Pallas kernel's power-of-two unpack
            # loop does not carry — exact ref path, same dispatch rule as
            # plane_gain has always taken
            y = cim_ref.cim_matmul_packed(
                x, planes, operands["sign_packed"], operands["scale"], gain, pids
            )
        elif kernel:
            # const_rle zero-tile flags drive the kernel's K-block skipping
            # (bit-exact: a skipped tile contributes exact zeros)
            y = cim_ops.cim_matmul_packed(
                x, planes, operands["sign_packed"], operands["scale"],
                tile_nz=operands.get("plane_tile_nz"),
            )
        else:
            y = cim_ref.cim_matmul_packed(x, planes, operands["sign_packed"], operands["scale"])
    elif kernel or (use_kernel and "encoding" in operands):
        # explicit use_kernel on a legacy operand dict keeps the historical
        # behavior (interpret-mode Pallas off-TPU) for kernel parity tests
        y = cim_ops.cim_matmul(x, operands["splanes"], operands["scale"])
    else:
        y = cim_ref.cim_matmul(x, operands["splanes"], operands["scale"])
    encoding = operands.get("encoding")
    if encoding == "offset_binary" or encoding is None:
        # rank-1 digital correction: x @ (Q*scale + offset) = core + sum(x)*offset.
        # Array-only operand dicts carry no encoding tag; offset is exactly 0
        # for sign_magnitude, so applying it unconditionally is a no-op there.
        y = y + jnp.sum(x, axis=-1, keepdims=True) * operands["offset"]
    return y


# ---------------------------------------------------------------------------
# Fidelity probes
# ---------------------------------------------------------------------------

def output_mse(f, params_a, params_b, batch) -> jax.Array:
    """Mean squared error between model outputs under two parameter sets."""
    ya, yb = f(params_a, batch), f(params_b, batch)
    return jnp.mean((ya - yb) ** 2)


def logit_kl(f, params_a, params_b, batch) -> jax.Array:
    """KL(softmax(f_a) || softmax(f_b)) averaged over positions.

    The direct analogue of a 'accuracy within 1%' check when no labelled
    eval set exists: small logit KL bounds the label-flip probability.
    """
    la, lb = f(params_a, batch), f(params_b, batch)
    pa = jax.nn.log_softmax(la, axis=-1)
    pb = jax.nn.log_softmax(lb, axis=-1)
    return jnp.mean(jnp.sum(jnp.exp(pa) * (pa - pb), axis=-1))


def top1_agreement(f, params_a, params_b, batch) -> jax.Array:
    """Fraction of positions where argmax predictions agree (accuracy proxy)."""
    la, lb = f(params_a, batch), f(params_b, batch)
    return jnp.mean((jnp.argmax(la, -1) == jnp.argmax(lb, -1)).astype(jnp.float32))


def deploy_and_probe(
    f,
    params,
    batch,
    spec: CrossbarSpec = CrossbarSpec(),
    config: PlannerConfig = PlannerConfig(),
) -> tuple[DeploymentPlan, dict[str, float]]:
    """One-call: plan deployment, swap weights, measure fidelity."""
    from repro.core.planner import build_deployment, deploy_params

    plan = build_deployment(params, spec, config)
    params_hat = deploy_params(params, plan)
    probes = {
        "output_mse": float(output_mse(f, params, params_hat, batch)),
        "logit_kl": float(logit_kl(f, params, params_hat, batch)),
        "top1_agreement": float(top1_agreement(f, params, params_hat, batch)),
    }
    return plan, probes
