"""CIM crossbar forward simulation + model fidelity probes.

``cim_linear`` computes a linear layer the way the analog array does: as a
sum over bit columns of {0,1}-plane dot products scaled by powers of two
(sign applied digitally for sign_magnitude; rank-1 offset correction for
offset_binary).  On TPU this dispatches to the fused Pallas ``cim_matmul``
kernel (one VMEM-resident activation tile accumulates all bit planes); on CPU
it uses the pure-jnp reference.  Numerically both equal ``x @ w_hat`` for the
dequantized planes — the value of the simulation is that *error-injected*
planes (bit stucking, stuck-at faults) flow through the same path the
hardware would use.

``logit_kl`` / ``output_mse`` are the accuracy-preservation probes used by
the benchmarks when a labelled task is unavailable (DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitslice
from repro.core.planner import CrossbarSpec, DeploymentPlan, PlannerConfig, analyze_tensor


def prepare_linear(
    w: jax.Array, spec: CrossbarSpec = CrossbarSpec()
) -> dict[str, jax.Array]:
    """Quantize a [K, N] weight matrix into crossbar operands for cim_linear.

    Sections here are per (row-block of K): the natural, unpermuted layout —
    this is the *execution* path (what the array computes), independent of the
    *programming order* optimizations which live in the planner.
    """
    if w.ndim != 2:
        raise ValueError("prepare_linear expects a 2-D weight")
    qt = bitslice.quantize(w, spec.cols, spec.encoding)
    q = qt.q.reshape(w.shape)
    sign = qt.sign.reshape(w.shape)
    planes = bitslice.bitplanes(q, spec.cols)  # bool[K, N, cols]
    # signed planes in {-1, 0, 1}: sign folded in so the matmul core is a
    # plain integer dot product per column (kernels/cim_matmul contract:
    # splanes is [cols, K, N] with plane 0 = LSB).
    splanes = jnp.moveaxis(planes.astype(jnp.int8) * sign[..., None], -1, 0)
    return {
        "splanes": splanes,
        "scale": qt.scale,
        "offset": qt.offset,
        "encoding": spec.encoding,
    }


def cim_linear(x: jax.Array, operands: dict[str, jax.Array], *, use_kernel: bool = False) -> jax.Array:
    """y = x @ w_hat computed bit-plane by bit-plane (crossbar dataflow)."""
    if use_kernel:
        from repro.kernels.cim_matmul import ops as cim_ops

        y = cim_ops.cim_matmul(x, operands["splanes"], operands["scale"])
    else:
        from repro.kernels.cim_matmul import ref as cim_ref

        y = cim_ref.cim_matmul(x, operands["splanes"], operands["scale"])
    if operands["encoding"] == "offset_binary":
        # rank-1 digital correction: x @ (Q*scale + offset) = core + sum(x)*offset
        y = y + jnp.sum(x, axis=-1, keepdims=True) * operands["offset"]
    return y


# ---------------------------------------------------------------------------
# Fidelity probes
# ---------------------------------------------------------------------------

def output_mse(f, params_a, params_b, batch) -> jax.Array:
    """Mean squared error between model outputs under two parameter sets."""
    ya, yb = f(params_a, batch), f(params_b, batch)
    return jnp.mean((ya - yb) ** 2)


def logit_kl(f, params_a, params_b, batch) -> jax.Array:
    """KL(softmax(f_a) || softmax(f_b)) averaged over positions.

    The direct analogue of a 'accuracy within 1%' check when no labelled
    eval set exists: small logit KL bounds the label-flip probability.
    """
    la, lb = f(params_a, batch), f(params_b, batch)
    pa = jax.nn.log_softmax(la, axis=-1)
    pb = jax.nn.log_softmax(lb, axis=-1)
    return jnp.mean(jnp.sum(jnp.exp(pa) * (pa - pb), axis=-1))


def top1_agreement(f, params_a, params_b, batch) -> jax.Array:
    """Fraction of positions where argmax predictions agree (accuracy proxy)."""
    la, lb = f(params_a, batch), f(params_b, batch)
    return jnp.mean((jnp.argmax(la, -1) == jnp.argmax(lb, -1)).astype(jnp.float32))


def deploy_and_probe(
    f,
    params,
    batch,
    spec: CrossbarSpec = CrossbarSpec(),
    config: PlannerConfig = PlannerConfig(),
) -> tuple[DeploymentPlan, dict[str, float]]:
    """One-call: plan deployment, swap weights, measure fidelity."""
    from repro.core.planner import build_deployment, deploy_params

    plan = build_deployment(params, spec, config)
    params_hat = deploy_params(params, plan)
    probes = {
        "output_mse": float(output_mse(f, params, params_hat, batch)),
        "logit_kl": float(logit_kl(f, params, params_hat, batch)),
        "top1_agreement": float(top1_agreement(f, params, params_hat, batch)),
    }
    return plan, probes
