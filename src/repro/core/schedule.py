"""Multi-crossbar reprogramming schedules and thread balancing (§III.B–C).

Given S sections (in SWS order) and L physical crossbars programmable in
parallel, a *schedule* assigns each crossbar a chain of sections to walk:

* **stride-L** — crossbar ``i`` programs sections ``i, i+L, i+2L, …``: every
  step jumps L positions in the sorted list, so consecutive programs differ
  more (larger magnitude gap -> more bit transitions).
* **stride-1** — crossbar ``i`` is seeded at offset ``i * ceil(S/L)`` and then
  walks *consecutive* sections.  Each step reprograms between adjacent sorted
  sections; only the L seed programs are 'far'.  This is the paper's winning
  schedule (Fig. 3b, Fig. 6b).

Pricing a schedule is embarrassingly pair-parallel: every job (one crossbar
reprogram) is an independent ``popcount(prev ^ cur)``.  ``schedule_job_costs``
therefore flattens *all* chains into one batched pairs array — ``prev[i]`` /
``cur[i]`` section indices per job, with a synthetic index for the pristine
all-zero state — and prices the whole schedule in a single
``price_pairs`` call (Pallas ``hamming`` kernel on TPU, portable
``lax.population_count`` elsewhere).  Inputs may be bool planes
``[S, rows, cols]`` (packed on the fly) or canonical packed planes
``uint8[S, W, cols]`` from ``bitslice.section_planes_packed``.

Thread balancing (§III.C, Fig. 4): programming engines run in lockstep rounds
(one crossbar program per thread per round); a round lasts as long as its
most expensive job.  The paper's greedy groups *similar-cost* jobs into the
same round (sort all jobs by cost, chunk into rounds of T), which drives
``sum_r max(round_r)`` down to ~``sum(costs)/T`` — the ideal T-way speedup.
An LPT (longest-processing-time) makespan balancer is included for the
asynchronous-threads interpretation as an ablation.
"""
from __future__ import annotations

import heapq
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitslice
from repro.core import cost as cost_lib
from repro.kernels.hamming import ops as hamming_ops


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def stride_l_chains(s: int, l: int) -> list[np.ndarray]:
    """Chains for stride-L scheduling: chains[i] = [i, i+L, i+2L, ...].

    Chains are host numpy arrays: they encode static schedule *structure*
    (always built from concrete section counts), which keeps them usable as
    constants inside jitted pricing functions.
    """
    return [np.arange(i, s, l, dtype=np.int32) for i in range(min(l, s))]


def stride_1_chains(s: int, l: int) -> list[np.ndarray]:
    """Chains for stride-1 scheduling: L contiguous blocks of the sorted list."""
    block = math.ceil(s / l)
    chains = []
    for i in range(l):
        lo, hi = i * block, min((i + 1) * block, s)
        if lo >= hi:
            break
        chains.append(np.arange(lo, hi, dtype=np.int32))
    return chains


def make_chains(s: int, l: int, kind: str) -> list[np.ndarray]:
    if kind == "stride1":
        return stride_1_chains(s, l)
    if kind == "strideL":
        return stride_l_chains(s, l)
    raise ValueError(f"unknown schedule kind: {kind!r}")


# ---------------------------------------------------------------------------
# Batched pair pricing
# ---------------------------------------------------------------------------

def chain_pairs(
    chains: list[jnp.ndarray], *, include_initial: bool = True
) -> tuple[np.ndarray, np.ndarray]:
    """Flatten chains into one batched (prev, cur) job-index array.

    Job ``i`` reprograms a crossbar holding section ``prev[i]`` with section
    ``cur[i]``; ``prev == -1`` denotes the pristine all-zero crossbar.  Jobs
    appear chain by chain in walk order, matching the historical
    per-chain concatenation contract of :func:`schedule_job_costs`.

    Chains must be concrete (they always are: schedules are built from static
    section counts, never traced values).
    """
    prevs, curs = [], []
    for c in chains:
        c = np.asarray(c, dtype=np.int32)
        if include_initial:
            prevs.append(np.concatenate([np.array([-1], np.int32), c[:-1]]))
            curs.append(c)
        else:
            prevs.append(c[:-1])
            curs.append(c[1:])
    return np.concatenate(prevs), np.concatenate(curs)


def _as_packed(planes: jax.Array) -> jax.Array:
    """Accept bool[S, rows, cols] or packed uint8[S, W, cols] planes."""
    if planes.dtype == jnp.uint8:
        return planes
    return bitslice.pack_rows(planes)


def schedule_job_costs(
    planes: jax.Array,
    chains: list[jnp.ndarray],
    *,
    include_initial: bool = True,
) -> jax.Array:
    """Flat per-job costs (one job = one crossbar reprogram) -> int32[njobs].

    All chain steps are priced in ONE batched ``price_pairs`` call on packed
    planes — no per-chain Python loop over XORs.
    """
    packed = _as_packed(planes)
    prev, cur = chain_pairs(chains, include_initial=include_initial)
    if prev.shape[0] == 0:
        return jnp.zeros((0,), jnp.int32)
    # Prepend the pristine all-zero state so prev == -1 gathers zeros.
    states = jnp.concatenate(
        [jnp.zeros((1,) + packed.shape[1:], packed.dtype), packed], axis=0
    )
    return hamming_ops.price_pairs(states[prev + 1], states[cur + 1])


def schedule_transitions(
    planes: jax.Array,
    chains: list[jnp.ndarray],
    *,
    include_initial: bool = True,
) -> jax.Array:
    """Total transitions across all crossbars -> int32[] (sum over chains)."""
    return jnp.sum(schedule_job_costs(planes, chains, include_initial=include_initial))


def schedule_job_costs_looped(
    planes: jax.Array,
    chains: list[jnp.ndarray],
    *,
    include_initial: bool = True,
) -> jax.Array:
    """Seed reference: per-chain Python loop over bool-plane XOR sums.

    Kept as the oracle the batched packed path is parity-tested against and
    as the baseline ``benchmarks/planner_throughput.py`` measures speedup
    over (``PlannerConfig(impl="bool")``).
    """
    per_chain = [
        cost_lib.consecutive_costs(planes, c, include_initial=include_initial) for c in chains
    ]
    return jnp.concatenate(per_chain)


# ---------------------------------------------------------------------------
# Thread balancing
# ---------------------------------------------------------------------------

def lockstep_time(job_costs: jax.Array, threads: int, *, sort_jobs: bool) -> jax.Array:
    """Lockstep-rounds total time: sum over rounds of the round's max cost.

    ``sort_jobs=False`` is the unsorted baseline (jobs in arrival order, each
    round mixes small and large costs and is bottlenecked by the largest);
    ``sort_jobs=True`` is the paper's greedy similar-cost grouping.
    """
    n = job_costs.shape[0]
    if sort_jobs:
        job_costs = jnp.sort(job_costs)[::-1]
    pad = (-n) % threads
    padded = jnp.pad(job_costs, (0, pad))
    rounds = padded.reshape(-1, threads)
    return jnp.sum(jnp.max(rounds, axis=1))


def lockstep_time_host(job_costs, threads: int, *, sort_jobs: bool) -> np.int64:
    """Host int64 twin of :func:`lockstep_time` (same algorithm, same values).

    Used by the planner's packed fast path: whole-tensor totals can exceed
    int32 at extreme scale (> 2^31 transitions), which the device path —
    jax without x64 — cannot represent.  Per-job costs themselves are tiny
    (<= rows * cols bits), so int32 inputs are always safe.
    """
    costs = np.asarray(job_costs, dtype=np.int64)
    if sort_jobs:
        costs = np.sort(costs)[::-1]
    pad = (-costs.shape[0]) % threads
    if pad:
        costs = np.concatenate([costs, np.zeros(pad, np.int64)])
    rounds = costs.reshape(-1, threads)
    return np.sum(rounds.max(axis=1), dtype=np.int64) if rounds.size else np.int64(0)


def lockstep_speedup(job_costs: jax.Array, threads: int, *, sort_jobs: bool) -> jax.Array:
    """Parallel speedup vs programming all jobs sequentially on one engine."""
    seq = jnp.sum(job_costs)
    t = lockstep_time(job_costs, threads, sort_jobs=sort_jobs)
    return seq.astype(jnp.float32) / jnp.maximum(t.astype(jnp.float32), 1.0)


def lpt_assignment(
    job_costs: jax.Array,
    threads: int,
    *,
    initial_loads: np.ndarray | None = None,
    capacity: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Longest-processing-time greedy makespan balancing (async ablation).

    Returns (thread_id int32[njobs], thread_loads int64[threads]).  Runs on
    the host: the greedy is inherently sequential, and host numpy gives the
    int64 accumulators large deployments need (the former int32 ``lax.scan``
    accumulator wrapped past ~2^31 total transitions per thread; jax without
    x64 cannot widen it).  Ties break toward the lowest thread id, matching
    the previous ``argmin`` behavior.

    ``initial_loads`` seeds each thread's starting load (the crossbar pool's
    wear-leveling assignment seeds with accumulated per-crossbar wear, so
    heavy chains land on the least-worn crossbars).  ``capacity`` bounds how
    many jobs one thread may take; ``capacity=1`` turns the greedy into a
    min-max matching (each chain on a distinct physical crossbar).  Returned
    loads include the initial loads.
    """
    costs = np.asarray(job_costs, dtype=np.int64)
    if capacity is not None and costs.shape[0] > threads * capacity:
        raise ValueError(
            f"{costs.shape[0]} jobs exceed {threads} threads x capacity {capacity}"
        )
    order = np.argsort(-costs, kind="stable")
    tids = np.empty(costs.shape[0], np.int32)
    if initial_loads is None:
        loads = np.zeros(threads, np.int64)
    else:
        loads = np.asarray(initial_loads, dtype=np.int64).copy()
        if loads.shape != (threads,):
            raise ValueError(f"initial_loads shape {loads.shape} != ({threads},)")
    taken = np.zeros(threads, np.int64)
    heap = [(int(loads[t]), t) for t in range(threads)]
    heapq.heapify(heap)
    for j in order:
        while True:
            load, t = heapq.heappop(heap)
            if capacity is None or taken[t] < capacity:
                break
            # thread already full: drop it from the heap for good
        taken[t] += 1
        tids[j] = t
        loads[t] = load + int(costs[j])
        heapq.heappush(heap, (int(loads[t]), t))
    return tids, loads


def lpt_makespan(job_costs: jax.Array, threads: int) -> np.int64:
    _, loads = lpt_assignment(job_costs, threads)
    return np.max(loads)
