"""Multi-crossbar reprogramming schedules and thread balancing (§III.B–C).

Given S sections (in SWS order) and L physical crossbars programmable in
parallel, a *schedule* assigns each crossbar a chain of sections to walk:

* **stride-L** — crossbar ``i`` programs sections ``i, i+L, i+2L, …``: every
  step jumps L positions in the sorted list, so consecutive programs differ
  more (larger magnitude gap -> more bit transitions).
* **stride-1** — crossbar ``i`` is seeded at offset ``i * ceil(S/L)`` and then
  walks *consecutive* sections.  Each step reprograms between adjacent sorted
  sections; only the L seed programs are 'far'.  This is the paper's winning
  schedule (Fig. 3b, Fig. 6b).

Thread balancing (§III.C, Fig. 4): programming engines run in lockstep rounds
(one crossbar program per thread per round); a round lasts as long as its
most expensive job.  The paper's greedy groups *similar-cost* jobs into the
same round (sort all jobs by cost, chunk into rounds of T), which drives
``sum_r max(round_r)`` down to ~``sum(costs)/T`` — the ideal T-way speedup.
An LPT (longest-processing-time) makespan balancer is included for the
asynchronous-threads interpretation as an ablation.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import cost as cost_lib


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def stride_l_chains(s: int, l: int) -> list[jnp.ndarray]:
    """Chains for stride-L scheduling: chains[i] = [i, i+L, i+2L, ...]."""
    return [jnp.arange(i, s, l, dtype=jnp.int32) for i in range(min(l, s))]


def stride_1_chains(s: int, l: int) -> list[jnp.ndarray]:
    """Chains for stride-1 scheduling: L contiguous blocks of the sorted list."""
    block = math.ceil(s / l)
    chains = []
    for i in range(l):
        lo, hi = i * block, min((i + 1) * block, s)
        if lo >= hi:
            break
        chains.append(jnp.arange(lo, hi, dtype=jnp.int32))
    return chains


def make_chains(s: int, l: int, kind: str) -> list[jnp.ndarray]:
    if kind == "stride1":
        return stride_1_chains(s, l)
    if kind == "strideL":
        return stride_l_chains(s, l)
    raise ValueError(f"unknown schedule kind: {kind!r}")


def schedule_transitions(
    planes: jax.Array,
    chains: list[jnp.ndarray],
    *,
    include_initial: bool = True,
) -> jax.Array:
    """Total transitions across all crossbars -> int32[] (sum over chains)."""
    totals = [
        cost_lib.chain_transitions(planes, c, include_initial=include_initial) for c in chains
    ]
    return jnp.sum(jnp.stack(totals))


def schedule_job_costs(
    planes: jax.Array,
    chains: list[jnp.ndarray],
    *,
    include_initial: bool = True,
) -> jax.Array:
    """Flat per-job costs (one job = one crossbar reprogram) -> int32[njobs]."""
    per_chain = [
        cost_lib.consecutive_costs(planes, c, include_initial=include_initial) for c in chains
    ]
    return jnp.concatenate(per_chain)


# ---------------------------------------------------------------------------
# Thread balancing
# ---------------------------------------------------------------------------

def lockstep_time(job_costs: jax.Array, threads: int, *, sort_jobs: bool) -> jax.Array:
    """Lockstep-rounds total time: sum over rounds of the round's max cost.

    ``sort_jobs=False`` is the unsorted baseline (jobs in arrival order, each
    round mixes small and large costs and is bottlenecked by the largest);
    ``sort_jobs=True`` is the paper's greedy similar-cost grouping.
    """
    n = job_costs.shape[0]
    if sort_jobs:
        job_costs = jnp.sort(job_costs)[::-1]
    pad = (-n) % threads
    padded = jnp.pad(job_costs, (0, pad))
    rounds = padded.reshape(-1, threads)
    return jnp.sum(jnp.max(rounds, axis=1))


def lockstep_speedup(job_costs: jax.Array, threads: int, *, sort_jobs: bool) -> jax.Array:
    """Parallel speedup vs programming all jobs sequentially on one engine."""
    seq = jnp.sum(job_costs)
    t = lockstep_time(job_costs, threads, sort_jobs=sort_jobs)
    return seq.astype(jnp.float32) / jnp.maximum(t.astype(jnp.float32), 1.0)


def lpt_assignment(job_costs: jax.Array, threads: int) -> tuple[jax.Array, jax.Array]:
    """Longest-processing-time greedy makespan balancing (async ablation).

    Returns (thread_id[njobs], thread_loads[threads]).  Implemented as a scan:
    jobs sorted descending, each assigned to the least-loaded thread.
    """
    order = jnp.argsort(-job_costs, stable=True)

    def step(loads, j):
        t = jnp.argmin(loads)
        return loads.at[t].add(job_costs[j].astype(loads.dtype)), t.astype(jnp.int32)

    loads0 = jnp.zeros((threads,), dtype=jnp.int32)
    loads, tids_sorted = jax.lax.scan(step, loads0, order)
    tids = jnp.zeros_like(tids_sorted).at[order].set(tids_sorted)
    return tids, loads


def lpt_makespan(job_costs: jax.Array, threads: int) -> jax.Array:
    _, loads = lpt_assignment(job_costs, threads)
    return jnp.max(loads)
