"""Deployment planner: DNN params -> crossbar programming plan + cost report.

This is the integration point that makes the paper's technique a first-class
framework feature: ``build_deployment`` consumes any pytree of weights (all
matmul weights of the assigned LM architectures), quantizes and bit-slices
them, applies Sorted Weight Sectioning, chooses a multi-crossbar schedule,
prices the reprogramming workload against the unsorted ISAAC/CASCADE-style
baseline, applies bit stucking, and returns both the metrics and the
*achieved* (error-injected) weights for accuracy evaluation.

Embedding-style lookup tables are excluded (CIM crossbars compute dot
products; lookups never map to them — DESIGN.md §4); callers control this
via ``PlannerConfig.exclude`` name patterns and ``min_size``/``min_ndim``.

Internal invariant: every tensor is handled as a *padded flat vector* of
length ``S * rows`` together with ``perm_full`` — a permutation of
``range(S * rows)`` mapping crossbar slot -> source element (source indices
``>= n`` are zero padding).  All orderings (magnitude sort, beyond-paper TSP
section reorder) compose into ``perm_full``, and reconstruction is a single
scatter, so index matching stays exact no matter how sections are shuffled.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import bitslice, schedule, stucking, sws


@dataclasses.dataclass(frozen=True)
class CrossbarSpec:
    """Geometry + encoding of the physical crossbars (paper default 128x10)."""

    rows: int = 128
    cols: int = 10
    encoding: str = "sign_magnitude"


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    sws: bool = True
    schedule: str = "stride1"  # "stride1" | "strideL"
    crossbars: int = 16  # L physical crossbars programmed in parallel
    threads: int = 64  # T lockstep programming engines (Fig. 7)
    p_stuck: float = 1.0  # 1.0 = full reprogramming (no stucking)
    stuck_cols: int = 1
    include_initial: bool = True
    section_order: str = "magnitude"  # "magnitude" | "tsp" (beyond-paper)
    min_size: int = 4096
    min_ndim: int = 2
    exclude: tuple[str, ...] = ("embed", "embedding", "lm_head", "pos_emb")
    seed: int = 0


@dataclasses.dataclass
class TensorReport:
    name: str
    shape: tuple[int, ...]
    n_weights: int
    n_sections: int
    transitions_baseline: int  # unsorted order, full reprogramming
    transitions_sws: int  # SWS order, full reprogramming
    transitions_final: int  # SWS order + bit stucking at p
    lockstep_time_unsorted: int
    lockstep_time_greedy: int
    lockstep_time_ideal: float
    quant_mse: float  # ||w - w_hat||^2 / n  (quantization + stucking error)

    @property
    def sws_speedup(self) -> float:
        return self.transitions_baseline / max(self.transitions_sws, 1)

    @property
    def total_speedup(self) -> float:
        return self.transitions_baseline / max(self.transitions_final, 1)


@dataclasses.dataclass
class DeploymentPlan:
    spec: CrossbarSpec
    config: PlannerConfig
    reports: dict[str, TensorReport]
    deployed: dict[str, jax.Array]  # name -> achieved weights (w_hat)

    def totals(self) -> dict[str, float]:
        base = sum(r.transitions_baseline for r in self.reports.values())
        sws_t = sum(r.transitions_sws for r in self.reports.values())
        fin = sum(r.transitions_final for r in self.reports.values())
        lk_u = sum(r.lockstep_time_unsorted for r in self.reports.values())
        lk_g = sum(r.lockstep_time_greedy for r in self.reports.values())
        lk_i = sum(r.lockstep_time_ideal for r in self.reports.values())
        return {
            "transitions_baseline": base,
            "transitions_sws": sws_t,
            "transitions_final": fin,
            "sws_speedup": base / max(sws_t, 1),
            "total_speedup": base / max(fin, 1),
            "lockstep_speedup_unsorted": base / lk_u if lk_u else float("nan"),
            "lockstep_speedup_greedy": sws_t / lk_g if lk_g else float("nan"),
            "lockstep_time_ideal": lk_i,
        }


def _sort_key(flat_padded: jax.Array, encoding: str) -> jax.Array:
    # sign_magnitude stores |w|: sort by magnitude so bit patterns sort too.
    # offset_binary stores w - min: sort by value for the same property.
    return jnp.abs(flat_padded) if encoding == "sign_magnitude" else flat_padded


def _perm_full(
    flat_padded: jax.Array, spec: CrossbarSpec, config: PlannerConfig, q_padded: jax.Array
) -> jax.Array:
    """Slot -> source-element permutation of length S*rows (see module doc)."""
    total = flat_padded.shape[0]
    if not config.sws:
        return jnp.arange(total, dtype=jnp.int32)
    perm = jnp.argsort(_sort_key(flat_padded, spec.encoding), stable=True).astype(jnp.int32)
    if config.section_order == "tsp":
        planes = bitslice.bitplanes(q_padded[perm].reshape(-1, spec.rows), spec.cols)
        order = sws.tsp_greedy_order(bitslice.pack_rows(planes))
        slot = (order[:, None] * spec.rows + jnp.arange(spec.rows, dtype=jnp.int32)).reshape(-1)
        perm = perm[slot]
    return perm


def analyze_tensor(
    w: jax.Array,
    spec: CrossbarSpec,
    config: PlannerConfig,
    key: jax.Array,
    name: str = "w",
) -> tuple[TensorReport, jax.Array]:
    """Full paper pipeline for one weight tensor.

    Returns (report, w_hat) where w_hat carries the achieved (quantized +
    stuck-bit) values in the tensor's logical layout.
    """
    flat = jnp.ravel(w).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % spec.rows
    flat_padded = jnp.pad(flat, (0, pad))
    total = flat_padded.shape[0]
    s = total // spec.rows
    l = max(1, min(config.crossbars, s))

    qt = bitslice.quantize(flat, spec.cols, spec.encoding)
    q_padded = jnp.pad(qt.q, (0, pad))
    sign_padded = jnp.pad(qt.sign, (0, pad), constant_values=1)

    # --- baseline: unsorted natural order, full reprogramming --------------
    planes_u = bitslice.bitplanes(q_padded.reshape(s, spec.rows), spec.cols)
    chains = schedule.make_chains(s, l, config.schedule)
    trans_base = int(
        schedule.schedule_transitions(planes_u, chains, include_initial=config.include_initial)
    )
    jobs_u = schedule.schedule_job_costs(planes_u, chains, include_initial=config.include_initial)
    lk_unsorted = int(schedule.lockstep_time(jobs_u, config.threads, sort_jobs=False))

    # --- SWS order ----------------------------------------------------------
    perm = _perm_full(flat_padded, spec, config, q_padded)
    planes_s = bitslice.bitplanes(q_padded[perm].reshape(s, spec.rows), spec.cols)
    trans_sws = int(
        schedule.schedule_transitions(planes_s, chains, include_initial=config.include_initial)
    )
    jobs_s = schedule.schedule_job_costs(planes_s, chains, include_initial=config.include_initial)
    lk_greedy = int(schedule.lockstep_time(jobs_s, config.threads, sort_jobs=True))
    lk_ideal = float(jnp.sum(jobs_s)) / config.threads

    # --- bit stucking on the SWS schedule ------------------------------------
    if config.p_stuck < 1.0:
        total_fin, achieved = stucking.stuck_schedule(
            planes_s,
            chains,
            config.p_stuck,
            key,
            stuck_cols=config.stuck_cols,
            include_initial=config.include_initial,
        )
        trans_final = int(total_fin)
    else:
        trans_final = trans_sws
        achieved = planes_s

    # --- reconstruct achieved weights (exact index matching) ----------------
    sign_slots = sign_padded[perm].reshape(s, spec.rows)
    w_hat_slots = bitslice.dequantize_from_planes(achieved, sign_slots, qt.scale, qt.offset)
    logical = jnp.zeros((total,), dtype=jnp.float32).at[perm].set(w_hat_slots.reshape(-1))
    w_hat_flat = logical[:n]
    w_hat = w_hat_flat.reshape(w.shape).astype(w.dtype)

    quant_mse = float(jnp.mean((flat - w_hat_flat) ** 2))

    report = TensorReport(
        name=name,
        shape=tuple(w.shape),
        n_weights=int(n),
        n_sections=int(s),
        transitions_baseline=trans_base,
        transitions_sws=trans_sws,
        transitions_final=trans_final,
        lockstep_time_unsorted=lk_unsorted,
        lockstep_time_greedy=lk_greedy,
        lockstep_time_ideal=lk_ideal,
        quant_mse=quant_mse,
    )
    return report, w_hat


def iter_weights(params: Any, config: PlannerConfig):
    """Yield (name, tensor) for every crossbar-eligible weight in a pytree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    pat = re.compile("|".join(config.exclude)) if config.exclude else None
    for path, leaf in flat:
        if not hasattr(leaf, "ndim"):
            continue
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if leaf.ndim < config.min_ndim or leaf.size < config.min_size:
            continue
        if pat is not None and pat.search(name.lower()):
            continue
        yield name, leaf


def build_deployment(
    params: Any,
    spec: CrossbarSpec = CrossbarSpec(),
    config: PlannerConfig = PlannerConfig(),
    *,
    progress: Callable[[str], None] | None = None,
) -> DeploymentPlan:
    """Plan crossbar deployment for every eligible weight in ``params``."""
    key = jax.random.PRNGKey(config.seed)
    reports: dict[str, TensorReport] = {}
    deployed: dict[str, jax.Array] = {}
    for name, w in iter_weights(params, config):
        key, sub = jax.random.split(key)
        if progress:
            progress(name)
        report, w_hat = analyze_tensor(w, spec, config, sub, name=name)
        reports[name] = report
        deployed[name] = w_hat
    return DeploymentPlan(spec=spec, config=config, reports=reports, deployed=deployed)


def deploy_params(params: Any, plan: DeploymentPlan) -> Any:
    """Return a params pytree with deployed tensors replaced by w_hat."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append(plan.deployed.get(name, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)
