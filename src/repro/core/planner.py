"""Deployment planner: DNN params -> crossbar programming plan + cost report.

This is the integration point that makes the paper's technique a first-class
framework feature: ``build_deployment`` consumes any pytree of weights (all
matmul weights of the assigned LM architectures), quantizes and bit-slices
them, applies Sorted Weight Sectioning, chooses a multi-crossbar schedule,
prices the reprogramming workload against the unsorted ISAAC/CASCADE-style
baseline, applies bit stucking, and returns both the metrics and the
*achieved* (error-injected) weights for accuracy evaluation.

Embedding-style lookup tables are excluded (CIM crossbars compute dot
products; lookups never map to them — DESIGN.md §4); callers control this
via ``PlannerConfig.exclude`` name patterns and ``min_size``/``min_ndim``.

Internal invariant: every tensor is handled as a *padded flat vector* of
length ``S * rows`` together with ``perm_full`` — a permutation of
``range(S * rows)`` mapping crossbar slot -> source element (source indices
``>= n`` are zero padding).  All orderings (magnitude sort, beyond-paper TSP
section reorder) compose into ``perm_full``, and reconstruction is a single
scatter, so index matching stays exact no matter how sections are shuffled.

**Fast path (default, ``impl="packed"``).**  The whole per-tensor pipeline is
one jitted function keyed on ``(tensor shape, spec, config)``: pricing a full
LM config retraces once per *distinct* weight shape (a handful for a
transformer), not once per tensor.  Bit planes are packed exactly once into
the canonical ``uint8[S, W, cols]`` words (``bitslice.section_planes_packed``)
and every downstream consumer — the batched pair pricing in
``core.schedule``, the stucking walks in ``core.stucking``, the TSP section
reorder in ``core.sws`` — operates on packed words; bool planes are only
unpacked at the very end to reconstruct achieved weights.  Pair pricing
dispatches through ``repro.kernels.hamming.ops.price_pairs``: the compiled
Pallas ``hamming`` kernel on TPU, a portable ``lax.population_count`` XOR on
CPU/GPU.  ``impl="bool"`` preserves the original eager bool-plane pipeline
(per-chain Python loops) as the parity oracle and benchmark baseline; both
paths share one PRNG discipline and produce bit-identical plans.
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import TYPE_CHECKING, Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitslice, planes, schedule, stucking, sws

if TYPE_CHECKING:
    from repro.core.pool import CrossbarPool


@dataclasses.dataclass(frozen=True)
class CrossbarSpec:
    """Geometry + encoding of the physical crossbars (paper default 128x10)."""

    rows: int = 128
    cols: int = 10
    encoding: str = "sign_magnitude"


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    sws: bool = True
    schedule: str = "stride1"  # "stride1" | "strideL"
    crossbars: int = 16  # L physical crossbars programmed in parallel
    threads: int = 64  # T lockstep programming engines (Fig. 7)
    p_stuck: float = 1.0  # 1.0 = full reprogramming (no stucking)
    stuck_cols: int = 1
    include_initial: bool = True
    section_order: str = "magnitude"  # "magnitude" | "tsp" (beyond-paper)
    min_size: int = 4096
    min_ndim: int = 2
    exclude: tuple[str, ...] = ("embed", "embedding", "lm_head", "pos_emb")
    seed: int = 0
    impl: str = "packed"  # "packed" (jitted fast path) | "bool" (reference)
    # chain->crossbar leveling when streaming through a CrossbarPool:
    # "none" | "rotate" | "lpt" | "fault" (fault-aware remap, core/nonideal);
    # None defers to the pool's own setting
    pool_leveling: str | None = None
    # stored-plane codec (core/planes.py): "raw" | "const_rle" | "col_perm" |
    # "col_perm_rle".  Non-raw codecs change the *physical* bits the
    # crossbars hold (and hence the priced transitions); logical planes —
    # and the deployed w_hat — decode back byte-identically.
    codec: str = "raw"


@dataclasses.dataclass
class TensorReport:
    name: str
    shape: tuple[int, ...]
    n_weights: int
    n_sections: int
    transitions_baseline: int  # unsorted order, full reprogramming
    transitions_sws: int  # SWS order, full reprogramming
    transitions_final: int  # SWS order + bit stucking at p
    lockstep_time_unsorted: int
    lockstep_time_greedy: int
    lockstep_time_ideal: float
    quant_mse: float  # ||w - w_hat||^2 / n  (quantization + stucking error)
    # dequantization constants of the achieved weights — what deploy_params
    # needs to re-materialize crossbar operands (packed / int8 planes) from
    # the dense w_hat without re-running the planner
    scale: float = 0.0
    offset: float = 0.0

    @property
    def sws_speedup(self) -> float:
        return self.transitions_baseline / max(self.transitions_sws, 1)

    @property
    def total_speedup(self) -> float:
        return self.transitions_baseline / max(self.transitions_final, 1)


@dataclasses.dataclass
class DeploymentPlan:
    spec: CrossbarSpec
    config: PlannerConfig
    reports: dict[str, TensorReport]
    deployed: dict[str, jax.Array]  # name -> achieved weights (w_hat)
    pool_stats: dict | None = None  # wear summary when built against a CrossbarPool

    def totals(self) -> dict[str, float]:
        base = sum(r.transitions_baseline for r in self.reports.values())
        sws_t = sum(r.transitions_sws for r in self.reports.values())
        fin = sum(r.transitions_final for r in self.reports.values())
        lk_u = sum(r.lockstep_time_unsorted for r in self.reports.values())
        lk_g = sum(r.lockstep_time_greedy for r in self.reports.values())
        lk_i = sum(r.lockstep_time_ideal for r in self.reports.values())
        return {
            "transitions_baseline": base,
            "transitions_sws": sws_t,
            "transitions_final": fin,
            "sws_speedup": base / max(sws_t, 1),
            "total_speedup": base / max(fin, 1),
            "lockstep_speedup_unsorted": base / lk_u if lk_u else float("nan"),
            "lockstep_speedup_greedy": sws_t / lk_g if lk_g else float("nan"),
            "lockstep_time_ideal": lk_i,
        }


def _sort_key(flat_padded: jax.Array, encoding: str) -> jax.Array:
    # sign_magnitude stores |w|: sort by magnitude so bit patterns sort too.
    # offset_binary stores w - min: sort by value for the same property.
    return jnp.abs(flat_padded) if encoding == "sign_magnitude" else flat_padded


def _perm_full_with_inverse(
    flat_padded: jax.Array, spec: CrossbarSpec, config: PlannerConfig, q_padded: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Slot -> source permutation of length S*rows, plus its inverse.

    The inverse comes for free from the host-side sort on CPU
    (``sws.stable_argsort``), letting reconstruction be a gather instead of
    a (much slower) scatter.
    """
    total = flat_padded.shape[0]
    if not config.sws:
        ar = jnp.arange(total, dtype=jnp.int32)
        return ar, ar
    perm, inv = sws.stable_argsort(
        _sort_key(flat_padded, spec.encoding),
        with_inverse=True,
        nonneg=spec.encoding == "sign_magnitude",  # key is |w|
    )
    if config.section_order == "tsp":
        packed = bitslice.section_planes_packed(q_padded[perm], spec.rows, spec.cols)
        order = sws.tsp_greedy_order(packed)
        slot = (order[:, None] * spec.rows + jnp.arange(spec.rows, dtype=jnp.int32)).reshape(-1)
        perm = perm[slot]
        inv = sws.inverse_permutation(perm)
    return perm, inv


def _perm_full(
    flat_padded: jax.Array, spec: CrossbarSpec, config: PlannerConfig, q_padded: jax.Array
) -> jax.Array:
    """Slot -> source-element permutation of length S*rows (see module doc)."""
    return _perm_full_with_inverse(flat_padded, spec, config, q_padded)[0]


def _perm_full_bool(
    flat_padded: jax.Array, spec: CrossbarSpec, config: PlannerConfig, q_padded: jax.Array
) -> jax.Array:
    """Eager twin of :func:`_perm_full` for the bool reference paths.

    Uses the seed device argsort — stable, hence the identical permutation to
    the host-callback sort of the packed path.  Kept as the ONE place the
    bool pipeline's sort discipline lives (the stateless reference and the
    pool twin both call it), so the packed/bool parity contract cannot drift
    between copies.
    """
    total = flat_padded.shape[0]
    if not config.sws:
        return jnp.arange(total, dtype=jnp.int32)
    perm = jnp.argsort(_sort_key(flat_padded, spec.encoding), stable=True).astype(jnp.int32)
    if config.section_order == "tsp":
        packed_t = bitslice.section_planes_packed(q_padded[perm], spec.rows, spec.cols)
        order = sws.tsp_greedy_order(packed_t)
        slot = (
            order[:, None] * spec.rows + jnp.arange(spec.rows, dtype=jnp.int32)
        ).reshape(-1)
        perm = perm[slot]
    return perm


@partial(jax.jit, static_argnames=("spec", "config"))
def _prep_core_pool(
    flat: jax.Array, spec: CrossbarSpec, config: PlannerConfig
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Shared per-tensor prep: quantize, baseline pricing, SWS packed planes.

    The common prefix of the stateless ``_analyze_core`` (which inlines it
    under its own jit) and of pool-mode analysis, where the stateful
    ``CrossbarPool`` performs the pricing walk itself — it must carry
    crossbar content and wear across tensors, so the jit stops at the
    canonical SWS-ordered packed planes plus the pristine-baseline job costs
    and the reconstruction aux.  Same shape-bucketed retrace behavior as
    ``_analyze_core``.
    """
    n = flat.shape[0]
    pad = (-n) % spec.rows
    flat_padded = jnp.pad(flat, (0, pad))
    total = n + pad
    s = total // spec.rows
    l = max(1, min(config.crossbars, s))

    qt = bitslice.quantize(flat, spec.cols, spec.encoding)
    q_padded = jnp.pad(qt.q, (0, pad))
    sign_padded = jnp.pad(qt.sign, (0, pad), constant_values=1)

    chains = schedule.make_chains(s, l, config.schedule)

    # --- baseline: unsorted natural order, full reprogramming --------------
    packed_u = bitslice.section_planes_packed(q_padded, spec.rows, spec.cols)
    jobs_u = schedule.schedule_job_costs(packed_u, chains, include_initial=config.include_initial)

    # --- SWS order ---------------------------------------------------------
    perm, inv_perm = _perm_full_with_inverse(flat_padded, spec, config, q_padded)
    packed_s = bitslice.section_planes_packed(q_padded[perm], spec.rows, spec.cols)
    aux = {
        "packed_s": packed_s,
        "sign_slots": sign_padded[perm].reshape(s, spec.rows),
        "scale": qt.scale,
        "offset": qt.offset,
        "inv_perm": inv_perm,
    }
    return jobs_u, aux


@partial(jax.jit, static_argnames=("spec", "config"))
def _analyze_core(
    flat: jax.Array, key: jax.Array, spec: CrossbarSpec, config: PlannerConfig
) -> tuple[dict[str, jax.Array], jax.Array]:
    """Jitted per-tensor pipeline on canonical packed planes.

    flat: f32[n] logical weights.  Retraces per distinct ``n`` (and static
    spec/config), so same-shape tensors across a model share one compilation.
    Returns (metric scalars, reconstruction aux).  Weight reconstruction
    happens *outside* this jit (see ``analyze_tensor``): XLA contracts the
    dequant multiply+add into an FMA inside a fused graph, which would break
    bit-exactness of w_hat against the eager bool reference.
    """
    jobs_u, prep = _prep_core_pool(flat, spec, config)
    packed_s = prep["packed_s"]
    s = packed_s.shape[0]
    l = max(1, min(config.crossbars, s))
    chains = schedule.make_chains(s, l, config.schedule)
    jobs_s = schedule.schedule_job_costs(packed_s, chains, include_initial=config.include_initial)

    # --- bit stucking on the SWS schedule ----------------------------------
    # Totals, lockstep times, and lockstep_time_ideal are all aggregated on
    # the host (int64 / float64) in the wrapper: device sums are int32-bound
    # (jax without x64) and a whole-tensor total can exceed 2^31 at extreme
    # scale, while per-job and per-chain values stay far below it.
    if config.p_stuck < 1.0:
        stuck_chain_totals, achieved_packed = stucking.stuck_schedule_packed(
            packed_s,
            chains,
            config.p_stuck,
            key,
            rows=spec.rows,
            stuck_cols=config.stuck_cols,
            include_initial=config.include_initial,
        )
    else:
        stuck_chain_totals = None
        achieved_packed = packed_s

    metrics = {
        "jobs_u": jobs_u,
        "jobs_s": jobs_s,
        "stuck_chain_totals": stuck_chain_totals,
    }
    aux = {
        "achieved_packed": achieved_packed,
        "sign_slots": prep["sign_slots"],
        "scale": prep["scale"],
        "offset": prep["offset"],
        "inv_perm": prep["inv_perm"],
    }
    return metrics, aux


@partial(jax.jit, static_argnames=("rows",))
def _dequant_slots(
    achieved_packed: jax.Array,
    sign_slots: jax.Array,
    scale: jax.Array,
    offset: jax.Array,
    *,
    rows: int,
) -> jax.Array:
    """Achieved packed planes -> achieved slot weights f32[S, rows].

    Deliberately its own jit entry, called identically by the packed and bool
    planner impls: float rounding (XLA may contract the dequant multiply+add
    into an FMA) is then decided by ONE executable, so both impls get
    bit-identical weights by construction.
    """
    achieved = bitslice.unpack_rows(achieved_packed, rows)
    return bitslice.dequantize_from_planes(achieved, sign_slots, scale, offset)


def _prep_bool(
    flat: jax.Array, spec: CrossbarSpec, config: PlannerConfig
) -> tuple[Any, jax.Array, jax.Array, list[np.ndarray], jax.Array, jax.Array]:
    """Eager twin of :func:`_prep_core_pool`: the seed reference's per-tensor
    prep — quantize, pad, baseline job pricing, SWS permutation.  The ONE
    place the bool pipeline's prep discipline lives; shared by the stateless
    reference and the pool twin so the packed/bool parity contract cannot
    drift between copies.

    Returns (qt, q_padded, sign_padded, chains, jobs_u, perm).
    """
    n = flat.shape[0]
    pad = (-n) % spec.rows
    flat_padded = jnp.pad(flat, (0, pad))
    s = flat_padded.shape[0] // spec.rows
    l = max(1, min(config.crossbars, s))

    qt = bitslice.quantize(flat, spec.cols, spec.encoding)
    q_padded = jnp.pad(qt.q, (0, pad))
    sign_padded = jnp.pad(qt.sign, (0, pad), constant_values=1)

    # --- baseline: unsorted natural order, full reprogramming --------------
    planes_u = bitslice.bitplanes(q_padded.reshape(s, spec.rows), spec.cols)
    chains = schedule.make_chains(s, l, config.schedule)
    jobs_u = schedule.schedule_job_costs_looped(
        planes_u, chains, include_initial=config.include_initial
    )

    # --- SWS order (see _perm_full_bool: the seed device argsort, identical
    # to the fast host-callback sort the packed path uses) ------------------
    perm = _perm_full_bool(flat_padded, spec, config, q_padded)
    return qt, q_padded, sign_padded, chains, jobs_u, perm


def _analyze_tensor_bool(
    w: jax.Array,
    spec: CrossbarSpec,
    config: PlannerConfig,
    key: jax.Array,
    name: str = "w",
) -> tuple[TensorReport, jax.Array]:
    """Seed reference pipeline: eager bool planes + per-chain loops.

    Bit-identical to the packed path (same PRNG discipline); kept for parity
    tests and as the ``benchmarks/planner_throughput.py`` baseline.
    """
    flat = jnp.ravel(w).astype(jnp.float32)
    n = flat.shape[0]
    qt, q_padded, sign_padded, chains, jobs_u, perm = _prep_bool(flat, spec, config)
    total = q_padded.shape[0]
    s = total // spec.rows
    trans_base = int(jnp.sum(jobs_u))
    lk_unsorted = int(schedule.lockstep_time(jobs_u, config.threads, sort_jobs=False))

    planes_s = bitslice.bitplanes(q_padded[perm].reshape(s, spec.rows), spec.cols)
    jobs_s = schedule.schedule_job_costs_looped(
        planes_s, chains, include_initial=config.include_initial
    )
    trans_sws = int(jnp.sum(jobs_s))
    lk_greedy = int(schedule.lockstep_time(jobs_s, config.threads, sort_jobs=True))
    lk_ideal = float(jnp.sum(jobs_s)) / config.threads

    # --- bit stucking on the SWS schedule ----------------------------------
    if config.p_stuck < 1.0:
        total_fin, achieved = stucking.stuck_schedule(
            planes_s,
            chains,
            config.p_stuck,
            key,
            stuck_cols=config.stuck_cols,
            include_initial=config.include_initial,
        )
        trans_final = int(total_fin)
    else:
        trans_final = trans_sws
        achieved = planes_s

    # --- reconstruct achieved weights (exact index matching) ---------------
    sign_slots = sign_padded[perm].reshape(s, spec.rows)
    w_hat_slots = _dequant_slots(
        bitslice.pack_rows(achieved), sign_slots, qt.scale, qt.offset, rows=spec.rows
    )
    logical = jnp.zeros((total,), dtype=jnp.float32).at[perm].set(w_hat_slots.reshape(-1))
    w_hat_flat = logical[:n]
    w_hat = w_hat_flat.reshape(w.shape).astype(w.dtype)

    report = TensorReport(
        name=name,
        shape=tuple(w.shape),
        n_weights=int(n),
        n_sections=int(s),
        transitions_baseline=trans_base,
        transitions_sws=trans_sws,
        transitions_final=trans_final,
        lockstep_time_unsorted=lk_unsorted,
        lockstep_time_greedy=lk_greedy,
        lockstep_time_ideal=lk_ideal,
        quant_mse=float(jnp.mean((flat - w_hat_flat) ** 2)),
        scale=float(qt.scale),
        offset=float(qt.offset),
    )
    return report, w_hat


def _analyze_tensor_pool(
    w: jax.Array,
    spec: CrossbarSpec,
    config: PlannerConfig,
    key: jax.Array,
    pool: "CrossbarPool",
    name: str = "w",
) -> tuple[TensorReport, jax.Array]:
    """Per-tensor pipeline streaming through a persistent ``CrossbarPool``.

    ``transitions_sws``/``transitions_final`` price reprogramming from the
    pool's *current* content (the first job of every chain is a cross-tensor
    seam); with the pool reset between tensors they reproduce the stateless
    path bit-exactly (parity invariant pinned by ``tests/test_pool.py``).
    Supports both planner impls: ``packed`` preps via a jitted core,
    ``bool`` via the eager seed path; the pool twins mirror the same split.
    """
    if not config.include_initial:
        raise ValueError(
            "pool streaming prices physical seam programs; include_initial=False "
            "has no pool interpretation"
        )
    if (spec.rows, spec.cols) != (pool.spec.rows, pool.spec.cols):
        raise ValueError(f"planner spec {spec} != pool spec {pool.spec}")
    flat = jnp.ravel(w).astype(jnp.float32)
    n = int(flat.shape[0])
    s = -(-n // spec.rows)
    l = max(1, min(config.crossbars, s))
    chains = schedule.make_chains(s, l, config.schedule)

    if config.impl == "packed":
        jobs_u, aux = _prep_core_pool(flat, spec, config)
    elif config.impl == "bool":
        qt, q_padded, sign_padded, chains, jobs_u, perm = _prep_bool(flat, spec, config)
        aux = {
            "packed_s": bitslice.section_planes_packed(q_padded[perm], spec.rows, spec.cols),
            "sign_slots": sign_padded[perm].reshape(s, spec.rows),
            "scale": qt.scale,
            "offset": qt.offset,
            "inv_perm": sws.inverse_permutation(perm),
        }
    else:
        raise ValueError(f"unknown planner impl: {config.impl!r}")

    # codec layer: the pool programs/prices/wears the *stored* bits
    # (planes.PlaneSet.physical — permuted columns, reconstructed constants),
    # so transitions under a codec are the physical transitions its layout
    # actually costs.  The bool impl stays raw-only: it is the parity oracle
    # for the packed pipeline, and codec encoding happens on packed words.
    pset = None
    if config.codec != "raw":
        if config.impl == "bool":
            raise ValueError("plane codecs require impl='packed' (bool is the raw parity oracle)")
        # under bit stucking the stored lowest-order columns are deliberately
        # under-programmed; pin them so the bounded LSB error stays an LSB
        # error (plan_col_order docstring)
        pin = config.stuck_cols if config.p_stuck < 1.0 else 0
        pset = planes.encode(
            aux["packed_s"], config.codec, chains=chains, pin_cols=pin
        )

    prep = pool.program(
        pset if pset is not None else aux["packed_s"],
        chains,
        p_stuck=config.p_stuck,
        key=key,
        stuck_cols=config.stuck_cols,
        leveling=config.pool_leveling,
        impl=config.impl,
        name=name,
    )

    # dequantize what the array *reads back* (== prep.achieved byte-for-byte
    # unless the pool has injected faults — core/nonideal.py), so deployed
    # weights and everything served from them see the non-ideal cells.
    # Under a codec the readback is in the stored layout: fault masks have
    # already applied to the physical bits, and logical planes are recovered
    # *after* the read (planes.logical_from_physical), mirroring hardware.
    achieved_read = prep.achieved_read
    if pset is not None:
        achieved_read = planes.logical_from_physical(achieved_read, pset.col_order)
    w_hat_slots = _dequant_slots(
        achieved_read, aux["sign_slots"], aux["scale"], aux["offset"], rows=spec.rows
    )
    w_hat_flat = w_hat_slots.reshape(-1)[aux["inv_perm"]][:n]
    w_hat = w_hat_flat.reshape(w.shape).astype(w.dtype)

    if pool.integrity is not None:
        # the reconstruction closure core/integrity.py needs to dequantize
        # repaired planes back into served weights, bit-exactly (rebuild)
        pool.integrity.attach_aux(name, {
            "sign_slots": aux["sign_slots"],
            "scale": aux["scale"],
            "offset": aux["offset"],
            "inv_perm": aux["inv_perm"],
            "n": n,
            "shape": tuple(w.shape),
            "dtype": w.dtype,
        })

    jobs_u_np = np.asarray(jobs_u)
    report = TensorReport(
        name=name,
        shape=tuple(w.shape),
        n_weights=n,
        n_sections=s,
        transitions_baseline=int(np.sum(jobs_u_np, dtype=np.int64)),
        transitions_sws=prep.transitions_full,
        transitions_final=prep.transitions_programmed,
        lockstep_time_unsorted=int(
            schedule.lockstep_time_host(jobs_u_np, config.threads, sort_jobs=False)
        ),
        lockstep_time_greedy=int(
            schedule.lockstep_time_host(prep.job_costs, config.threads, sort_jobs=True)
        ),
        lockstep_time_ideal=float(prep.transitions_full) / config.threads,
        quant_mse=float(jnp.mean((flat - w_hat_flat) ** 2)),
        scale=float(aux["scale"]),
        offset=float(aux["offset"]),
    )
    return report, w_hat


def analyze_tensor(
    w: jax.Array,
    spec: CrossbarSpec,
    config: PlannerConfig,
    key: jax.Array,
    name: str = "w",
    *,
    pool: "CrossbarPool | None" = None,
) -> tuple[TensorReport, jax.Array]:
    """Full paper pipeline for one weight tensor.

    Returns (report, w_hat) where w_hat carries the achieved (quantized +
    stuck-bit) values in the tensor's logical layout.  With ``pool`` the
    tensor streams through persistent crossbar state instead of a pristine
    per-tensor pool (see ``core.pool``).
    """
    if config.codec not in planes.CODECS:
        raise ValueError(
            f"unknown plane codec {config.codec!r}; choose from {planes.CODECS}"
        )
    if pool is not None:
        return _analyze_tensor_pool(w, spec, config, key, pool, name=name)
    if config.codec != "raw":
        # Codec pricing is inherently a physical-programming question, so the
        # stateless path routes through an ephemeral pristine pool: streaming
        # a tensor into an all-zero pool reproduces stateless per-tensor
        # accounting bit-exactly (pool parity invariant (a), tests/test_pool.py).
        from repro.core.pool import CrossbarPool

        eph = CrossbarPool(spec, max(1, config.crossbars))
        return _analyze_tensor_pool(w, spec, config, key, eph, name=name)
    if config.impl == "bool":
        return _analyze_tensor_bool(w, spec, config, key, name=name)
    if config.impl != "packed":
        raise ValueError(f"unknown planner impl: {config.impl!r}")

    flat = jnp.ravel(w).astype(jnp.float32)
    metrics, aux = _analyze_core(flat, key, spec, config)

    # Reconstruction runs through the SAME _dequant_slots executable as the
    # bool reference, so float rounding matches it bit-for-bit; the gather by
    # the host-computed inverse permutation replaces the reference's scatter
    # (pure data movement either way — values are bit-identical).
    w_hat_slots = _dequant_slots(
        aux["achieved_packed"], aux["sign_slots"], aux["scale"], aux["offset"],
        rows=spec.rows,
    )
    n = flat.shape[0]
    w_hat_flat = w_hat_slots.reshape(-1)[aux["inv_perm"]][:n]
    w_hat = w_hat_flat.reshape(w.shape).astype(w.dtype)

    # Host int64 aggregation: whole-tensor totals can exceed int32 at
    # extreme scale (see _analyze_core).  Matches the bool reference's
    # values exactly wherever the reference itself does not overflow.
    jobs_u = np.asarray(metrics["jobs_u"])
    jobs_s = np.asarray(metrics["jobs_s"])
    trans_sws = int(np.sum(jobs_s, dtype=np.int64))
    if metrics["stuck_chain_totals"] is not None:
        trans_final = int(np.sum(np.asarray(metrics["stuck_chain_totals"]), dtype=np.int64))
    else:
        trans_final = trans_sws

    report = TensorReport(
        name=name,
        shape=tuple(w.shape),
        n_weights=int(flat.shape[0]),
        n_sections=-(-int(flat.shape[0]) // spec.rows),
        transitions_baseline=int(np.sum(jobs_u, dtype=np.int64)),
        transitions_sws=trans_sws,
        transitions_final=trans_final,
        lockstep_time_unsorted=int(
            schedule.lockstep_time_host(jobs_u, config.threads, sort_jobs=False)
        ),
        lockstep_time_greedy=int(
            schedule.lockstep_time_host(jobs_s, config.threads, sort_jobs=True)
        ),
        lockstep_time_ideal=float(trans_sws) / config.threads,
        quant_mse=float(jnp.mean((flat - w_hat_flat) ** 2)),
        scale=float(aux["scale"]),
        offset=float(aux["offset"]),
    )
    return report, w_hat


def iter_weights(params: Any, config: PlannerConfig):
    """Yield (name, tensor) for every crossbar-eligible weight in a pytree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    # exclude patterns are literal substrings: escape them so metacharacters
    # ("w.bias", "head[") neither over-match nor blow up the alternation
    pat = (
        re.compile("|".join(re.escape(p) for p in config.exclude))
        if config.exclude
        else None
    )
    for path, leaf in flat:
        if not hasattr(leaf, "ndim"):
            continue
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if leaf.ndim < config.min_ndim or leaf.size < config.min_size:
            continue
        if pat is not None and pat.search(name.lower()):
            continue
        yield name, leaf


def build_deployment(
    params: Any,
    spec: CrossbarSpec = CrossbarSpec(),
    config: PlannerConfig = PlannerConfig(),
    *,
    progress: Callable[[str], None] | None = None,
    pool: "CrossbarPool | None" = None,
) -> DeploymentPlan:
    """Plan crossbar deployment for every eligible weight in ``params``.

    With ``pool``, the model's tensors stream through ONE persistent
    crossbar pool in iteration order: every tensor's chains reprogram
    whatever the previous tensor left on its assigned crossbars (cross-tensor
    seams), and the pool's per-cell wear counters accumulate the whole
    deployment.  The per-tensor PRNG split discipline is identical with and
    without a pool, so resetting the pool between tensors recovers the
    stateless plan bit-exactly.
    """
    key = jax.random.PRNGKey(config.seed)
    reports: dict[str, TensorReport] = {}
    deployed: dict[str, jax.Array] = {}
    for name, w in iter_weights(params, config):
        key, sub = jax.random.split(key)
        if progress:
            progress(name)
        report, w_hat = analyze_tensor(w, spec, config, sub, name=name, pool=pool)
        reports[name] = report
        deployed[name] = w_hat
    return DeploymentPlan(
        spec=spec,
        config=config,
        reports=reports,
        deployed=deployed,
        pool_stats=pool.stats().to_dict() if pool is not None else None,
    )


MATERIALIZATIONS = ("dense", "packed", "planes_int8")

# Deployed tensors whose consumers are not plain [K, N] matmuls (per-head
# reshapes, convolutions, elementwise/einsum uses): always materialized as
# dense w_hat even under "packed"/"planes_int8" — still the achieved
# crossbar weights, just dense-served.  Matched against '/'-separated path
# components of the tensor name, not substrings.
MATERIALIZE_DENSE_ONLY = (
    "wk_b", "wv_b",  # MLA absorbed-decode up-projections (reshaped per head)
    "conv",          # SSM causal-conv taps (depthwise conv, not a matmul)
    "a_log",         # Mamba state matrix (elementwise exp)
    "r",             # sLSTM recurrent kernel (per-head einsum)
    "meta",          # Hymba meta tokens (concatenated, never multiplied)
)


def _dense_only(name: str) -> bool:
    parts = name.split("/")
    return any(p in parts for p in MATERIALIZE_DENSE_ONLY)


def deploy_params(
    params: Any,
    plan: DeploymentPlan,
    *,
    materialize: str = "dense",
    codec: str | None = None,
) -> Any:
    """Return a params pytree with deployed tensors replaced by achieved state.

    ``materialize`` chooses the serving representation of every deployed
    tensor (non-deployed leaves are always passed through dense):

    * ``"dense"`` (default / baseline) — the achieved f32 weights ``w_hat``;
      the model's matmuls stay ordinary dense dots.
    * ``"packed"`` — bit-packed crossbar operand dicts (the canonical packed
      planes the pool holds, ~8x less weight traffic); eligible matmuls run
      through ``simulator.cim_linear`` (see ``models.layers.linear``).
    * ``"planes_int8"`` — signed int8 plane operand dicts (one byte per bit
      cell); the parity/traffic baseline for the packed path.

    ``codec`` (default: the plan's ``config.codec``) applies the serving-side
    plane codec to packed operands (``planes.encode_operands``: plane-axis
    reorder + zero-tile flags).  Encoded operands are exact re-encodings —
    served tokens stay bit-identical to dense (pinned by
    ``tests/test_cim_packed.py``).

    Operand dicts are exact re-encodings of ``w_hat`` (same achieved weights,
    stucking included) — see ``simulator.operands_from_dense``.
    """
    if materialize not in MATERIALIZATIONS:
        raise ValueError(
            f"unknown materialize {materialize!r}; choose from {MATERIALIZATIONS}"
        )
    codec = plan.config.codec if codec is None else codec
    if codec not in planes.CODECS:
        raise ValueError(f"unknown plane codec {codec!r}; choose from {planes.CODECS}")
    if materialize != "dense":
        from repro.core import simulator

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if name not in plan.deployed:
            out.append(leaf)
            continue
        w_hat = plan.deployed[name]
        if materialize == "dense" or _dense_only(name):
            out.append(w_hat)
            continue
        r = plan.reports[name]
        out.append(
            simulator.operands_from_dense(
                w_hat, r.scale, r.offset, plan.spec.encoding, plan.spec.cols,
                materialize=materialize, codec=codec,
            )
        )
    return jax.tree_util.tree_unflatten(treedef, out)
