"""Online crossbar integrity: scrub, detect, localize, and self-repair.

``core/nonideal.py`` gave the pool one-directional faults — stuck cells are
injected and serving reads through them — but nothing ever *finds* which
stored bits went bad, let alone repairs them.  The fleet's only detector is
an end-to-end KL probe that can just kill a replica.  This module closes the
detect → localize → classify → repair loop, and prices every repair write in
the same transition/wear currency the planner optimizes (``price_pairs``),
turning the paper's endurance accounting into a live reliability policy:

* **Registration** (``IntegrityManager.register``, hooked into
  ``CrossbarPool.program``): each deployed tensor keeps its reference stored
  planes (``PoolProgramReport.achieved`` — the pool itself only retains the
  *last* section per chain), the expected read through the registration-time
  fault masks (``achieved_read`` — the deployment's bit-exact contract), and
  per-tile checksums over the expected read.  Tiles are
  ``IntegrityConfig.tile_bytes`` packed bytes (default 16 — one
  ``planes.OPERAND_TILE_BYTES`` tile = one bk=128 kernel K-block), with a
  position-weighted byte sum per (section, tile, column): any single-byte
  change is detected (weights 1..16 make byte deltas non-cancelling) and an
  optional spare parity column (XOR of all data columns) cross-checks
  multi-column corruption.
* **Scrubbing** (``scrub_round``): a budgeted round-robin cursor over all
  registered tiles, meant to run *between* engine dispatch rounds
  (``Engine.attach_scrub``) so serving latency stays bounded.  A mismatching
  checksum triggers a re-read — a match on the second read classifies the
  event as **transient** drift (no repair) — then a deterministic masked
  read diffs against the expected planes to localize persistent faulty
  cells exactly.
* **Repair policy** (endurance-aware, per fault):
    1. **in-place rewrite** — stored bits drifted but cells still write
       (retention/state corruption): rewrite only the corrupted tile, cost =
       popcount of the toggle, charged to the owning crossbar's wear;
    2. **column remap** — cells that stay wrong after a verified rewrite are
       hard stuck-at; the faulty *stored column* is remapped onto a clean
       spare column plane (``col_map``), the column-granular cousin of the
       ``col_perm`` codec's reordering.  Low-order logical columns below
       ``tolerate_cols`` are instead tolerated un-repaired — exactly the
       paper's bit-stucking insight that LSB-plane errors are bounded;
    3. **section migration** — when spares are exhausted the whole section
       is rewritten into pristine spare pool capacity (cost = programming
       the full section), freeing its spares and clearing its masks.
  Every option is priced with ``hamming_ops.price_pairs`` and charged to the
  pool's wear/write counters; a per-round ``repair_budget`` caps repair
  writes (highest-significance columns repaired first, the remainder stays
  pending for the next round — ``pending_faults()`` is what the fleet's
  placement scoring reads to route around replicas mid-repair).
* **Refresh** (``rebuild``/``rebuild_plan``): repaired planes are
  dequantized through the planner's exact pipeline
  (``logical_from_physical`` → ``_dequant_slots`` → inverse permutation) so
  a repaired deployment is byte-identical to the original whenever every
  hard fault was remapped or migrated — the engine swaps it in atomically
  via ``hot_swap`` (in-flight streams keep their epoch's bit-exact
  contract).

Differential/fault-aware mapping (arXiv:2106.09166) and X-CHANGR
(arXiv:1907.00285) motivate the policy: targeted remapping recovers accuracy
at a small fraction of a full reprogram — ``benchmarks/integrity_scrub.py``
gates repair transitions at <= 0.5x the full-reprogram cost of the affected
tensors.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planes as planes_mod
from repro.kernels.hamming import ops as hamming_ops

if TYPE_CHECKING:  # pool imports integrity lazily; keep the cycle type-only
    from repro.core.pool import CrossbarPool, PoolProgramReport


# ---------------------------------------------------------------------------
# Config + reports
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IntegrityConfig:
    """Scrub/repair policy knobs.

    ``spare_cols`` clean spare column planes are provisioned per section as
    remap targets (plus one reserved parity column when ``parity_col``);
    ``scrub_tiles`` bounds tiles verified per round so scrubbing between
    engine dispatches has bounded latency; ``repair_budget`` caps repair
    write transitions per round (None = unbounded; the first action of a
    round always proceeds so repair cannot live-lock); hard faults in
    logical columns below ``tolerate_cols`` are tolerated un-repaired (the
    bit-stucking insight: LSB-plane errors are bounded); ``transient_rate``
    models per-bit transient read flips that the re-read classifier must
    reject without spending repair writes.
    """

    tile_bytes: int = planes_mod.OPERAND_TILE_BYTES
    spare_cols: int = 2
    parity_col: bool = True
    scrub_tiles: int = 64
    repair_budget: int | None = None
    tolerate_cols: int = 0
    transient_rate: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.tile_bytes < 1:
            raise ValueError(f"tile_bytes must be >= 1, got {self.tile_bytes}")
        if self.spare_cols < 0:
            raise ValueError(f"spare_cols must be >= 0, got {self.spare_cols}")
        if self.scrub_tiles < 1:
            raise ValueError(f"scrub_tiles must be >= 1, got {self.scrub_tiles}")
        if self.repair_budget is not None and self.repair_budget < 1:
            raise ValueError(f"repair_budget must be >= 1 or None, got {self.repair_budget}")
        if self.tolerate_cols < 0:
            raise ValueError(f"tolerate_cols must be >= 0, got {self.tolerate_cols}")
        if not 0.0 <= self.transient_rate <= 1.0:
            raise ValueError(
                f"transient_rate must be in [0, 1], got {self.transient_rate}"
            )


@dataclasses.dataclass
class ScrubReport:
    """Counters from one (or an aggregation of) scrub round(s)."""

    rounds: int = 0
    tiles_scanned: int = 0
    detections: int = 0  # tiles with a persistent (non-transient) mismatch
    transients: int = 0  # tiles whose mismatch vanished on re-read
    localized_bits: int = 0  # faulty cells pinpointed by reference diff
    rewrites: int = 0  # in-place tile rewrites (retention corruption)
    remaps: int = 0  # column remaps onto spare planes (hard stuck-at)
    migrations: int = 0  # whole-section migrations to pristine capacity
    tolerated: int = 0  # hard-faulty low-order columns left un-repaired
    parity_mismatches: int = 0  # parity-column cross-check disagreements
    repair_transitions: int = 0  # total repair write cost (price_pairs)
    pending: int = 0  # repairs deferred past the round's write budget

    def merge(self, other: "ScrubReport") -> None:
        for f in dataclasses.fields(self):
            if f.name == "pending":
                self.pending = other.pending  # a level, not a flow
            else:
                setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TensorRecord:
    """Integrity metadata + live modeled device state for one deployed tensor.

    ``reference`` is what the cells should *hold*, ``expected`` what a read
    should *return* (reference through the registration-time stuck masks —
    the deployment's contract).  ``stored``/``stuck0``/``stuck1`` are the
    live modeled cells that storms corrupt; ``col_map[s, c] >= cols`` means
    stored column ``c`` of section ``s`` has been remapped onto spare slot
    ``col_map[s, c] - cols``.
    """

    name: str
    reference: np.ndarray  # uint8[S, W, C] target stored bits (physical layout)
    expected: np.ndarray  # uint8[S, W, C] expected read (the serving contract)
    checksums: np.ndarray  # uint32[S, T, C] position-weighted tile sums
    parity: np.ndarray | None  # uint8[S, W] XOR of expected data columns
    sec_xbar: np.ndarray  # int32[S] owning physical crossbar per section
    col_order: np.ndarray | None  # int32[S, C] stored position -> logical plane
    transitions_full: int  # full-reprogram cost baseline (report.transitions_full)
    stored: np.ndarray  # uint8[S, W, C] live cell contents
    stuck0: np.ndarray  # uint8[S, W, C] live stuck-at-0 mask
    stuck1: np.ndarray  # uint8[S, W, C] live stuck-at-1 mask (disjoint)
    spare: np.ndarray  # uint8[S, W, n_spare] clean spare column planes
    spare_used: np.ndarray  # bool[S, n_spare]
    col_map: np.ndarray  # int32[S, C]
    detections: int = 0
    aux: dict[str, Any] | None = None  # planner-attached reconstruction closure


def tile_checksums(expected: np.ndarray, tile_bytes: int) -> np.ndarray:
    """Position-weighted byte sums per (section, tile, column) -> uint32[S, T, C].

    Weighting byte ``i`` within a tile by ``i + 1`` makes any single-byte
    delta non-cancelling (a plain XOR/sum misses even-multiplicity flips of
    the same bit position across bytes).
    """
    s, w, c = expected.shape
    t = -(-w // tile_bytes)
    pad = t * tile_bytes - w
    p = np.pad(expected, ((0, 0), (0, pad), (0, 0))).astype(np.uint32)
    p = p.reshape(s, t, tile_bytes, c)
    weights = np.arange(1, tile_bytes + 1, dtype=np.uint32)[None, None, :, None]
    return (p * weights).sum(axis=2, dtype=np.uint32)


def _price(a: np.ndarray, b: np.ndarray) -> int:
    """Total transitions a -> b on the shared Hamming path (Pallas on TPU,
    popcount elsewhere) — every repair write is priced here, never ad hoc."""
    a3 = a.reshape(-1, a.shape[-2], a.shape[-1]) if a.ndim == 3 else a[None]
    b3 = b.reshape(-1, b.shape[-2], b.shape[-1]) if b.ndim == 3 else b[None]
    if a3.shape[0] == 0:
        return 0
    return int(np.asarray(hamming_ops.price_pairs(jnp.asarray(a3), jnp.asarray(b3))).sum())


# ---------------------------------------------------------------------------
# The manager
# ---------------------------------------------------------------------------

class IntegrityManager:
    """Per-pool scrub/detect/repair state over all registered tensors."""

    def __init__(self, pool: "CrossbarPool", cfg: IntegrityConfig | None = None):
        self.pool = pool
        self.cfg = cfg or IntegrityConfig()
        self.rows = pool.spec.rows
        self.cols = pool.spec.cols
        self.words = -(-pool.spec.rows // 8)
        self.tensors: dict[str, TensorRecord] = {}
        self.totals = ScrubReport()
        self.spare_writes = 0  # repair writes landing on spare planes
        self._tiles: list[tuple[str, int, int]] = []
        self._segments: dict[str, tuple[int, int]] = {}
        self._cursor = 0
        self._clean_streak = 0
        self._pending: set[tuple[str, int, int]] = set()
        self._read_ctr = 0

    # -- registration ------------------------------------------------------

    def register(
        self,
        report: "PoolProgramReport",
        *,
        chains: list[np.ndarray],
        col_order: np.ndarray | None = None,
    ) -> TensorRecord:
        """Record a freshly programmed tensor's integrity metadata.

        Called by ``CrossbarPool.program`` when integrity is enabled; the
        expected read is ``achieved_read`` verbatim, so pre-existing pool
        faults at program time are part of the contract, not defects."""
        reference = np.asarray(report.achieved)
        expected = np.asarray(report.achieved_read)
        s = reference.shape[0]
        sec_xbar = np.zeros(s, np.int32)
        for j, c in enumerate(chains):
            sec_xbar[np.asarray(c)] = report.assignment[j]
        if self.pool.faults is not None:
            stuck0 = np.asarray(self.pool.faults.stuck0)[sec_xbar]
            stuck1 = np.asarray(self.pool.faults.stuck1)[sec_xbar]
        else:
            stuck0 = np.zeros_like(reference)
            stuck1 = np.zeros_like(reference)
        cfg = self.cfg
        rec = TensorRecord(
            name=report.name,
            reference=reference.copy(),
            expected=expected.copy(),
            checksums=tile_checksums(expected, cfg.tile_bytes),
            parity=self._parity_of(expected) if cfg.parity_col else None,
            sec_xbar=sec_xbar,
            col_order=None if col_order is None else np.asarray(col_order, np.int32),
            transitions_full=int(report.transitions_full),
            stored=reference.copy(),
            stuck0=stuck0.copy(),
            stuck1=stuck1.copy(),
            spare=np.zeros((s, self.words, cfg.spare_cols), np.uint8),
            spare_used=np.zeros((s, cfg.spare_cols), bool),
            col_map=np.tile(np.arange(self.cols, dtype=np.int32), (s, 1)),
        )
        self.tensors[report.name] = rec
        self._rebuild_tile_list()
        return rec

    def attach_aux(self, name: str, aux: dict[str, Any]) -> None:
        """Planner hook: the reconstruction closure (sign slots, quant scale/
        offset, inverse permutation, original shape) needed by ``rebuild``."""
        self.tensors[name].aux = aux

    def _parity_of(self, expected: np.ndarray) -> np.ndarray:
        out = np.zeros(expected.shape[:2], np.uint8)
        for c in range(expected.shape[2]):
            out ^= expected[:, :, c]
        return out

    def _rebuild_tile_list(self) -> None:
        tiles = []
        self._segments = {}  # name -> (S, T): shape of its tile grid
        for name, rec in self.tensors.items():
            t = rec.checksums.shape[1]
            self._segments[name] = (rec.reference.shape[0], t)
            tiles.extend((name, s, ti) for s in range(rec.reference.shape[0]) for ti in range(t))
        self._tiles = tiles
        self._cursor = 0
        self._clean_streak = 0

    @property
    def total_tiles(self) -> int:
        return len(self._tiles)

    # -- the modeled read path ---------------------------------------------

    def read(self, rec: TensorRecord, *, transient: bool = True) -> np.ndarray:
        """What the array returns for this tensor right now: live stored bits
        through the live stuck masks, remapped columns served from their
        spare planes, plus (optionally) transient per-read bit flips."""
        out = (rec.stored & ~rec.stuck0) | rec.stuck1
        remapped = np.argwhere(rec.col_map >= self.cols)
        if remapped.size:
            out = out.copy()
            for s, c in remapped:
                out[s, :, c] = rec.spare[s, :, rec.col_map[s, c] - self.cols]
        if transient and self.cfg.transient_rate > 0.0:
            self._read_ctr += 1
            rng = np.random.default_rng((self.cfg.seed, self._read_ctr))
            bits = rng.random((out.shape[0], self.rows, self.cols)) < self.cfg.transient_rate
            pad = self.words * 8 - self.rows
            if pad:
                bits = np.pad(bits, ((0, 0), (0, pad), (0, 0)))
            out = out ^ np.packbits(bits, axis=1)
        return out

    def verify_all(self) -> bool:
        """Deterministic full sweep: every tensor's read matches its contract."""
        return all(
            np.array_equal(self.read(rec, transient=False), rec.expected)
            for rec in self.tensors.values()
        )

    def pending_faults(self) -> int:
        """Known-but-unrepaired tiles (budget-deferred).  The fleet routes
        around replicas with pending faults and penalizes their score."""
        return len(self._pending)

    @property
    def clean(self) -> bool:
        """A full scrub cycle has passed with zero detections and no backlog."""
        return self._clean_streak >= len(self._tiles) and not self._pending

    # -- fault-storm injection ---------------------------------------------

    def storm(
        self,
        key: jax.Array,
        *,
        corrupt_rate: float = 0.0,
        stuck_rate: float = 0.0,
        tensors: list[str] | None = None,
    ) -> dict:
        """Deterministic mid-trace fault storm: flip stored bits at
        ``corrupt_rate`` (retention/state corruption — repairable in place)
        and add new stuck cells at ``stuck_rate`` (hard faults — need remap,
        migration, or tolerance).  Returns injected counts."""
        if not 0.0 <= corrupt_rate <= 1.0 or not 0.0 <= stuck_rate <= 1.0:
            raise ValueError("storm rates must be in [0, 1]")
        names = sorted(tensors if tensors is not None else self.tensors)
        corrupted = new_stuck = 0
        pad = self.words * 8 - self.rows
        for i, name in enumerate(names):
            rec = self.tensors[name]
            s = rec.stored.shape[0]
            k = jax.random.fold_in(key, i)
            kc, ks, kv = jax.random.split(k, 3)
            shape = (s, self.rows, self.cols)
            if corrupt_rate > 0.0:
                bits = np.asarray(jax.random.bernoulli(kc, corrupt_rate, shape))
                if pad:
                    bits = np.pad(bits, ((0, 0), (0, pad), (0, 0)))
                mask = np.packbits(bits, axis=1)
                rec.stored ^= mask
                corrupted += int(bits.sum())
            if stuck_rate > 0.0:
                cells = np.asarray(jax.random.bernoulli(ks, stuck_rate, shape))
                s1sel = np.asarray(jax.random.bernoulli(kv, 0.5, shape))
                if pad:
                    cells = np.pad(cells, ((0, 0), (0, pad), (0, 0)))
                    s1sel = np.pad(s1sel, ((0, 0), (0, pad), (0, 0)))
                cells_p = np.packbits(cells, axis=1)
                s1_p = np.packbits(cells & s1sel, axis=1)
                s0_new = (cells_p & ~s1_p) & ~rec.stuck1
                s1_new = s1_p & ~(rec.stuck0 | s0_new)
                rec.stuck0 |= s0_new
                rec.stuck1 |= s1_new
                new_stuck += _price(s0_new | s1_new, np.zeros_like(s0_new))
        return {
            "tensors": len(names),
            "corrupted_bits": corrupted,
            "new_stuck_cells": new_stuck,
        }

    # -- scrubbing ----------------------------------------------------------

    def scrub_round(self, budget_tiles: int | None = None) -> ScrubReport:
        """Verify up to ``budget_tiles`` tiles (default ``cfg.scrub_tiles``)
        from the round-robin cursor, classifying and repairing mismatches
        within the round's repair-write budget."""
        rep = ScrubReport(rounds=1)
        if not self._tiles:
            return rep
        n = min(budget_tiles or self.cfg.scrub_tiles, len(self._tiles))
        # per-tensor round read cache; checksum/parity comparisons run
        # vectorized over exactly the section range the round's window
        # covers, so the (overwhelmingly common) all-clean sweep is a
        # handful of whole-window numpy ops, not per-tile slicing
        cache: dict[str, np.ndarray] = {}
        tb = self.cfg.tile_bytes
        budget = self.cfg.repair_budget
        spent = 0

        scanned = 0
        while scanned < n:
            name, s, t = self._tiles[self._cursor]
            rec = self.tensors[name]
            if name not in cache:
                cache[name] = self.read(rec)
            read1 = cache[name]
            S, T = self._segments[name]
            flat = s * T + t
            limit = min(S * T - flat, n - scanned)
            sub = slice(s, (flat + limit - 1) // T + 1)  # sections in window
            bad = (tile_checksums(read1[sub], tb) != rec.checksums[sub]).any(axis=2)
            dirty = bad
            if rec.parity is not None:
                eq = np.bitwise_xor.reduce(read1[sub], axis=2) == rec.parity[sub]
                pad = (-eq.shape[1]) % tb
                if pad:
                    eq = np.pad(eq, ((0, 0), (0, pad)), constant_values=True)
                par_bad = ~eq.reshape(eq.shape[0], -1, tb).all(axis=2)
                dirty = bad | par_bad
            # bulk-advance the cursor over the window's run of clean tiles
            # (the steady-state path: one argmax, no per-tile work)
            off = flat - s * T  # window start within the sub-range
            hits = np.flatnonzero(dirty.reshape(-1)[off : off + limit])
            run = int(hits[0]) if hits.size else limit
            if run:
                if self._pending:
                    for p in [p for p in self._pending if p[0] == name]:
                        if flat <= p[1] * T + p[2] < flat + run:
                            self._pending.discard(p)
                rep.tiles_scanned += run
                self._clean_streak += run
                scanned += run
                self._cursor = (self._cursor + run) % len(self._tiles)
                continue
            # dirty tile at the cursor: per-tile classification + repair
            scanned += 1
            rep.tiles_scanned += 1
            sl = slice(t * tb, min((t + 1) * tb, rec.reference.shape[1]))
            if not bad.reshape(-1)[off]:  # checksum clean, parity caught it
                rep.parity_mismatches += 1
            # re-read: a transient flip vanishes on the second read
            read2 = self.read(rec)
            csums2 = tile_checksums(read2[s : s + 1, :, :], tb)[0]
            persistent = bool((csums2[t] != rec.checksums[s, t]).any())
            # deterministic localization: masked read diffed against the
            # expected (reference-through-masks) planes
            det = self.read(rec, transient=False)[s, sl, :] ^ rec.expected[s, sl, :]
            if not persistent or not det.any():
                rep.transients += 1
                self._clean_streak += 1
                self._cursor = (self._cursor + 1) % len(self._tiles)
                continue
            rep.detections += 1
            rec.detections += 1
            self._clean_streak = 0
            rep.localized_bits += _price(det, np.zeros_like(det))
            done, cost = self._repair_tile(
                rec, s, t, sl, rep, budget=budget, spent=spent
            )
            spent += cost
            cache.pop(name, None)  # repairs invalidate the round's cached read
            if not done:
                self._pending.add((name, s, t))
                rep.pending = len(self._pending)
                break  # budget exhausted: resume at this tile next round
            self._pending.discard((name, s, t))
            self._cursor = (self._cursor + 1) % len(self._tiles)
        rep.pending = len(self._pending)
        self.totals.merge(rep)
        return rep

    def scrub_until_clean(self, *, max_rounds: int = 10_000) -> ScrubReport:
        """Drive ``scrub_round`` until a full clean cycle (or ``max_rounds``).
        Aggregated report; ``clean`` tells whether convergence was reached."""
        agg = ScrubReport()
        for _ in range(max_rounds):
            agg.merge(self.scrub_round())
            if self.clean:
                break
        return agg

    # -- repair -------------------------------------------------------------

    def _afford(self, cost: int, budget: int | None, spent: int) -> bool:
        # the first action of a round always proceeds (progress guarantee)
        return budget is None or spent == 0 or spent + cost <= budget

    def _repair_tile(
        self,
        rec: TensorRecord,
        s: int,
        t: int,
        sl: slice,
        rep: ScrubReport,
        *,
        budget: int | None,
        spent: int,
    ) -> tuple[bool, int]:
        """Repair one persistently mismatching tile.  Returns (done, cost)."""
        cost = 0
        # 1) in-place rewrite of corrupted stored bits (cells still write)
        toggle = rec.stored[s, sl, :] ^ rec.reference[s, sl, :]
        if toggle.any():
            c_rw = _price(toggle, np.zeros_like(toggle))
            if not self._afford(c_rw, budget, spent + cost):
                return False, cost
            rec.stored[s, sl, :] = rec.reference[s, sl, :]
            self._charge_pool(int(rec.sec_xbar[s]), toggle, sl)
            rep.rewrites += 1
            rep.repair_transitions += c_rw
            cost += c_rw
        # 2) verified re-read: what survives a rewrite is hard stuck-at
        verify = self.read(rec, transient=False)
        resid = verify[s, sl, :] ^ rec.expected[s, sl, :]
        bad_cols = [c for c in range(self.cols) if resid[:, c].any()]
        # highest logical significance first: MSB-plane faults flip the
        # largest weight magnitudes, so they get the budget first
        def _logical(c: int) -> int:
            return int(rec.col_order[s, c]) if rec.col_order is not None else c

        for c in sorted(bad_cols, key=_logical, reverse=True):
            logical = _logical(c)
            if logical < self.cfg.tolerate_cols:
                # bit stucking: a low-order faulty column stays un-repaired;
                # the bounded LSB error becomes part of the serving contract
                rec.expected[s, :, c] = verify[s, :, c]
                rec.checksums[s, :, c] = tile_checksums(
                    rec.expected[s : s + 1], self.cfg.tile_bytes
                )[0, :, c]
                if rec.parity is not None:
                    rec.parity[s] = np.bitwise_xor.reduce(rec.expected[s], axis=1)
                rep.tolerated += 1
                continue
            free = np.flatnonzero(~rec.spare_used[s])
            if free.size:
                j = int(free[0])
                col = rec.expected[s, :, c]
                c_rm = _price(col[None, :, None], rec.spare[s, :, j][None, :, None])
                if not self._afford(c_rm, budget, spent + cost):
                    return False, cost
                rec.spare[s, :, j] = col
                rec.spare_used[s, j] = True
                rec.col_map[s, c] = self.cols + j
                self.spare_writes += c_rm
                self.pool.total_writes += c_rm
                rep.remaps += 1
                rep.repair_transitions += c_rm
                cost += c_rm
            else:
                c_mig = self._migrate_section(rec, s, budget=budget, spent=spent + cost)
                if c_mig is None:
                    return False, cost
                rep.migrations += 1
                rep.repair_transitions += c_mig
                cost += c_mig
                break  # the whole section is now pristine
        return True, cost

    def _migrate_section(
        self, rec: TensorRecord, s: int, *, budget: int | None, spent: int
    ) -> int | None:
        """Rewrite a whole section into pristine spare pool capacity (the
        least-worn crossbar).  Frees the section's spares, clears its live
        masks, and re-anchors the contract at the reference bits."""
        target = rec.expected[s]
        c_mig = _price(target, np.zeros_like(target))
        if not self._afford(c_mig, budget, spent):
            return None
        xbar = int(np.argmin(self.pool.wear_totals()))
        rec.sec_xbar[s] = xbar
        rec.stored[s] = rec.expected[s].copy()
        rec.reference[s] = rec.expected[s].copy()
        rec.stuck0[s] = 0
        rec.stuck1[s] = 0
        rec.col_map[s] = np.arange(self.cols, dtype=np.int32)
        rec.spare_used[s] = False
        rec.spare[s] = 0
        rec.checksums[s] = tile_checksums(rec.expected[s : s + 1], self.cfg.tile_bytes)[0]
        if rec.parity is not None:
            rec.parity[s] = np.bitwise_xor.reduce(rec.expected[s], axis=1)
        self._charge_pool(xbar, target, slice(0, rec.reference.shape[1]))
        return c_mig

    def _charge_pool(self, xbar: int, toggle: np.ndarray, sl: slice) -> None:
        """Charge a physical write's per-cell wear to the owning crossbar —
        repair writes spend the same endurance currency as programming."""
        bits = np.unpackbits(toggle, axis=0)
        row0 = sl.start * 8
        row1 = min(row0 + bits.shape[0], self.rows)
        if row1 > row0:
            self.pool.wear[xbar, row0:row1, :] += bits[: row1 - row0].astype(np.int64)
        self.pool.total_writes += int(bits.sum())

    # -- repaired-plane refresh --------------------------------------------

    def rebuild(self, name: str) -> jax.Array:
        """Dequantize the tensor's *current* read back into served weights —
        the planner's exact pipeline, so a fully repaired tensor reproduces
        the original deployment byte-for-byte."""
        from repro.core import planner as _planner  # lazy: avoid import cycle

        rec = self.tensors[name]
        if rec.aux is None:
            raise ValueError(
                f"tensor {name!r} has no reconstruction aux; deploy it through "
                "planner.build_deployment with integrity enabled"
            )
        arr = jnp.asarray(self.read(rec, transient=False))
        if rec.col_order is not None:
            arr = planes_mod.logical_from_physical(arr, jnp.asarray(rec.col_order))
        aux = rec.aux
        w_hat_slots = _planner._dequant_slots(
            arr, aux["sign_slots"], aux["scale"], aux["offset"], rows=self.rows
        )
        flat = w_hat_slots.reshape(-1)[aux["inv_perm"]][: aux["n"]]
        return flat.reshape(aux["shape"]).astype(aux["dtype"])

    def rebuild_plan(self, plan):
        """A ``DeploymentPlan`` whose deployed tensors reflect the current
        (possibly repaired) device state — feed to ``planner.deploy_params``
        and swap in atomically via ``Engine.hot_swap``."""
        deployed = dict(plan.deployed)
        for name in self.tensors:
            if name in deployed:
                deployed[name] = self.rebuild(name)
        return dataclasses.replace(plan, deployed=deployed)

    # -- reporting ----------------------------------------------------------

    def affected(self) -> list[str]:
        """Tensors with at least one persistent detection so far."""
        return sorted(n for n, r in self.tensors.items() if r.detections > 0)

    def transitions_full_affected(self) -> int:
        """Full-reprogram cost of every affected tensor — the baseline the
        repair-transition gate compares against."""
        return sum(self.tensors[n].transitions_full for n in self.affected())

    def summary(self) -> dict:
        return {
            "tensors": len(self.tensors),
            "tiles": self.total_tiles,
            "spare_cols": self.cfg.spare_cols,
            "parity_col": self.cfg.parity_col,
            "pending": self.pending_faults(),
            "clean": self.clean if self._tiles else True,
            "spare_writes": self.spare_writes,
            "totals": self.totals.to_dict(),
        }
