"""Persistent crossbar pool: cross-tensor scheduling + per-cell wear accounting.

The paper's premise is finite memristor endurance, yet per-tensor pricing
(``planner._analyze_core``) restarts every tensor from a freshly pristine set
of L crossbars — an accounting fiction that cannot answer the deployment
question: how many writes does each *physical* cell absorb when a whole model
(or a sequence of models / checkpoints) streams through one fixed pool?
X-CHANGR-style remapping work shows cross-deployment reuse is where lifetime
is won or lost, so the pool is a first-class stateful subsystem here:

* ``CrossbarPool`` holds persistent packed crossbar state ``uint8[L, W, cols]``
  (the planner's canonical packed-plane representation) plus per-cell wear
  counters (host int64 — device int32 would wrap under long wear histories).
* ``program(sections, chains)`` carries state *across* calls: the first
  program of every chain is a **cross-tensor seam** priced from the pool's
  current content, not from pristine zeros.  All jobs are priced with the
  existing batched ``price_pairs`` path (Pallas ``hamming`` kernel on TPU,
  portable popcount elsewhere); an eager bool-plane twin (``impl="bool"``)
  reproduces every output bit-exactly and serves as the parity oracle.
* Wear-leveling chain→crossbar assignment (``leveling=``): ``"rotate"``
  seeds the chain walk at the least-worn crossbar; ``"lpt"`` runs the
  longest-processing-time greedy of ``schedule.lpt_assignment`` with
  capacity 1, seeded by accumulated per-crossbar wear, so heavy chains land
  on the least-worn crossbars; ``"fault"`` is the X-CHANGR-style remap of
  ``core.nonideal`` — chains are steered away from crossbars whose stuck
  cells would flip their high-order bits (falls back to ``"lpt"`` when no
  faults are injected).
* Non-ideal reads (``inject_faults``): a sampled ``nonideal.FaultState``
  attaches stuck-at masks per crossbar; ``PoolProgramReport.achieved_read``
  is what the array *reads back* through those masks — identical to
  ``achieved`` byte-for-byte at zero fault rate (the parity pin), and the
  planes the planner dequantizes into served weights.

Parity invariants (pinned by ``tests/test_pool.py``):

(a) with the pool ``reset()`` between tensors, streaming reproduces the
    planner's per-tensor ``transitions_*`` totals bit-exactly — the seam from
    an all-zero pool *is* the pristine initial program, and the stucked walk
    shares ``stucking._pad_chains``'s key schedule;
(b) wear conservation — the per-cell wear increments of a ``program`` call
    sum exactly to its programmed transitions (seams included);
(c) packed and bool implementations agree on every output.

Serving export: ``PoolProgramReport.achieved`` is the canonical packed
resident state per section after a program call — the planner dequantizes it
into the plan's ``deployed`` weights, and ``deploy_params(materialize=
"packed")`` re-encodes those into the bit-packed serving operands
(``simulator.operands_from_dense``; the re-encoding is bit-exact with the
pool's planes, pinned by ``tests/test_cim_packed.py``) — so ``serve --cim
--materialize packed`` computes on exactly the bits this pool holds.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitslice, cost, schedule
from repro.core.stucking import _pad_chains, _walk_packed
from repro.kernels.hamming import ops as hamming_ops

if TYPE_CHECKING:  # CrossbarSpec lives in planner; avoid the import cycle
    from repro.core.planner import CrossbarSpec


LEVELINGS = ("none", "rotate", "lpt", "fault")

DEFAULT_ENDURANCE = 1e8  # typical ReRAM cell write endurance (order of magnitude)


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PoolProgramReport:
    """Outcome of streaming one tensor's sections through the pool."""

    name: str
    assignment: np.ndarray  # int32[Lc] chain -> physical crossbar id
    seam_costs: np.ndarray  # int64[Lc] first program per chain, from pool state
    chain_totals: np.ndarray  # int64[Lc] full-reprogram totals (seam + intra)
    job_costs: np.ndarray  # int64[njobs] chain-major, seam job first per chain
    programmed_job_costs: np.ndarray  # int64[njobs] actually-programmed (stucked)
    transitions_full: int  # sum(job_costs): full reprogramming from pool state
    transitions_programmed: int  # == transitions_full when p_stuck >= 1
    wear_increment_total: int
    wear_increment_max: int
    achieved: jax.Array  # uint8[S, W, cols] resident state per section
    # what a read returns through the pool's fault masks (== achieved when
    # no faults are injected — zero-fault parity, tests/test_nonideal.py)
    achieved_read: jax.Array  # uint8[S, W, cols]


@dataclasses.dataclass(frozen=True)
class PoolStats:
    """Lifetime wear summary of a pool: how many physical writes its cells
    absorbed across every tensor programmed so far.  ``exhaustion_horizon``
    converts the worst cell into "how many such histories until the
    endurance budget dies" — the paper's motivating quantity made
    measurable (see docs/paper_map.md, endurance accounting)."""

    n_crossbars: int
    cells: int  # L * rows * cols physical memristors
    tensors_seen: int
    programs: int  # crossbar program operations (jobs) executed
    total_writes: int
    max_cell_writes: int
    mean_cell_writes: float

    def exhaustion_horizon(self, endurance: float = DEFAULT_ENDURANCE) -> float:
        """How many times the observed programming history could repeat before
        the most-worn cell exceeds ``endurance`` writes (inf if unworn)."""
        if self.max_cell_writes == 0:
            return float("inf")
        return endurance / self.max_cell_writes

    def to_dict(self, endurance: float = DEFAULT_ENDURANCE) -> dict:
        d = dataclasses.asdict(self)
        d["endurance"] = endurance
        d["exhaustion_horizon"] = self.exhaustion_horizon(endurance)
        return d


# ---------------------------------------------------------------------------
# Jitted packed helpers (retrace per shape bucket, like the planner core)
# ---------------------------------------------------------------------------

@jax.jit
def _price_intra_packed(packed: jax.Array, prev: jax.Array, cur: jax.Array) -> jax.Array:
    """Intra-chain job costs, batched: one ``price_pairs`` over all
    section-to-section steps of every chain (the gathers stay inside jit).
    Seams are priced separately — the chain→crossbar assignment, hence which
    pool state each seam reprograms, depends on these intra totals first."""
    return hamming_ops.price_pairs(packed[prev], packed[cur])


@partial(jax.jit, static_argnames=("rows",))
def _full_program_packed(
    state_assigned: jax.Array, packed: jax.Array,
    padded: jax.Array, valid: jax.Array, *, rows: int,
) -> tuple[jax.Array, jax.Array]:
    """p=1 pool walk, fully vectorized (no scan): every cell that differs is
    programmed, so per-cell wear is the XOR of consecutive resident states.

    Returns (wear int32[Lc, rows, cols], final states uint8[Lc, W, cols]).
    """
    seq = packed[padded]  # [Lc, T, W, cols]
    prev = jnp.concatenate([state_assigned[:, None], seq[:, :-1]], axis=1)
    tog = jnp.bitwise_xor(prev, seq)
    tog = jnp.where(valid[:, :, None, None], tog, jnp.uint8(0))
    bits = jnp.unpackbits(tog, axis=2, count=rows)  # [Lc, T, rows, cols]
    wear = jnp.sum(bits.astype(jnp.int32), axis=1)
    # padding repeats the last real section, so seq[:, -1] is the final state
    return wear, seq[:, -1]


@partial(jax.jit, static_argnames=("rows", "stuck_cols"))
def _stuck_program_packed(
    packed: jax.Array, padded: jax.Array, valid: jax.Array, keys: jax.Array,
    state_assigned: jax.Array, p: jax.Array | float, *, rows: int, stuck_cols: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """p<1 pool walk: ``stucking._walk_packed`` per chain, seeded with the
    pool's persistent states and accumulating per-cell wear.

    Returns (counts int32[Lc, T], wear int32[Lc, rows, cols],
    final states uint8[Lc, W, cols], achieved uint8[S, W, cols]).
    """
    _, states, counts, wear = jax.vmap(
        lambda o, v, k, s0: _walk_packed(
            packed, o, p, k, rows=rows, stuck_cols=stuck_cols,
            include_initial=True, valid=v, state0=s0, with_wear=True,
        )
    )(padded, valid, keys, state_assigned)
    # padded steps are masked no-ops (see stucking._pad_chains), so duplicate
    # indices in this scatter carry values identical to the last real visit
    achieved = packed.at[padded.reshape(-1)].set(
        states.reshape((-1,) + packed.shape[1:])
    )
    return counts, wear, states[:, -1], achieved


# ---------------------------------------------------------------------------
# Bool-plane oracle twin (eager, readable; bit-exact with the packed path)
# ---------------------------------------------------------------------------

def _program_bool_reference(
    planes: np.ndarray,  # bool[S, rows, cols] ideal section planes
    state_bool: np.ndarray,  # bool[Lc, rows, cols] assigned pool states
    chains: list[np.ndarray],
    p: float,
    key: jax.Array,
    *,
    stuck_cols: int,
) -> tuple[list[list[int]], np.ndarray, np.ndarray, np.ndarray]:
    """Eager per-chain walk mirroring the packed path's exact PRNG discipline:
    per-chain keys from one ``split(key, Lc)`` and per-step keys from
    ``split(chain_key, padded_len)`` — the schedule ``stucking._pad_chains``
    and ``_walk_packed`` use, so Bernoulli masks match draw for draw.

    Returns (per-chain per-step counts, wear int64[Lc, rows, cols],
    final states bool[Lc, rows, cols], achieved bool[S, rows, cols]).
    """
    max_len = max(len(c) for c in chains)
    chain_keys = jax.random.split(key, len(chains))
    achieved = np.array(planes, dtype=bool)
    wear = np.zeros(state_bool.shape, np.int64)
    finals = np.empty_like(state_bool)
    counts: list[list[int]] = []
    p32 = jnp.float32(p)  # match _walk_packed's float32 threshold exactly
    for i, ch in enumerate(chains):
        state = np.array(state_bool[i], dtype=bool)
        step_keys = jax.random.split(chain_keys[i], max_len)
        chain_counts = []
        for t, sec in enumerate(np.asarray(ch)):
            target = np.asarray(planes[sec])
            trans = state ^ target
            if p < 1.0 and stuck_cols > 0:
                mask = np.asarray(
                    jax.random.bernoulli(
                        step_keys[t], p32, (state.shape[0], stuck_cols)
                    )
                )
                program = trans.copy()
                program[:, :stuck_cols] &= mask
            else:
                program = trans
            state = np.where(program, target, state)
            wear[i] += program
            chain_counts.append(int(program.sum()))
            achieved[sec] = state
        finals[i] = state
        counts.append(chain_counts)
    return counts, wear, finals, achieved


# ---------------------------------------------------------------------------
# The pool
# ---------------------------------------------------------------------------

class CrossbarPool:
    """L physical crossbars with persistent content and per-cell wear.

    ``state`` is packed exactly like the planner's canonical planes
    (``uint8[L, ceil(rows/8), cols]``, rows packed MSB-first); ``wear`` is a
    host ``int64[L, rows, cols]`` counter of programmed transitions per cell.
    """

    def __init__(self, spec: "CrossbarSpec", n_crossbars: int, *, leveling: str = "none"):
        if leveling not in LEVELINGS:
            raise ValueError(f"unknown pool leveling {leveling!r}; choose from {LEVELINGS}")
        if n_crossbars < 1:
            raise ValueError("pool needs at least one crossbar")
        if spec.rows < 1 or spec.cols < 1:
            raise ValueError(
                f"crossbar geometry must be positive, got {spec.rows}x{spec.cols}"
            )
        self.spec = spec
        self.n_crossbars = int(n_crossbars)
        self.leveling = leveling
        self._words = -(-spec.rows // 8)
        self._state = jnp.zeros((self.n_crossbars, self._words, spec.cols), jnp.uint8)
        self.wear = np.zeros((self.n_crossbars, spec.rows, spec.cols), np.int64)
        self.tensors_seen = 0
        self.programs = 0
        self.total_writes = 0
        self.faults = None  # Optional[nonideal.FaultState]
        self.integrity = None  # Optional[integrity.IntegrityManager]

    # -- integrity ---------------------------------------------------------

    def enable_integrity(self, cfg=None):
        """Attach an :class:`~repro.core.integrity.IntegrityManager`.

        Once enabled, every ``program()`` call registers the tensor's
        reference planes, per-tile checksums, and spare columns with the
        manager, so the scrub/detect/repair loop (``core/integrity.py``) can
        verify and repair the deployment online.  Returns the manager (also
        kept on ``self.integrity``).
        """
        from repro.core import integrity  # local: pool <-> integrity cycle hygiene

        self.integrity = integrity.IntegrityManager(
            self, cfg or integrity.IntegrityConfig()
        )
        return self.integrity

    # -- faults ------------------------------------------------------------

    def inject_faults(self, model, key: jax.Array | None = None):
        """Sample and attach a ``nonideal.FaultState`` for this pool.

        Deterministic per (model, key).  Once attached, every
        ``program()`` report's ``achieved_read`` passes through the stuck
        masks and the ``"fault"`` leveling has damage information to remap
        against.  Returns the state (also kept on ``self.faults``).
        """
        from repro.core import nonideal  # local: planner <-> pool cycle hygiene

        if key is None:
            key = jax.random.PRNGKey(0)
        self.faults = nonideal.inject(self.spec, self.n_crossbars, model, key)
        return self.faults

    def read_state(self) -> np.ndarray:
        """Host copy of the pool content *as read* through any fault masks."""
        if self.faults is None:
            return self.state
        from repro.core import nonideal

        return np.asarray(
            nonideal.read_packed(self._state, self.faults.stuck0, self.faults.stuck1)
        )

    # -- introspection -----------------------------------------------------

    @property
    def state(self) -> np.ndarray:
        """Host copy of the packed pool content uint8[L, W, cols]."""
        return np.asarray(self._state)

    def wear_totals(self) -> np.ndarray:
        """Accumulated writes per crossbar -> int64[L]."""
        return self.wear.sum(axis=(1, 2))

    def stats(self) -> PoolStats:
        return PoolStats(
            n_crossbars=self.n_crossbars,
            cells=int(self.wear.size),
            tensors_seen=self.tensors_seen,
            programs=self.programs,
            total_writes=self.total_writes,
            max_cell_writes=int(self.wear.max()),
            mean_cell_writes=float(self.wear.mean()),
        )

    def reset(self, *, wear: bool = False) -> None:
        """Zero the crossbar content (and optionally the wear history).

        Resetting content between tensors recovers the planner's per-tensor
        pristine accounting bit-exactly (parity invariant (a)); wear normally
        survives resets — erasing a crossbar is itself free only in this
        simplified model, but the counters exist to *accumulate* lifetimes.
        """
        self._state = jnp.zeros_like(self._state)
        if wear:
            self.wear[:] = 0
            self.tensors_seen = 0
            self.programs = 0
            self.total_writes = 0

    # -- chain -> crossbar assignment --------------------------------------

    def _assign(
        self,
        chain_costs: np.ndarray,
        leveling: str,
        *,
        packed: jax.Array | None = None,
        chains: list[np.ndarray] | None = None,
    ) -> np.ndarray:
        lc = chain_costs.shape[0]
        if leveling == "none":
            return np.arange(lc, dtype=np.int32)
        if leveling == "rotate":
            # seed the contiguous chain block at the least-worn crossbar
            start = int(np.argmin(self.wear_totals()))
            return ((start + np.arange(lc)) % self.n_crossbars).astype(np.int32)
        if leveling == "fault" and self.faults is not None and packed is not None:
            # X-CHANGR-style remap: steer damage-sensitive chains away from
            # crossbars whose stuck cells would flip their high-order bits,
            # ties broken toward least wear (core/nonideal.py)
            from repro.core import nonideal

            damage = nonideal.damage_matrix(packed, chains, self.faults)
            return nonideal.fault_aware_assignment(damage, wear=self.wear_totals())
        # "lpt" (and "fault" with no injected faults — nothing to avoid,
        # wear-level instead): heaviest chains to least-worn crossbars, one
        # chain per crossbar (capacity 1 — chains program in parallel on
        # distinct hardware), loads seeded with accumulated wear
        tids, _ = schedule.lpt_assignment(
            chain_costs, self.n_crossbars,
            initial_loads=self.wear_totals(), capacity=1,
        )
        return tids

    # -- programming -------------------------------------------------------

    def program(
        self,
        packed: jax.Array,
        chains: list[np.ndarray],
        *,
        p_stuck: float = 1.0,
        key: jax.Array | None = None,
        stuck_cols: int = 1,
        leveling: str | None = None,
        impl: str = "packed",
        name: str = "w",
    ) -> PoolProgramReport:
        """Stream one tensor's sections through the pool along ``chains``.

        ``packed`` are canonical packed planes ``uint8[S, W, cols]`` (bool
        planes are packed on entry), or a :class:`~repro.core.planes.PlaneSet`
        — a codec-encoded stored representation, in which case the pool
        programs its ``physical()`` bits: the words the crossbar actually
        holds (permuted columns for ``col_perm``, reconstructed constants for
        ``const_rle``).  Seam pricing, wear counters, and fault masks all see
        those physical bits, so endurance accounting stays exact under every
        codec; the caller recovers logical planes from ``achieved_read`` with
        ``planes.logical_from_physical`` *after* the (possibly faulty) read.
        Each chain is assigned a physical crossbar (``leveling=None`` defers
        to the pool's own setting); its first program reprograms whatever
        that crossbar currently holds — the cross-tensor seam.  State and
        wear counters are updated in place; per-job costs, seams, and wear
        increments come back in the report.  Every program is counted
        (``include_initial`` semantics are inherently True for a pool: the
        seam is a physical write).
        """
        if impl not in ("packed", "bool"):
            raise ValueError(f"unknown pool impl: {impl!r}")
        leveling = self.leveling if leveling is None else leveling
        if leveling not in LEVELINGS:
            raise ValueError(f"unknown pool leveling {leveling!r}; choose from {LEVELINGS}")
        col_order = None
        if hasattr(packed, "physical"):  # PlaneSet: program the stored bits
            if getattr(packed, "col_order", None) is not None:
                col_order = np.asarray(packed.col_order)
            packed = packed.physical()
        packed = jnp.asarray(packed)
        if packed.dtype != jnp.uint8:
            packed = bitslice.pack_rows(packed)
        s, words, cols = packed.shape
        if (words, cols) != (self._words, self.spec.cols):
            raise ValueError(
                f"section planes {packed.shape} do not fit pool geometry "
                f"{self.spec.rows}x{self.spec.cols}"
            )
        chains = [np.asarray(c, dtype=np.int32) for c in chains]
        lc = len(chains)
        if not 1 <= lc <= self.n_crossbars:
            raise ValueError(f"{lc} chains for a pool of {self.n_crossbars} crossbars")
        if key is None:
            key = jax.random.PRNGKey(0)
        rows = self.spec.rows
        full = p_stuck >= 1.0 or stuck_cols == 0

        planes = bitslice.unpack_rows(packed, rows) if impl == "bool" else None

        # --- intra-chain job costs (assignment-independent) ----------------
        prev_i, cur_i = schedule.chain_pairs(chains, include_initial=False)
        if impl == "packed":
            intra = np.asarray(
                _price_intra_packed(packed, prev_i, cur_i), np.int64
            ) if prev_i.size else np.zeros((0,), np.int64)
        else:
            intra = (
                np.asarray(cost.pair_transitions(planes[prev_i], planes[cur_i]), np.int64)
                if prev_i.size else np.zeros((0,), np.int64)
            )
        lens = [len(c) - 1 for c in chains]
        intra_per_chain = np.split(intra, np.cumsum(lens)[:-1]) if lc else []
        chain_intra = np.array([x.sum() for x in intra_per_chain], np.int64)

        # --- chain -> crossbar assignment + seam pricing --------------------
        assignment = self._assign(chain_intra, leveling, packed=packed, chains=chains)
        firsts = np.array([c[0] for c in chains], np.int32)
        assignment_dev = jnp.asarray(assignment)
        state_assigned = self._state[assignment_dev]
        if impl == "packed":
            seam = np.asarray(
                hamming_ops.price_pairs(state_assigned, packed[firsts]), np.int64
            )
        else:
            state_bool = np.asarray(bitslice.unpack_rows(self._state, rows))[assignment]
            seam = np.asarray(
                cost.pair_transitions(jnp.asarray(state_bool), planes[firsts]), np.int64
            )
        job_costs = np.concatenate(
            [np.concatenate([seam[j : j + 1], intra_per_chain[j]]) for j in range(lc)]
        )
        chain_totals = seam + chain_intra

        # --- the physical walk: wear, final states, achieved planes ---------
        padded, valid, keys = _pad_chains(chains, key)
        if impl == "packed":
            if full:
                wear_inc, new_states = _full_program_packed(
                    state_assigned, packed, padded, valid, rows=rows
                )
                achieved = packed
                programmed_job_costs = job_costs
            else:
                counts, wear_inc, new_states, achieved = _stuck_program_packed(
                    packed, padded, valid, keys, state_assigned, p_stuck,
                    rows=rows, stuck_cols=stuck_cols,
                )
                counts = np.asarray(counts, np.int64)
                programmed_job_costs = np.concatenate(
                    [counts[j, : len(c)] for j, c in enumerate(chains)]
                )
            wear_inc = np.asarray(wear_inc, np.int64)
            new_states = jnp.asarray(new_states)
        else:
            counts_b, wear_inc, finals_b, achieved_b = _program_bool_reference(
                np.asarray(planes), state_bool, chains, p_stuck, key,
                stuck_cols=stuck_cols,
            )
            programmed_job_costs = np.array(
                [c for per_chain in counts_b for c in per_chain], np.int64
            )
            new_states = bitslice.pack_rows(jnp.asarray(finals_b))
            achieved = bitslice.pack_rows(jnp.asarray(achieved_b))

        # --- non-ideal readback ---------------------------------------------
        if self.faults is None:
            achieved_read = achieved
        else:
            from repro.core import nonideal

            sec_xbar = np.zeros(s, np.int32)
            for j, c in enumerate(chains):
                sec_xbar[c] = assignment[j]
            idx = jnp.asarray(sec_xbar)
            achieved_read = nonideal.read_packed(
                achieved, self.faults.stuck0[idx], self.faults.stuck1[idx]
            )

        # --- commit ---------------------------------------------------------
        self._state = self._state.at[assignment_dev].set(new_states)
        self.wear[assignment] += wear_inc
        self.tensors_seen += 1
        self.programs += int(job_costs.shape[0])
        wear_total = int(wear_inc.sum())
        self.total_writes += wear_total

        report = PoolProgramReport(
            name=name,
            assignment=assignment,
            seam_costs=seam,
            chain_totals=chain_totals,
            job_costs=job_costs,
            programmed_job_costs=programmed_job_costs,
            transitions_full=int(job_costs.sum()),
            transitions_programmed=int(programmed_job_costs.sum()),
            wear_increment_total=wear_total,
            wear_increment_max=int(wear_inc.max()),
            achieved=achieved,
            achieved_read=achieved_read,
        )
        if self.integrity is not None:
            # register reference planes + tile checksums for the scrub loop
            self.integrity.register(report, chains=chains, col_order=col_order)
        return report
