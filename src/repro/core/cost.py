"""Reprogramming cost model (Eq. 1 of the paper).

The cost of reprogramming a crossbar holding bit matrix ``A`` to hold ``B`` is
the number of memristors that change state::

    R_AB = sum_ij |a_ij - b_ij|        (Hamming distance)

``chain_transitions`` prices a whole programming *chain* (one physical
crossbar walking an ordered list of sections); per-column breakdowns feed the
bit-stucking analysis (low-order columns carry a disproportionate share of
transitions because their bit values are ~Bernoulli(0.5)).

Two equivalent paths are provided:
  * bool planes  — direct XOR + sum (clear, differentiable-ish; kept as the
    readable oracle the packed path is tested against)
  * packed uint8 — XOR + ``lax.population_count`` (8x less data movement)

**Packed-plane invariant (canonical fast path).**  The planner packs each
tensor's bit planes exactly once (``bitslice.section_planes_packed``) into
``uint8[S, W, cols]`` where ``W = ceil(rows/8)``: the *rows* axis is packed
MSB-first into byte words, the bit-column axis stays unpacked (so per-column
stucking/pricing still slices ``[..., :k]``), and row padding is zero (a
pristine memristor), which makes padded words free in every XOR+popcount.
All downstream pricing — the batched pair pricing in ``core.schedule``
(the planner's actual hot path) and the stucking walks in ``core.stucking``
— consumes these packed words directly; bool planes are only materialized
at the very end for dequantization.  ``chain_transitions_packed`` /
``consecutive_costs_packed`` here are the packed twins of the chain-level
oracles, used for parity pinning and ad-hoc packed pricing rather than by
the planner itself.  Pair pricing dispatches through
``repro.kernels.hamming.ops.price_pairs``: the compiled Pallas kernel on
TPU, a plain ``lax.population_count`` XOR on every other backend
(interpret-mode Pallas would be far slower than the portable fallback).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _popcount_i32(x: jax.Array) -> jax.Array:
    return jax.lax.population_count(x).astype(jnp.int32)


def pair_transitions(a: jax.Array, b: jax.Array) -> jax.Array:
    """R_AB for bool planes of identical shape [..., rows, cols] -> int32[...]."""
    return jnp.sum(jnp.logical_xor(a, b), axis=(-2, -1), dtype=jnp.int32)


def pair_transitions_packed(a: jax.Array, b: jax.Array) -> jax.Array:
    """R_AB for packed uint8 planes [..., words, cols] -> int32[...]."""
    x = jax.lax.population_count(jnp.bitwise_xor(a, b))
    return jnp.sum(x.astype(jnp.int32), axis=(-2, -1))


def chain_transitions_packed(
    packed: jax.Array,
    order: jax.Array | None = None,
    *,
    include_initial: bool = True,
    per_column: bool = False,
) -> jax.Array:
    """:func:`chain_transitions` on packed planes uint8[S, W, cols].

    Bit-exact with the bool path: row padding inside the packed words is zero
    on both chain states, so it never contributes to the XOR popcount.
    """
    seq = packed if order is None else packed[order]
    diffs = _popcount_i32(jnp.bitwise_xor(seq[1:], seq[:-1]))
    axes = (0, 1, 2) if not per_column else (0, 1)
    total = jnp.sum(diffs, axis=axes)
    if include_initial:
        first = _popcount_i32(seq[0])
        total = total + jnp.sum(first, axis=0 if per_column else None)
    return total


def consecutive_costs_packed(
    packed: jax.Array, order: jax.Array | None = None, *, include_initial: bool = True
) -> jax.Array:
    """:func:`consecutive_costs` on packed planes -> int32[T] (or [T-1])."""
    seq = packed if order is None else packed[order]
    step = jnp.sum(_popcount_i32(jnp.bitwise_xor(seq[1:], seq[:-1])), axis=(1, 2))
    if include_initial:
        first = jnp.sum(_popcount_i32(seq[0]))[None]
        step = jnp.concatenate([first, step])
    return step


def chain_transitions(
    planes: jax.Array,
    order: jax.Array | None = None,
    *,
    include_initial: bool = True,
    per_column: bool = False,
) -> jax.Array:
    """Total transitions programming sections along ``order`` on ONE crossbar.

    planes: bool[S, rows, cols]; order: int[T] (defaults to arange(S)).
    The crossbar starts pristine (all inactive); if ``include_initial`` the
    first program from the pristine state is counted (the paper counts it —
    stride-1 'initially incurs higher costs by programming the first L
    crossbars').

    Returns int32[] total, or int32[cols] per-column totals if requested.
    """
    seq = planes if order is None else planes[order]
    diffs = jnp.logical_xor(seq[1:], seq[:-1])
    axes = (0, 1, 2) if not per_column else (0, 1)
    total = jnp.sum(diffs, axis=axes, dtype=jnp.int32)
    if include_initial:
        # per-column keeps the cols axis: reduce rows only
        total = total + jnp.sum(seq[0], axis=0 if per_column else None, dtype=jnp.int32)
    return total


def consecutive_costs(
    planes: jax.Array, order: jax.Array | None = None, *, include_initial: bool = True
) -> jax.Array:
    """Per-step reprogramming costs along a chain -> int32[T] (or [T-1]).

    Step t is the cost of programming section order[t] over the previous
    state; step 0 (if included) is programming over the pristine crossbar.
    These per-step costs are the 'jobs' the thread balancer schedules.
    """
    seq = planes if order is None else planes[order]
    step = jnp.sum(jnp.logical_xor(seq[1:], seq[:-1]), axis=(1, 2), dtype=jnp.int32)
    if include_initial:
        first = jnp.sum(seq[0], dtype=jnp.int32)[None]
        step = jnp.concatenate([first, step])
    return step


def active_fraction_per_column(planes: jax.Array) -> jax.Array:
    """Fraction of active memristors per bit column -> f32[cols].

    The paper's §IV observation: for bell-shaped weights this tends to 0.5 in
    the lowest-order column and decays toward 0 for high-order columns.
    """
    return jnp.mean(planes.astype(jnp.float32), axis=tuple(range(planes.ndim - 1)))


def transition_fraction_per_column(planes: jax.Array, order: jax.Array | None = None) -> jax.Array:
    """Expected per-column share of chain transitions -> f32[cols]."""
    seq = planes if order is None else planes[order]
    diffs = jnp.logical_xor(seq[1:], seq[:-1]).astype(jnp.float32)
    col = jnp.sum(diffs, axis=(0, 1))
    return col / jnp.maximum(jnp.sum(col), 1.0)
