#!/usr/bin/env python
"""Docs reference checker: fail on dead links so the paper→code map can't rot.

Scans README.md and docs/*.md for three kinds of references and verifies
each against the working tree (no network, stdlib only):

  1. Markdown links ``[text](target)``: ``#anchor`` targets must match a
     heading in the same file; relative-path targets (optionally with an
     ``#anchor``) must exist, and the anchor must match a heading in the
     target file.  ``http(s)://`` targets are skipped.
  2. Backticked repo paths (`` `src/repro/core/pool.py` ``, `` `docs/...` ``):
     any backticked token containing a ``/`` and a known file suffix must
     exist relative to the repo root (glob patterns like ``BENCH_*.json``
     are matched as globs).
  3. Backticked dotted module references (`` `repro.launch.engine` ``,
     `` `benchmarks.roofline` ``): the longest module prefix must resolve
     to a real ``.py`` file or package under ``src/`` or the repo root —
     trailing attribute names (``repro.core.pool.CrossbarPool``) are
     allowed as long as the module part resolves.

Exit status: 0 when the docs are sound, 1 when any reference is dead (each
one printed to stderr).  Run as ``python tools/check_docs.py`` from the
repo root; CI runs it as its own job and tier-1 wraps it in
``tests/test_docs.py``.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]
PATH_SUFFIXES = (".py", ".md", ".json", ".yml", ".yaml", ".txt", ".ini")
MODULE_ROOTS = {"repro": REPO / "src" / "repro", "benchmarks": REPO / "benchmarks"}

LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.MULTILINE)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip formatting, lowercase, spaces -> dashes,
    drop everything that isn't alphanumeric, dash, or underscore."""
    text = re.sub(r"[*`]", "", heading)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # [text](url) -> text
    text = text.strip().lower().replace(" ", "-")
    return re.sub(r"[^\wÀ-￿-]", "", text)


def anchors_of(path: Path, cache: dict) -> set[str]:
    if path not in cache:
        cache[path] = {github_slug(h) for h in HEADING_RE.findall(path.read_text())}
    return cache[path]


def check_link(md: Path, target: str, cache: dict) -> str | None:
    """None if the link resolves, else a human-readable complaint."""
    if target.startswith(("http://", "https://", "mailto:")):
        return None
    path_part, _, anchor = target.partition("#")
    dest = md if not path_part else (md.parent / path_part).resolve()
    if not dest.exists():
        return f"missing file {path_part!r}"
    if anchor and dest.suffix == ".md":
        if anchor not in anchors_of(dest, cache):
            return f"missing anchor #{anchor} in {dest.relative_to(REPO)}"
    return None


def check_repo_path(token: str) -> str | None:
    """Backticked path-looking token: must exist (globs allowed)."""
    if any(ch in token for ch in "*?["):
        return None if list(REPO.glob(token)) else f"no files match glob {token!r}"
    return None if (REPO / token).exists() else f"missing path {token!r}"


def check_module_ref(token: str) -> str | None:
    """Dotted `repro...` / `benchmarks...` reference: the module part must
    resolve to a .py file or package.  Attributes are only tolerated AFTER
    a component resolved to a module file — a name that follows a package
    directory must itself be a module or sub-package, so renaming e.g.
    launch/paged_cache.py flags every doc still saying
    `repro.launch.paged_cache`."""
    parts = token.split(".")
    root = MODULE_ROOTS[parts[0]]
    node = root
    for part in parts[1:]:
        if (node / part).is_dir():
            node = node / part
            continue
        if (node / f"{part}.py").is_file():
            return None  # module resolves; the rest are attributes
        return (
            f"{token!r}: no module/package {part!r} under "
            f"{node.relative_to(REPO)}"
        )
    return None  # pure package reference


def scan(md: Path, cache: dict) -> list[str]:
    text = md.read_text()
    problems = []
    for m in LINK_RE.finditer(text):
        err = check_link(md, m.group(1), cache)
        if err:
            problems.append(f"{md.relative_to(REPO)}: link ({m.group(1)}): {err}")
    for m in CODE_RE.finditer(text):
        token = m.group(0).strip("`").strip()
        if "/" in token and token.endswith(PATH_SUFFIXES) and " " not in token:
            err = check_repo_path(token)
            if err:
                problems.append(f"{md.relative_to(REPO)}: `{token}`: {err}")
        elif re.fullmatch(r"(repro|benchmarks)\.[\w.]+", token):
            err = check_module_ref(token)
            if err:
                problems.append(f"{md.relative_to(REPO)}: `{token}`: {err}")
    return problems


def main() -> int:
    cache: dict = {}
    problems = []
    for md in DOC_FILES:
        if md.exists():
            problems.extend(scan(md, cache))
    for p in problems:
        print(f"DEAD REF: {p}", file=sys.stderr)
    if not problems:
        n_files = sum(1 for f in DOC_FILES if f.exists())
        print(f"docs check OK ({n_files} files)")
    return 1 if problems else 0  # a plain count would wrap mod 256 in exit()


if __name__ == "__main__":
    sys.exit(main())
