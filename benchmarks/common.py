"""Shared benchmark machinery: shape-faithful model weight sets + helpers.

The paper evaluates trained ResNet/VGG/AlexNet/ViT/DeiT on ImageNet-1K.
This environment has no ImageNet or pretrained checkpoints (DESIGN.md §2),
so each model is represented by its *exact published layer shapes* with
fan-in-scaled gaussian weights — the bell-shaped distribution SWS exploits
is a property of both trained and initialized DNNs (Han et al. 2015).  The
LM entries draw their shapes from this framework's assigned architecture
configs, tying the paper's experiments to the production stack.

``--full`` benchmarks every element of every tensor; the default caps each
tensor at ``max_elems`` (transitions are a per-element statistic, so a
uniform subsample is unbiased; validated against --full on VGG16).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, Iterable

import jax
import jax.numpy as jnp

OUT_DIR = Path("experiments/bench")

PHYS_COLS = 128  # physical crossbar columns (the paper's 128x128 arrays)


def weights_per_section(cols: int, rows: int = 128) -> int:
    """Weights one crossbar holds (paper §II: a 128x128 array with 16
    power-of-two multipliers stores 128/16 = 8 weights per row, labelled
    '128x16'; '128x10' stores 12 weights per row)."""
    return rows * max(1, PHYS_COLS // cols)

# ---------------------------------------------------------------------------
# Shape-faithful model weight sets
# ---------------------------------------------------------------------------

def _conv(cout, cin, k):  # torch layout (cout, cin, k, k)
    return (cout, cin, k, k)


def _resnet50_shapes() -> list[tuple[int, ...]]:
    shapes = [_conv(64, 3, 7)]
    # (in_planes, planes, blocks, stride) per stage; bottleneck expansion 4
    stages = [(64, 64, 3), (256, 128, 4), (512, 256, 6), (1024, 512, 3)]
    for cin, planes, blocks in stages:
        for b in range(blocks):
            c_in = cin if b == 0 else planes * 4
            shapes += [
                _conv(planes, c_in, 1),
                _conv(planes, planes, 3),
                _conv(planes * 4, planes, 1),
            ]
            if b == 0:
                shapes.append(_conv(planes * 4, c_in, 1))  # downsample proj
    shapes.append((1000, 2048))  # fc
    return shapes


def _vgg16_shapes() -> list[tuple[int, ...]]:
    cfg = [64, 64, 128, 128, 256, 256, 256, 512, 512, 512, 512, 512, 512]
    shapes, cin = [], 3
    for cout in cfg:
        shapes.append(_conv(cout, cin, 3))
        cin = cout
    shapes += [(4096, 25088), (4096, 4096), (1000, 4096)]
    return shapes


def _alexnet_shapes() -> list[tuple[int, ...]]:
    return [
        _conv(64, 3, 11), _conv(192, 64, 5), _conv(384, 192, 3),
        _conv(256, 384, 3), _conv(256, 256, 3),
        (4096, 9216), (4096, 4096), (1000, 4096),
    ]


def _vit_shapes(d: int, layers: int, heads: int) -> list[tuple[int, ...]]:
    shapes = [(d, 3 * 16 * 16)]  # patch embed
    for _ in range(layers):
        shapes += [(d, 3 * d), (d, d), (d, 4 * d), (4 * d, d)]
    shapes.append((1000, d))
    return shapes


def _lm_layer_shapes(arch: str) -> list[tuple[int, ...]]:
    """One transformer layer's matmul weights from an assigned arch config."""
    from repro.configs import get_arch

    cfg = get_arch(arch)
    hd = cfg.resolved_head_dim
    shapes = [
        (cfg.d_model, cfg.n_heads * hd),
        (cfg.d_model, cfg.n_kv_heads * hd),
        (cfg.d_model, cfg.n_kv_heads * hd),
        (cfg.n_heads * hd, cfg.d_model),
    ]
    if cfg.d_ff:
        shapes += [(cfg.d_model, cfg.d_ff)] * 2 + [(cfg.d_ff, cfg.d_model)]
    return shapes


MODELS: dict[str, Callable[[], list[tuple[int, ...]]]] = {
    "alexnet": _alexnet_shapes,
    "vgg16": _vgg16_shapes,
    "resnet50": _resnet50_shapes,
    "deit-tiny": lambda: _vit_shapes(192, 12, 3),
    "deit-base": lambda: _vit_shapes(768, 12, 12),
    "vit-base": lambda: _vit_shapes(768, 12, 12),
    # LM-framework tie-ins (one layer each; full model = n_layers x this)
    "internlm2-layer": lambda: _lm_layer_shapes("internlm2-1.8b"),
    "yi6b-layer": lambda: _lm_layer_shapes("yi-6b"),
}

PAPER_DEFAULT_MODELS = ["alexnet", "vgg16", "resnet50", "deit-tiny", "deit-base", "vit-base"]


def model_weights(
    name: str, *, max_elems: int = 2_000_000, seed: int = 0
) -> Iterable[tuple[str, jax.Array]]:
    """Yield (tensor_name, flat_weights) with fan-in-scaled gaussian values."""
    key = jax.random.PRNGKey(seed)
    for i, shape in enumerate(MODELS[name]()):
        fan_in = int(jnp.prod(jnp.asarray(shape[1:]))) if len(shape) > 1 else shape[0]
        n = int(jnp.prod(jnp.asarray(shape)))
        n_eff = min(n, max_elems) if max_elems else n
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (n_eff,)) * (2.0 / fan_in) ** 0.5
        yield f"{name}/t{i}{tuple(shape)}", w


def model_planes(
    name: str,
    *,
    cols: int = 10,
    rows: int = 128,
    sort: bool = True,
    max_elems: int = 2_000_000,
    seed: int = 0,
) -> jax.Array:
    """bool[S, W, cols] section bit planes for a whole model, W = weights per
    physical crossbar (see ``weights_per_section``).

    Mirrors the paper's accounting: quantization scale and the SWS sort are
    *per layer* (a global sort/scale would let small-fan-in layers collapse
    to zeros and inflate speedups by an order of magnitude), and the
    per-layer section streams are concatenated in layer order — the model
    streaming through the crossbar pool layer by layer.
    """
    from repro.core import bitslice, sws

    w_per = weights_per_section(cols, rows)
    chunks = []
    for _, w in model_weights(name, max_elems=max_elems, seed=seed):
        if sort:
            w = w[sws.sws_permutation(w)]
        qt = bitslice.quantize(w, cols)
        q = jnp.pad(qt.q, (0, (-w.shape[0]) % w_per))
        chunks.append(bitslice.bitplanes(q.reshape(-1, w_per), cols))
    return jnp.concatenate(chunks, axis=0)


# ---------------------------------------------------------------------------
# Output helpers
# ---------------------------------------------------------------------------

def save_json(figname: str, payload: dict) -> Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{figname}.json"
    path.write_text(json.dumps(payload, indent=1))
    return path


def banner(title: str) -> None:
    print(f"\n=== {title} " + "=" * max(0, 70 - len(title)))


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
