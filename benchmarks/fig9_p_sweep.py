"""Paper Fig. 9 — sweeping the stucking probability p (ViT-Base, ResNet-50).

Two halves, mirroring the paper's two panels under our data constraints
(DESIGN.md §2 — no ImageNet):

* transitions: swept on the shape-faithful ViT-Base / ResNet-50 weight sets;
* accuracy: swept on a *trained* reduced LM where task accuracy is directly
  measurable (deterministic next-token task), deployed at each p.

Paper finding: p can be driven to 0 (stuck column) within a 1% accuracy
margin; speedup grows as p falls.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import banner, model_planes, save_json
from benchmarks.trained_lm import eval_accuracy, get_trained_lm
from repro.core import schedule, stucking
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment, deploy_params

ROWS, COLS = 128, 10
L_CROSSBARS = 16
PS = (0.0, 0.25, 0.5, 0.75, 1.0)


def transitions_sweep(models=("vit-base", "resnet50"), *, max_elems=2_000_000, seed=0):
    # The exact stochastic stucking walk is sequential over sections; cap the
    # per-tensor sample harder than the other figures (transitions are a
    # per-element statistic, so a uniform subsample is unbiased; --full lifts).
    max_elems = min(max_elems, 500_000) if max_elems else 0
    out = {}
    key = jax.random.PRNGKey(seed)
    for m in models:
        planes = model_planes(m, cols=COLS, sort=True, max_elems=max_elems, seed=seed)
        chains = schedule.stride_1_chains(planes.shape[0], L_CROSSBARS)
        t_ref = None
        entry = {}
        for p in PS:
            key, sub = jax.random.split(key)
            t, _ = stucking.stuck_schedule(planes, chains, p, sub)
            t = int(t)
            if p == 1.0:
                t_ref = t
            entry[str(p)] = t
        out[m] = {
            "transitions": entry,
            "speedup_vs_p1": {k: t_ref / max(v, 1) for k, v in entry.items()},
        }
    return out


def accuracy_sweep(seed=0):
    cfg, params, batch_fn = get_trained_lm(seed=seed)
    acc_fp = eval_accuracy(cfg, params, batch_fn)
    out = {"fp_accuracy": acc_fp, "per_p": {}}
    for p in PS:
        plan = build_deployment(
            params, CrossbarSpec(rows=ROWS, cols=COLS),
            PlannerConfig(p_stuck=p, min_size=1024, seed=seed),
        )
        acc = eval_accuracy(cfg, deploy_params(params, plan), batch_fn)
        out["per_p"][str(p)] = {
            "accuracy": acc,
            "drop_pct": 100.0 * (acc_fp - acc),
            "total_speedup": plan.totals()["total_speedup"],
        }
    return out


def run(*, max_elems=2_000_000, seed=0) -> dict:
    return {
        "transitions": transitions_sweep(max_elems=max_elems, seed=seed),
        "accuracy": accuracy_sweep(seed=seed),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    banner("Fig. 9 — p sweep (speedup + accuracy)")
    res = run(max_elems=0 if args.full else 2_000_000)
    for m, r in res["transitions"].items():
        sp = "  ".join(f"p={p}:{v:.2f}x" for p, v in r["speedup_vs_p1"].items())
        print(f"  {m:10s} {sp}")
    acc = res["accuracy"]
    print(f"  trained-LM fp accuracy: {acc['fp_accuracy']:.4f}")
    for p, r in acc["per_p"].items():
        print(
            f"    p={p}: acc={r['accuracy']:.4f} (drop {r['drop_pct']:+.2f}%) "
            f"deploy-speedup={r['total_speedup']:.2f}x"
        )
    save_json("fig9_p_sweep", res)


if __name__ == "__main__":
    main()
