"""Paper Fig. 8 — bit-stucking speedup: p=0.5 over p=1 (full reprogramming).

Reprogramming the SWS stride-1 schedule with only half the transitional
memristors in the lowest-order column actually programmed.  Paper band:
+19% (AlexNet) to +27% (DeiT-Base) fewer transitions, <1% accuracy loss
(accuracy measured separately in fig9/fig10/accuracy_e2e).
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import PAPER_DEFAULT_MODELS, banner, model_planes, save_json
from repro.core import schedule, stucking

COLS = 10
L_CROSSBARS = 16


def run(models=None, *, p=0.5, max_elems=2_000_000, seed=0) -> dict:
    models = models or PAPER_DEFAULT_MODELS
    key = jax.random.PRNGKey(seed)
    results = {}
    for m in models:
        planes = model_planes(m, cols=COLS, sort=True, max_elems=max_elems, seed=seed)
        chains = schedule.stride_1_chains(planes.shape[0], L_CROSSBARS)
        key, sub = jax.random.split(key)
        t_full, _ = stucking.stuck_schedule(planes, chains, 1.0, sub)
        t_half, _ = stucking.stuck_schedule(planes, chains, p, sub)
        results[m] = {
            "p": p,
            "transitions_p1": int(t_full),
            "transitions_p": int(t_half),
            "speedup_pct": 100.0 * (int(t_full) - int(t_half)) / int(t_full),
        }
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--p", type=float, default=0.5)
    args = ap.parse_args()

    banner(f"Fig. 8 — bit stucking p={args.p} vs p=1")
    res = run(p=args.p, max_elems=0 if args.full else 2_000_000)
    for m, r in res.items():
        print(f"  {m:12s} saves {r['speedup_pct']:5.1f}% of transitions")
    save_json("fig8_stucking", res)
    print("  [paper check] band: 19% (AlexNet) .. 27% (DeiT-Base)")


if __name__ == "__main__":
    main()
