"""A trained reduced LM shared by the accuracy benchmarks (fig9/fig10/e2e).

Trains once per process (cached) on the deterministic next-token task, so
"accuracy" is exact and cheap to evaluate: the model must learn the vocab
lookup t -> (5t + 7) mod V.  A converged model scores ~1.0; crossbar
deployment error shows up directly as accuracy drop — the closest CPU-scale
analogue of the paper's ImageNet top-1 criterion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import DataConfig, make_dataset
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import AdamWConfig, adamw_init

ARCH = "internlm2-1.8b"
STEPS = 120
SEQ, BATCH = 64, 8


@functools.lru_cache(maxsize=2)
def get_trained_lm(seed: int = 0):
    cfg = get_arch(ARCH, reduced=True)
    ds = make_dataset(DataConfig(cfg.vocab_size, SEQ, BATCH, task="copy", seed=seed))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=STEPS)))
    params = api.init(jax.random.PRNGKey(seed), cfg)
    opt = adamw_init(params)
    for s in range(STEPS):
        params, opt, _ = step(params, opt, ds.batch_at(s))

    def batch_fn(i: int):
        return ds.batch_at(10_000 + i)  # held-out steps

    return cfg, params, batch_fn


def eval_accuracy(cfg, params, batch_fn, *, n_batches: int = 4) -> float:
    """Next-token accuracy on held-out batches."""
    correct = total = 0
    for i in range(n_batches):
        batch = batch_fn(i)
        logits, _ = api.forward(params, cfg, batch)
        pred = jnp.argmax(logits[:, :-1], axis=-1)
        tgt = batch["tokens"][:, 1:]
        correct += int(jnp.sum(pred == tgt))
        total += int(tgt.size)
    return correct / total
