"""Paper Fig. 5 — SWS speedup for a single 128x16 crossbar.

One physical crossbar walks every section of the model in (a) natural
unsorted order (ISAAC/CASCADE-style allocation) vs (b) per-layer SWS order;
speedup = transitions(a) / transitions(b).  Paper band: 1.47x (DeiT-Tiny,
sharp distribution) to 1.87x (VGG16, smooth distribution).
"""
from __future__ import annotations

import argparse

from benchmarks.common import PAPER_DEFAULT_MODELS, banner, model_planes, save_json
from repro.core import cost

ROWS, COLS = 128, 16


def run(models=None, *, max_elems=2_000_000, seed=0) -> dict:
    models = models or PAPER_DEFAULT_MODELS + ["internlm2-layer", "yi6b-layer"]
    results = {}
    for m in models:
        planes_u = model_planes(m, cols=COLS, sort=False, max_elems=max_elems, seed=seed)
        planes_s = model_planes(m, cols=COLS, sort=True, max_elems=max_elems, seed=seed)
        t_u = int(cost.chain_transitions(planes_u))
        t_s = int(cost.chain_transitions(planes_s))
        results[m] = {
            "n_sections": int(planes_u.shape[0]),
            "transitions_unsorted": t_u,
            "transitions_sws": t_s,
            "speedup": t_u / max(t_s, 1),
        }
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    banner("Fig. 5 — SWS single-crossbar (128x16) speedup")
    res = run(max_elems=0 if args.full else 2_000_000, seed=args.seed)
    for m, r in res.items():
        print(f"  {m:18s} sections={r['n_sections']:7d}  speedup={r['speedup']:.2f}x")
    save_json("fig5_sws_single", res)
    paper = {"deit-tiny": 1.47, "vgg16": 1.87}
    for m, want in paper.items():
        got = res[m]["speedup"]
        print(f"  [paper check] {m}: paper={want:.2f}x ours={got:.2f}x")


if __name__ == "__main__":
    main()
