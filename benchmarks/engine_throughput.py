"""Continuous batching vs static lockstep batching under a Poisson trace.

Serves one heterogeneous request trace (prompt lengths, generation lengths,
and Poisson arrival times all drawn per request) two ways:

  * ``static``  — the PR3-era lockstep server: requests are grouped into
    fixed-size batches in arrival order, prompts padded to one static shape,
    and decode runs until the *longest* request in the batch finishes — a
    retired sequence burns compute until the batch drains, and the batch
    cannot start until its last member arrives.
  * ``engine``  — ``launch.engine.Engine``: paged KV cache, chunked prefill,
    and mid-flight admission into freed slots; decode advances all live
    slots in per-slot-masked quanta.

Both servers are pre-warmed (the engine via ``Engine.prewarm`` — every
bucketed variant compiled up front; the static server one dummy batch per
generation bucket) so the wall-clock comparison measures steady-state
serving.  Reported:
useful tok/s (only each request's own ``max_new_tokens`` count) and p50/p95
request latency (finish − arrival).

  PYTHONPATH=src python -m benchmarks.engine_throughput [--quick] [--check]

Writes experiments/bench/BENCH_engine.json.  ``--check`` exits non-zero if
the engine's tok/s falls below the static baseline at equal load (the CI
regression gate).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, save_json
from repro.configs import get_arch
from repro.launch import steps
from repro.launch.engine import Engine, EngineConfig, Request, _bucket
from repro.models import api


def make_trace(
    cfg, n_requests: int, *, min_prompt=4, max_prompt=48, min_gen=2, max_gen=32,
    rate: float = 500.0, seed: int = 0,
) -> list[Request]:
    """Heterogeneous Poisson trace: iid prompt lengths, heavy-tailed
    generation lengths, exponential inter-arrival gaps at ``rate``
    requests/second.

    Generation lengths are a short/long mixture (75% short around
    ``min_gen``, 25% long near ``max_gen``) — the shape of production
    serving traffic, and the regime lockstep batching handles worst: one
    long request in a batch drains every slot for its whole tail.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        if rng.random() < 0.75:
            gen = int(rng.integers(min_gen, min(min_gen + 7, max_gen) + 1))
        else:
            gen = int(rng.integers(max(max_gen // 2, min_gen), max_gen + 1))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        reqs.append(
            Request(
                rid=i, prompt=prompt, max_new_tokens=gen, greedy=True,
                seed=i, arrival_time=float(arrivals[i]),
            )
        )
    return reqs


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


class StaticServer:
    """Fixed-shape lockstep batching baseline.

    One compiled (prefill, decode-loop) pair per generation-length bucket;
    prompts are padded to the global ``max_prompt`` and decode always runs
    the bucketed batch-max generation length — the whole batch drains before
    the next one starts (exactly the ``launch.serve.generate`` shape
    discipline, amortized across a trace).
    """

    def __init__(self, cfg, params, batch_size: int, max_prompt: int, max_gen: int):
        self.cfg = cfg
        self.params = steps.prepare_serving_params(params)
        self.batch_size = batch_size
        self.max_prompt = max_prompt
        self.max_gen = max_gen
        self.prefill = jax.jit(steps.make_prefill_step(cfg))
        donate = steps.cache_donation()
        self._loops = {}
        self._donate = donate

    def _loop(self, gen_bucket: int):
        if gen_bucket not in self._loops:
            self._loops[gen_bucket] = jax.jit(
                steps.make_decode_loop(self.cfg, gen_bucket - 1),
                donate_argnums=self._donate,
            )
        return self._loops[gen_bucket]

    def serve_batch(self, reqs: list[Request]) -> np.ndarray:
        """(B, gen_bucket) tokens; rows beyond each request's own gen are
        drained lockstep waste."""
        b = len(reqs)
        gen_bucket = _bucket(max(r.max_new_tokens for r in reqs), self.max_gen)
        tokens = np.zeros((self.batch_size, self.max_prompt), np.int32)
        for i, r in enumerate(reqs):
            tokens[i, : r.prompt.size] = r.prompt  # right-padded static shape
        batch = {"tokens": jnp.asarray(tokens)}
        logits, pf_cache = self.prefill(self.params, batch)
        cache = api.init_cache(self.cfg, self.batch_size, self.max_prompt + gen_bucket)
        cache = api.merge_prefill_cache(self.cfg, cache, pf_cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        key = jax.random.PRNGKey(0)
        toks, _ = self._loop(gen_bucket)(
            self.params, cache, tok, key, jnp.int32(self.max_prompt)
        )
        out = np.concatenate([np.asarray(tok), np.asarray(toks)], axis=1)
        jax.block_until_ready(toks)
        return out[:b]

    def warmup(self, gen_buckets: set[int]) -> None:
        dummy = [
            Request(rid=-1, prompt=np.zeros(4, np.int32), max_new_tokens=g)
            for g in sorted(gen_buckets)
        ]
        for d in dummy:
            self.serve_batch([d])

    def run(self, reqs: list[Request]) -> dict:
        t0 = time.perf_counter()
        latencies, useful = [], 0
        for lo in range(0, len(reqs), self.batch_size):
            group = reqs[lo : lo + self.batch_size]
            now = time.perf_counter() - t0
            last = max(r.arrival_time for r in group)
            if last > now:  # lockstep: the batch waits for its last member
                time.sleep(last - now)
            self.serve_batch(group)
            done = time.perf_counter() - t0
            for r in group:
                latencies.append(done - r.arrival_time)
                useful += r.max_new_tokens
        wall = time.perf_counter() - t0
        return {
            "tok_s": useful / wall,
            "wall_s": wall,
            "p50_latency_ms": 1e3 * _pct(latencies, 50),
            "p95_latency_ms": 1e3 * _pct(latencies, 95),
            "n_batches": -(-len(reqs) // self.batch_size),
        }


def _retrace(trace: list[Request], tag: int) -> list[Request]:
    """Fresh Request objects (distinct rids) for a repeat pass."""
    return [
        Request(
            rid=tag * 10_000 + r.rid, prompt=r.prompt,
            max_new_tokens=r.max_new_tokens, greedy=r.greedy, seed=r.seed,
            arrival_time=r.arrival_time,
        )
        for r in trace
    ]


def run(
    arch: str = "gemma-2b",
    *,
    reduced: bool = True,
    n_requests: int = 64,
    max_slots: int = 8,
    min_prompt: int = 4,
    max_prompt: int = 16,
    min_gen: int = 2,
    max_gen: int = 128,
    rate: float = 500.0,
    page_size: int = 16,
    prefill_chunk: int = 16,
    decode_quantum: int = 16,
    passes: int = 3,
    seed: int = 0,
) -> dict:
    """The default trace is chat-shaped: short prompts (4..16) and
    heavy-tailed generations (75% short, tail to ``max_gen``) — the regime
    where lockstep drain waste dominates: a static batch decodes its *max*
    generation length for every row, so one tail request holds all slots
    hostage.  ``passes``: both servers serve the trace best-of-N (single
    passes on a reduced model are tens of milliseconds and swing with
    scheduler noise, cf. serving_throughput)."""
    cfg = get_arch(arch, reduced=reduced)
    params = api.init(jax.random.PRNGKey(seed), cfg)
    trace = make_trace(
        cfg, n_requests, min_prompt=min_prompt, max_prompt=max_prompt,
        min_gen=min_gen, max_gen=max_gen, rate=rate, seed=seed,
    )

    # --- pre-warm both servers (every jit variant compiled untimed) ---
    static = StaticServer(cfg, params, max_slots, max_prompt, max_gen)
    buckets = set()
    for lo in range(0, len(trace), max_slots):
        group = trace[lo : lo + max_slots]
        buckets.add(_bucket(max(r.max_new_tokens for r in group), max_gen))
    static.warmup(buckets)
    ecfg = EngineConfig(
        max_slots=max_slots, page_size=page_size,
        max_seq_len=max_prompt + max_gen, prefill_chunk=prefill_chunk,
        decode_quantum=decode_quantum,
    )
    eng = Engine(cfg, params, ecfg)
    eng.prewarm()

    # --- timed passes, interleaved so both servers sample the same machine
    # conditions (the reduced model serves a trace in ~100 ms; background
    # load drifting between two separate measurement phases would skew the
    # ratio more than anything either server does) ---
    rs, re = None, None
    for p in range(passes):
        cand = static.run(_retrace(trace, 100 + p))
        if rs is None or cand["wall_s"] < rs["wall_s"]:
            rs = cand
        stats0 = dict(eng.stats)
        t0 = time.perf_counter()
        results = eng.run(_retrace(trace, p))
        wall = time.perf_counter() - t0
        useful = sum(len(r.tokens) for r in results)
        lat = [r.latency for r in results]
        cand = {
            "tok_s": useful / wall,
            "wall_s": wall,
            "p50_latency_ms": 1e3 * _pct(lat, 50),
            "p95_latency_ms": 1e3 * _pct(lat, 95),
            # per-PASS deltas (the engine accumulates stats across passes)
            "decode_dispatches": eng.stats["decode_dispatches"] - stats0["decode_dispatches"],
            "prefill_dispatches": eng.stats["prefill_dispatches"] - stats0["prefill_dispatches"],
            "tokens_overrun": eng.stats["tokens_overrun"] - stats0["tokens_overrun"],
        }
        if re is None or cand["wall_s"] < re["wall_s"]:
            re = cand
    re["compiled_variants"] = len(eng._shapes_seen)

    return {
        "arch": arch,
        "reduced": reduced,
        "backend": jax.default_backend(),
        "trace": {
            "n_requests": n_requests, "rate_req_s": rate,
            "prompt_len": [min_prompt, max_prompt], "gen_len": [min_gen, max_gen],
            "total_tokens": sum(r.max_new_tokens for r in trace),
        },
        "max_slots": max_slots,
        "engine_config": {
            "page_size": page_size, "prefill_chunk": prefill_chunk,
            "decode_quantum": decode_quantum,
        },
        "static": rs,
        "engine": re,
        "speedup_tok_s": re["tok_s"] / max(rs["tok_s"], 1e-9),
        "p50_latency_ratio": rs["p50_latency_ms"] / max(re["p50_latency_ms"], 1e-9),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--full-size", action="store_true", help="no --reduced config")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--rate", type=float, default=500.0)
    ap.add_argument("--quick", action="store_true", help="CI smoke shapes")
    ap.add_argument(
        "--check", action="store_true",
        help="exit non-zero if engine tok/s regresses below the static "
             "baseline at equal load (CI gate)",
    )
    ap.add_argument(
        "--check-threshold", type=float, default=0.9,
        help="minimum engine/static tok/s ratio for --check; the default "
             "leaves a 10%% noise margin for shared CI runners (quick-mode "
             "passes are ~100 ms of wall time)",
    )
    args = ap.parse_args()

    kw = dict(n_requests=args.requests, max_slots=args.slots, rate=args.rate)
    if args.quick:
        kw = dict(
            n_requests=24, max_slots=4, rate=1000.0,
            max_prompt=12, max_gen=64, prefill_chunk=16, decode_quantum=8,
            passes=2,
        )

    banner("Engine throughput — continuous batching vs static lockstep")
    res = run(args.arch, reduced=not args.full_size, **kw)
    for name in ("static", "engine"):
        r = res[name]
        print(
            f"  {name:8s} {r['tok_s']:9.1f} tok/s   "
            f"p50 {r['p50_latency_ms']:8.1f} ms   p95 {r['p95_latency_ms']:8.1f} ms"
        )
    print(f"  speedup: {res['speedup_tok_s']:.2f}x tok/s, "
          f"{res['p50_latency_ratio']:.2f}x lower p50 latency "
          f"({res['engine']['compiled_variants']} compiled engine variants)")
    save_json("BENCH_engine", res)
    if args.check and res["speedup_tok_s"] < args.check_threshold:
        print(
            f"  CHECK FAILED: engine/static tok/s {res['speedup_tok_s']:.2f} "
            f"< {args.check_threshold}",
            file=sys.stderr,
        )
        sys.exit(1)


if __name__ == "__main__":
    main()
