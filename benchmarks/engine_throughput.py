"""Continuous batching vs static lockstep — and fused vs split dispatch.

Serves one heterogeneous request trace (prompt lengths, generation lengths,
and Poisson arrival times all drawn per request) three ways:

  * ``static``       — the PR3-era lockstep server: requests are grouped
    into fixed-size batches in arrival order, prompts padded to one static
    shape, and decode runs until the *longest* request in the batch
    finishes — a retired sequence burns compute until the batch drains, and
    the batch cannot start until its last member arrives.
  * ``engine_split`` — ``launch.engine.Engine(fused=False)``: paged KV
    cache, chunked prefill, and mid-flight admission into freed slots, with
    prefill and decode dispatched *separately* each cycle (the PR4
    discipline).
  * ``engine``       — the fused engine (``fused=True``): prefill chunks
    and decode quanta ride ONE bucketed dispatch per cycle, and a row
    finishing its prompt mid-batch rolls straight into decode in-graph.

All three servers are pre-warmed (the engines via one untimed trace pass —
compiling exactly the bucketed variants the trace exercises; the static
server one dummy batch per generation bucket) and the timed passes
interleave so every server samples the same machine conditions.  Reported:
useful tok/s (only each request's own ``max_new_tokens`` count) and
p50/p95 request latency (finish − arrival).

A second, *over-committed* scenario shrinks the pool until even a single
request's old reserve-up-front admission footprint (prompt + max_new +
quantum) exceeds the usable blocks — the PR4 engine raised "scheduler
stalled" on this trace; lazy allocation + block-pressure preemption now
admit and complete it (``overcommit`` fields in the JSON).

  PYTHONPATH=src python -m benchmarks.engine_throughput [--quick] [--check]

Writes experiments/bench/BENCH_engine.json (schema: docs/benchmarks.md).
``--check`` exits non-zero if (a) fused tok/s falls below the static
baseline, (b) fused falls below split at equal load, or (c) the
over-committed trace fails to complete with preemptions — the CI
regression gates.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, save_json
from repro.configs import get_arch
from repro.launch import steps
from repro.launch.engine import Engine, EngineConfig, Request, _bucket
from repro.models import api


def make_trace(
    cfg, n_requests: int, *, min_prompt=4, max_prompt=48, min_gen=2, max_gen=32,
    rate: float = 500.0, seed: int = 0,
) -> list[Request]:
    """Heterogeneous Poisson trace: iid prompt lengths, heavy-tailed
    generation lengths, exponential inter-arrival gaps at ``rate``
    requests/second.

    Generation lengths are a short/long mixture (75% short around
    ``min_gen``, 25% long near ``max_gen``) — the shape of production
    serving traffic, and the regime lockstep batching handles worst: one
    long request in a batch drains every slot for its whole tail.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        if rng.random() < 0.75:
            gen = int(rng.integers(min_gen, min(min_gen + 7, max_gen) + 1))
        else:
            gen = int(rng.integers(max(max_gen // 2, min_gen), max_gen + 1))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        reqs.append(
            Request(
                rid=i, prompt=prompt, max_new_tokens=gen, greedy=True,
                seed=i, arrival_time=float(arrivals[i]),
            )
        )
    return reqs


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


class StaticServer:
    """Fixed-shape lockstep batching baseline.

    One compiled (prefill, decode-loop) pair per generation-length bucket;
    prompts are padded to the global ``max_prompt`` and decode always runs
    the bucketed batch-max generation length — the whole batch drains before
    the next one starts (exactly the ``launch.serve.generate`` shape
    discipline, amortized across a trace).
    """

    def __init__(self, cfg, params, batch_size: int, max_prompt: int, max_gen: int):
        self.cfg = cfg
        self.params = steps.prepare_serving_params(params)
        self.batch_size = batch_size
        self.max_prompt = max_prompt
        self.max_gen = max_gen
        self.prefill = jax.jit(steps.make_prefill_step(cfg))
        donate = steps.cache_donation()
        self._loops = {}
        self._donate = donate

    def _loop(self, gen_bucket: int):
        if gen_bucket not in self._loops:
            self._loops[gen_bucket] = jax.jit(
                steps.make_decode_loop(self.cfg, gen_bucket - 1),
                donate_argnums=self._donate,
            )
        return self._loops[gen_bucket]

    def serve_batch(self, reqs: list[Request]) -> np.ndarray:
        """(B, gen_bucket) tokens; rows beyond each request's own gen are
        drained lockstep waste."""
        b = len(reqs)
        gen_bucket = _bucket(max(r.max_new_tokens for r in reqs), self.max_gen)
        tokens = np.zeros((self.batch_size, self.max_prompt), np.int32)
        for i, r in enumerate(reqs):
            tokens[i, : r.prompt.size] = r.prompt  # right-padded static shape
        batch = {"tokens": jnp.asarray(tokens)}
        logits, pf_cache = self.prefill(self.params, batch)
        cache = api.init_cache(self.cfg, self.batch_size, self.max_prompt + gen_bucket)
        cache = api.merge_prefill_cache(self.cfg, cache, pf_cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        key = jax.random.PRNGKey(0)
        toks, _ = self._loop(gen_bucket)(
            self.params, cache, tok, key, jnp.int32(self.max_prompt)
        )
        out = np.concatenate([np.asarray(tok), np.asarray(toks)], axis=1)
        jax.block_until_ready(toks)
        return out[:b]

    def warmup(self, gen_buckets: set[int]) -> None:
        dummy = [
            Request(rid=-1, prompt=np.zeros(4, np.int32), max_new_tokens=g)
            for g in sorted(gen_buckets)
        ]
        for d in dummy:
            self.serve_batch([d])

    def run(self, reqs: list[Request]) -> dict:
        t0 = time.perf_counter()
        latencies, useful = [], 0
        for lo in range(0, len(reqs), self.batch_size):
            group = reqs[lo : lo + self.batch_size]
            now = time.perf_counter() - t0
            last = max(r.arrival_time for r in group)
            if last > now:  # lockstep: the batch waits for its last member
                time.sleep(last - now)
            self.serve_batch(group)
            done = time.perf_counter() - t0
            for r in group:
                latencies.append(done - r.arrival_time)
                useful += r.max_new_tokens
        wall = time.perf_counter() - t0
        return {
            "tok_s": useful / wall,
            "wall_s": wall,
            "p50_latency_ms": 1e3 * _pct(latencies, 50),
            "p95_latency_ms": 1e3 * _pct(latencies, 95),
            "n_batches": -(-len(reqs) // self.batch_size),
        }


def _retrace(trace: list[Request], tag: int) -> list[Request]:
    """Fresh Request objects (distinct rids) for a repeat pass."""
    return [
        Request(
            rid=tag * 10_000 + r.rid, prompt=r.prompt,
            max_new_tokens=r.max_new_tokens, greedy=r.greedy, seed=r.seed,
            arrival_time=r.arrival_time,
        )
        for r in trace
    ]


def _engine_pass(eng: Engine, trace: list[Request], tag: int) -> dict:
    """One timed trace through an engine; per-PASS stat deltas (the engine
    accumulates stats across passes)."""
    stats0 = dict(eng.stats)
    t0 = time.perf_counter()
    results = eng.run(_retrace(trace, tag))
    wall = time.perf_counter() - t0
    useful = sum(len(r.tokens) for r in results)
    lat = [r.latency for r in results]
    return {
        "tok_s": useful / wall,
        "wall_s": wall,
        "p50_latency_ms": 1e3 * _pct(lat, 50),
        "p95_latency_ms": 1e3 * _pct(lat, 95),
        "decode_dispatches": eng.stats["decode_dispatches"] - stats0["decode_dispatches"],
        "prefill_dispatches": eng.stats["prefill_dispatches"] - stats0["prefill_dispatches"],
        "fused_dispatches": eng.stats["fused_dispatches"] - stats0["fused_dispatches"],
        "tokens_overrun": eng.stats["tokens_overrun"] - stats0["tokens_overrun"],
    }


def run_overcommit(
    cfg, params, *, n_requests: int = 6, max_slots: int = 4, page_size: int = 16,
    prompt_len: int = 25, max_new: int = 56, prefill_chunk: int = 16,
    decode_quantum: int = 16, preempt: str = "swap", seed: int = 0,
) -> dict:
    """Burst trace against a pool sized so the OLD reserve-up-front policy
    could not admit even one request: usable blocks = ceil((prompt +
    max_new - 1) / page) — exactly one request's true footprint — while the
    old admission reserved prompt + max_new + quantum.  Lazy allocation +
    preemption admit the burst and complete it; the JSON records both the
    completion and the counterfactual ("reserve_policy_admissible").

    The default shape puts prompt + max_new - 1 exactly on a page boundary
    (80 = 5 pages of 16), so the reserve policy's +quantum overhang always
    crosses into a sixth page the pool doesn't have — for any quantum and
    for page sizes 8/16 alike."""
    rng = np.random.default_rng(seed)
    reqs = [
        Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32),
            max_new_tokens=max_new, greedy=True, seed=i, arrival_time=0.0,
        )
        for i in range(n_requests)
    ]
    true_pages = -(-(prompt_len + max_new - 1) // page_size)
    reserve_pages = -(-(prompt_len + max_new + decode_quantum) // page_size)
    ecfg = EngineConfig(
        max_slots=max_slots, page_size=page_size,
        max_seq_len=prompt_len + max_new, prefill_chunk=prefill_chunk,
        decode_quantum=decode_quantum, num_blocks=1 + true_pages,
        fused=True, preempt=preempt,
    )
    eng = Engine(cfg, params, ecfg)
    t0 = time.perf_counter()
    results = eng.run(reqs)
    wall = time.perf_counter() - t0
    return {
        "n_requests": n_requests,
        "max_slots": max_slots,
        "usable_blocks": eng.pcfg.usable_blocks,
        "blocks_per_request_true": true_pages,
        "blocks_per_request_reserve_policy": reserve_pages,
        # the PR4 engine admission required reserve_pages free blocks and
        # raised "scheduler stalled" otherwise — this trace was unservable
        "reserve_policy_admissible": reserve_pages <= eng.pcfg.usable_blocks,
        "completed": sum(len(r.tokens) == max_new for r in results),
        "tok_s": sum(len(r.tokens) for r in results) / wall,
        "wall_s": wall,
        "preempt_mode": preempt,
        "preemptions": eng.stats["preemptions"],
        "swap_ins": eng.stats["swap_ins"],
        "readmissions": eng.stats["readmissions"],
    }


def run(
    arch: str = "gemma-2b",
    *,
    reduced: bool = True,
    n_requests: int = 64,
    max_slots: int = 8,
    min_prompt: int = 4,
    max_prompt: int = 16,
    min_gen: int = 2,
    max_gen: int = 128,
    rate: float = 500.0,
    page_size: int = 16,
    prefill_chunk: int = 16,
    decode_quantum: int = 16,
    passes: int = 5,
    seed: int = 0,
    overcommit: bool = True,
) -> dict:
    """The default trace is chat-shaped: short prompts (4..16) and
    heavy-tailed generations (75% short, tail to ``max_gen``) — the regime
    where lockstep drain waste dominates (a static batch decodes its *max*
    generation length for every row) and where split dispatching leaves
    decode slots idle during every prefill cycle.  ``passes``: all three
    servers serve the trace best-of-N, interleaved (single passes on a
    reduced model are tens of milliseconds and swing with scheduler noise,
    cf. serving_throughput)."""
    cfg = get_arch(arch, reduced=reduced)
    params = api.init(jax.random.PRNGKey(seed), cfg)
    trace = make_trace(
        cfg, n_requests, min_prompt=min_prompt, max_prompt=max_prompt,
        min_gen=min_gen, max_gen=max_gen, rate=rate, seed=seed,
    )

    # --- pre-warm all three servers (every jit variant compiled untimed) ---
    static = StaticServer(cfg, params, max_slots, max_prompt, max_gen)
    buckets = set()
    for lo in range(0, len(trace), max_slots):
        group = trace[lo : lo + max_slots]
        buckets.add(_bucket(max(r.max_new_tokens for r in group), max_gen))
    static.warmup(buckets)
    ekw = dict(
        max_slots=max_slots, page_size=page_size,
        max_seq_len=max_prompt + max_gen, prefill_chunk=prefill_chunk,
        decode_quantum=decode_quantum,
    )
    # engines warm with two untimed trace passes: they compile exactly the
    # bucketed variants this trace exercises (Engine.prewarm compiles the
    # FULL grid — minutes of XLA time the timed comparison doesn't need).
    # Two passes, because wall-clock arrival jitter shifts which shapes a
    # pass hits — a second warm pass catches most of the tail, and
    # best-of-N absorbs any variant still first seen inside a timed pass.
    eng_split = Engine(cfg, params, EngineConfig(fused=False, **ekw))
    eng_fused = Engine(cfg, params, EngineConfig(fused=True, **ekw))
    for w in range(2):
        eng_split.run(_retrace(trace, 900 + w))
        eng_fused.run(_retrace(trace, 910 + w))

    # --- timed passes, interleaved so all servers sample the same machine
    # conditions (the reduced model serves a trace in ~100 ms; background
    # load drifting between separate measurement phases would skew the
    # ratios more than anything any server does) ---
    rs, rsp, re = None, None, None
    for p in range(passes):
        cand = static.run(_retrace(trace, 100 + p))
        if rs is None or cand["wall_s"] < rs["wall_s"]:
            rs = cand
        cand = _engine_pass(eng_split, trace, 200 + p)
        if rsp is None or cand["wall_s"] < rsp["wall_s"]:
            rsp = cand
        cand = _engine_pass(eng_fused, trace, p)
        if re is None or cand["wall_s"] < re["wall_s"]:
            re = cand
    re["compiled_variants"] = len(eng_fused._shapes_seen)
    rsp["compiled_variants"] = len(eng_split._shapes_seen)

    res = {
        "arch": arch,
        "reduced": reduced,
        "backend": jax.default_backend(),
        "trace": {
            "n_requests": n_requests, "rate_req_s": rate,
            "prompt_len": [min_prompt, max_prompt], "gen_len": [min_gen, max_gen],
            "total_tokens": sum(r.max_new_tokens for r in trace),
        },
        "max_slots": max_slots,
        "engine_config": {
            "page_size": page_size, "prefill_chunk": prefill_chunk,
            "decode_quantum": decode_quantum,
        },
        "static": rs,
        "engine_split": rsp,
        "engine": re,
        "speedup_tok_s": re["tok_s"] / max(rs["tok_s"], 1e-9),
        "fused_vs_split_tok_s": re["tok_s"] / max(rsp["tok_s"], 1e-9),
        "p50_latency_ratio": rs["p50_latency_ms"] / max(re["p50_latency_ms"], 1e-9),
    }
    if overcommit:
        res["overcommit"] = run_overcommit(
            cfg, params, max_slots=min(max_slots, 4), page_size=page_size,
            prefill_chunk=prefill_chunk, decode_quantum=decode_quantum,
        )
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--full-size", action="store_true", help="no --reduced config")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--rate", type=float, default=500.0)
    ap.add_argument("--quick", action="store_true", help="CI smoke shapes")
    ap.add_argument(
        "--check", action="store_true",
        help="exit non-zero if the fused engine regresses below the static "
             "baseline or the split engine at equal load, or the "
             "over-committed trace fails to complete (CI gates)",
    )
    ap.add_argument(
        "--check-threshold", type=float, default=0.9,
        help="minimum engine/static and fused/split tok/s ratios for "
             "--check; the default leaves a 10%% noise margin for shared CI "
             "runners (quick-mode passes are ~100 ms of wall time)",
    )
    args = ap.parse_args()

    kw = dict(n_requests=args.requests, max_slots=args.slots, rate=args.rate)
    if args.quick:
        # 48 requests / 4 passes: a 24-request trace serves in ~60 ms and
        # the engine/static ratio swings ±25% with runner load — the gate
        # needs a trace long enough that scheduling wins dominate the noise
        kw = dict(
            n_requests=48, max_slots=4, rate=1000.0,
            max_prompt=12, max_gen=64, prefill_chunk=16, decode_quantum=8,
            passes=4,
        )

    banner("Engine throughput — fused vs split vs static lockstep")
    res = run(args.arch, reduced=not args.full_size, **kw)
    for name in ("static", "engine_split", "engine"):
        r = res[name]
        print(
            f"  {name:12s} {r['tok_s']:9.1f} tok/s   "
            f"p50 {r['p50_latency_ms']:8.1f} ms   p95 {r['p95_latency_ms']:8.1f} ms"
        )
    print(f"  fused vs static: {res['speedup_tok_s']:.2f}x tok/s, "
          f"{res['p50_latency_ratio']:.2f}x lower p50 latency; "
          f"fused vs split: {res['fused_vs_split_tok_s']:.2f}x "
          f"({res['engine']['compiled_variants']} compiled fused-engine variants)")
    oc = res.get("overcommit")
    if oc:
        print(f"  overcommit: {oc['completed']}/{oc['n_requests']} completed on "
              f"{oc['usable_blocks']} blocks "
              f"({oc['blocks_per_request_true']}/request true, "
              f"{oc['blocks_per_request_reserve_policy']}/request old reserve policy"
              f"{' — previously unadmittable' if not oc['reserve_policy_admissible'] else ''}), "
              f"{oc['preemptions']} preemptions, {oc['swap_ins']} swap-ins")
    save_json("BENCH_engine", res)
    if args.check:
        failures = []
        if res["speedup_tok_s"] < args.check_threshold:
            failures.append(
                f"engine/static tok/s {res['speedup_tok_s']:.2f} < {args.check_threshold}"
            )
        if res["fused_vs_split_tok_s"] < args.check_threshold:
            failures.append(
                f"fused/split tok/s {res['fused_vs_split_tok_s']:.2f} < {args.check_threshold}"
            )
        if oc and (oc["completed"] < oc["n_requests"] or oc["preemptions"] < 1):
            failures.append(
                f"overcommit incomplete: {oc['completed']}/{oc['n_requests']} "
                f"with {oc['preemptions']} preemptions"
            )
        if failures:
            for f in failures:
                print(f"  CHECK FAILED: {f}", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
