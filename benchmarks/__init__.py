"""Benchmark harness: one module per paper table/figure + roofline.

Run everything:  PYTHONPATH=src python -m benchmarks.run
Run one figure:  PYTHONPATH=src python -m benchmarks.fig5_sws_single
"""
