"""Run the full benchmark suite: every paper figure + accuracy + roofline.

  PYTHONPATH=src python -m benchmarks.run [--full]

Writes JSON artifacts to experiments/bench/ and prints each figure's
summary.  --full removes the per-tensor element cap (slower, exact).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    accuracy_e2e,
    engine_throughput,
    fault_tolerance,
    fig5_sws_single,
    fig6_strides,
    fig7_greedy,
    fig8_stucking,
    fig9_p_sweep,
    fig10_columns,
    fleet_tolerance,
    integrity_scrub,
    plane_compression,
    planner_throughput,
    pool_wear,
    redeploy_delta,
    roofline,
    serving_throughput,
)
from benchmarks.common import banner, save_json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    max_elems = 0 if args.full else 2_000_000

    t0 = time.time()
    summary = {}

    banner("Fig. 5 — SWS single crossbar")
    r5 = fig5_sws_single.run(max_elems=max_elems)
    for m, r in r5.items():
        print(f"  {m:18s} speedup={r['speedup']:.2f}x")
    save_json("fig5_sws_single", r5)
    summary["fig5"] = {m: r["speedup"] for m, r in r5.items()}

    banner("Fig. 6 — stride-L vs stride-1")
    r6 = fig6_strides.run(max_elems=max_elems)
    for m, r in r6.items():
        ls = "  ".join(f"L={l}:{v['speedup']:.2f}x" for l, v in r["strideL"].items())
        print(f"  {m:10s} {ls}  stride1:{r['stride1']['speedup']:.2f}x")
    save_json("fig6_strides", r6)
    summary["fig6"] = {
        m: {"stride1": r["stride1"]["speedup"], "strideL4": r["strideL"]["4"]["speedup"]}
        for m, r in r6.items()
    }

    banner("Fig. 7 — greedy thread balancing (64 threads)")
    r7 = fig7_greedy.run(max_elems=max_elems)
    for m, r in r7.items():
        print(f"  {m:12s} unsorted={r['speedup_unsorted']:5.1f}x  greedy={r['speedup_greedy']:5.1f}x")
    save_json("fig7_greedy", r7)
    summary["fig7"] = {m: r["speedup_greedy"] for m, r in r7.items()}

    banner("Fig. 8 — bit stucking p=0.5")
    r8 = fig8_stucking.run(max_elems=max_elems)
    for m, r in r8.items():
        print(f"  {m:12s} saves {r['speedup_pct']:5.1f}%")
    save_json("fig8_stucking", r8)
    summary["fig8"] = {m: r["speedup_pct"] for m, r in r8.items()}

    banner("Fig. 9 — p sweep")
    r9 = fig9_p_sweep.run(max_elems=max_elems)
    for m, r in r9["transitions"].items():
        sp = "  ".join(f"p={p}:{v:.2f}x" for p, v in r["speedup_vs_p1"].items())
        print(f"  {m:10s} {sp}")
    for p, r in r9["accuracy"]["per_p"].items():
        print(f"    p={p}: acc drop {r['drop_pct']:+.2f}%  speedup {r['total_speedup']:.2f}x")
    save_json("fig9_p_sweep", r9)
    summary["fig9_acc_drop_at_p0"] = r9["accuracy"]["per_p"]["0.0"]["drop_pct"]

    banner("Fig. 10 — column sweep")
    r10 = fig10_columns.run(max_elems=max_elems)
    for c, r in r10["accuracy"]["per_cols"].items():
        print(f"    cols={c:>2s}: acc drop {r['drop_pct']:+.2f}%")
    save_json("fig10_columns", r10)
    summary["fig10_acc_drop_at_10cols"] = r10["accuracy"]["per_cols"]["10"]["drop_pct"]

    banner("Accuracy preservation e2e (headline operating point)")
    racc = accuracy_e2e.run()
    print(f"  acc drop {racc['accuracy_drop_pct']:+.2f}%  total speedup {racc['total_speedup']:.2f}x")
    save_json("accuracy_e2e", racc)
    summary["accuracy_e2e"] = {
        "drop_pct": racc["accuracy_drop_pct"],
        "total_speedup": racc["total_speedup"],
    }

    banner("Planner throughput — packed fast path vs seed bool path")
    rpt = planner_throughput.run(
        max_elems=2_000_000 if args.full else 750_000,
        layers=None if args.full else 6,
    )
    print(
        f"  {rpt['arch']} x{rpt['layers']} layers ({rpt['n_elements']/1e6:.1f}M weights): "
        f"packed {rpt['time_packed_s']:.1f}s vs bool {rpt['time_bool_s']:.1f}s "
        f"-> {rpt['speedup']:.2f}x  bit_exact={rpt['bit_exact']}"
    )
    save_json("BENCH_planner", rpt)
    summary["planner_throughput"] = {
        "speedup": rpt["speedup"],
        "bit_exact": rpt["bit_exact"],
    }

    banner("Plane codecs — reprogramming transitions + weight traffic")
    rpc = plane_compression.run(max_elems=max_elems, gen=4 if not args.full else 8)
    for m, r in rpc["models"].items():
        for codec, c in r["codecs"].items():
            print(f"  {m:10s} {codec:12s} {c['transition_reduction_vs_raw']:.2f}x "
                  f"transitions, {c['compression_vs_raw']:.2f}x bytes vs raw")
    parity = all(
        r["tokens_match_dense"] for r in rpc["serving"]["codecs"].values()
    )
    print(f"  best transition reduction {rpc['best_transition_reduction']:.2f}x, "
          f"serve token parity: {parity}")
    save_json("BENCH_compress", rpc)
    summary["plane_compression"] = {
        "best_transition_reduction": rpc["best_transition_reduction"],
        "serve_token_parity": parity,
    }

    banner("Pool wear — persistent crossbar pool + wear leveling")
    rpool = pool_wear.run(deployments=3 if not args.full else 6)
    for lev, s in rpool["levelings"].items():
        print(f"  {lev:7s} max_cell={s['max_cell_writes']:8d}  "
              f"imbalance={s['crossbar_imbalance']:.3f}  "
              f"horizon={s['exhaustion_horizon_deployments']:.3g} deployments")
    print(f"  LPT leveling reduces max-cell wear "
          f"{rpool['max_wear_reduction_lpt_vs_none']:.2f}x")
    save_json("BENCH_pool", rpool)
    summary["pool_wear"] = {
        "max_wear_reduction_lpt_vs_none": rpool["max_wear_reduction_lpt_vs_none"],
        "max_cell_writes_lpt": rpool["levelings"]["lpt"]["max_cell_writes"],
    }

    banner("Serving throughput — fp vs cim-dense vs int8-planes vs packed")
    rserve = serving_throughput.run(
        gen=16 if not args.full else 64, batch=4 if not args.full else 8
    )
    for name, tps in rserve["tok_s"].items():
        print(f"  {name:16s} {tps:10.1f} tok/s")
    tr = rserve["weight_bytes_per_decode_step"]
    print(f"  weight traffic int8-planes/packed: {tr['int8_over_packed']:.2f}x "
          f"({tr['planes_int8']:,} -> {tr['packed']:,} B/step)")
    save_json("BENCH_serve", rserve)
    summary["serving"] = {
        "tok_s": rserve["tok_s"],
        "packed_over_int8_tok_s": rserve["packed_over_int8_tok_s"],
        "int8_over_packed_bytes": tr["int8_over_packed"],
        "token_agreement_vs_dense": rserve["token_agreement_vs_dense"],
    }

    banner("Engine throughput — fused vs split vs static lockstep")
    reng = engine_throughput.run(
        n_requests=32 if not args.full else 64,
        passes=2 if not args.full else 3,
    )
    print(f"  {'':12s} {'tok/s':>10s} {'p50 ms':>9s} {'p95 ms':>9s}")
    for name in ("static", "engine_split", "engine"):
        r = reng[name]
        print(f"  {name:12s} {r['tok_s']:10.1f} {r['p50_latency_ms']:9.1f} "
              f"{r['p95_latency_ms']:9.1f}")
    print(f"  continuous batching: {reng['speedup_tok_s']:.2f}x tok/s, "
          f"{reng['p50_latency_ratio']:.2f}x lower p50 latency; "
          f"fused vs split {reng['fused_vs_split_tok_s']:.2f}x "
          f"({reng['trace']['n_requests']} requests, "
          f"{reng['engine']['compiled_variants']} compiled variants)")
    oc = reng["overcommit"]
    print(f"  overcommit: {oc['completed']}/{oc['n_requests']} completed on "
          f"{oc['usable_blocks']} blocks, {oc['preemptions']} preemptions")
    save_json("BENCH_engine", reng)
    summary["engine"] = {
        "static_tok_s": reng["static"]["tok_s"],
        "engine_split_tok_s": reng["engine_split"]["tok_s"],
        "engine_tok_s": reng["engine"]["tok_s"],
        "speedup_tok_s": reng["speedup_tok_s"],
        "fused_vs_split_tok_s": reng["fused_vs_split_tok_s"],
        "p50_latency_ratio": reng["p50_latency_ratio"],
        "overcommit_completed": oc["completed"],
        "overcommit_preemptions": oc["preemptions"],
    }

    banner("Fault tolerance — stuck cells, fault-aware remap, hot redeploy")
    rft = fault_tolerance.run(
        rates=(0.0, 2e-3) if not args.full else (0.0, 5e-4, 2e-3, 8e-3),
        n_requests=4 if not args.full else 6,
        n_deploys=2 if not args.full else 3,
    )
    rd_ft = rft["redeploy"]
    print(f"  remapping recovery at rate {rft['ref_rate']}: "
          f"{100 * rft['recovery_at_ref']:.1f}%")
    print(f"  hot redeploy: {rd_ft['completed']}/{rd_ft['n_requests']} completed, "
          f"parity {rd_ft['stream_parity']}, "
          f"pause {rd_ft['swap_pause_s'] * 1e3:.0f} ms")
    save_json("BENCH_fault", rft)
    summary["fault"] = {
        "recovery_at_ref": rft["recovery_at_ref"],
        "redeploy_completed": rd_ft["completed"],
        "stream_parity": rd_ft["stream_parity"],
        "endurance_horizons": rft["endurance"]["horizons"],
    }

    banner("Integrity scrub — detect, repair, refresh; overhead and cost")
    ri = integrity_scrub.run(
        n_requests=3 if not args.full else 4,
        trials=2 if not args.full else 3,
        kl_rates=(1e-3,) if not args.full else (0.0, 1e-3, 4e-3),
    )
    sr_i, ov_i = ri["storm_repair"], ri["overhead"]
    print(f"  storm: {sr_i['detections']} detections, repair cost "
          f"{100 * sr_i['repair_cost_ratio']:.1f}% of full reprogram, "
          f"parity {sr_i['post_repair_parity']}")
    print(f"  scrub overhead: {100 * (1 - min(ov_i['throughput_ratio'], 1.0)):.1f}% "
          f"of serving tok/s at 1/{ov_i['scrub_every_steps']} duty cycle")
    save_json("BENCH_integrity", ri)
    summary["integrity"] = {
        "detections": sr_i["detections"],
        "repair_cost_ratio": sr_i["repair_cost_ratio"],
        "post_repair_parity": sr_i["post_repair_parity"],
        "refreshes": ri["engine_scrub"]["scrub_refreshes"],
        "throughput_ratio": ov_i["throughput_ratio"],
    }

    banner("Fleet tolerance — replica router under chaos")
    # replicas share this process's single device here; the CI smoke runs
    # the module standalone with --devices 4 for a real emulated mesh
    rfl = fleet_tolerance.run(
        counts=(1, 2) if not args.full else (1, 2, 4),
        n_requests=8 if not args.full else 16,
    )
    kt, st = rfl["kill_trace"], rfl["stall_trace"]
    print(f"  kill trace: {kt['completed']}/{kt['admitted']} completed, "
          f"parity {kt['stream_parity']}, {kt['surviving_replicas']} survivors")
    print(f"  stall trace: {st['completed']}/{st['admitted']} completed, "
          f"parity {st['stream_parity']}, {st['hedges']} hedges")
    save_json("BENCH_fleet", rfl)
    summary["fleet"] = {
        "tok_s_by_replicas": {str(r["n_replicas"]): r["tok_s"]
                              for r in rfl["scaling"]},
        "kill_completed": kt["completed"],
        "stall_completed": st["completed"],
        "stream_parity": kt["stream_parity"] and st["stream_parity"],
        "shed": rfl["admission"]["shed"],
    }

    banner("Redeploy delta (training-time integration, beyond-paper)")
    rd = redeploy_delta.run()
    for k, v in rd["tensors"].items():
        print(f"  {k}: stale-sort streaming {v['stale_sort_speedup']:.2f}x "
              f"(fresh re-sort {v['fresh_sort_speedup']:.2f}x)")
    save_json("redeploy_delta", rd)
    summary["redeploy"] = {k: v["stale_sort_speedup"] for k, v in rd["tensors"].items()}

    rroof = roofline.run()
    if rroof["rows"]:
        banner("Roofline (from dry-run artifacts)")
        n = len(rroof["rows"])
        bounds = {}
        for r in rroof["rows"]:
            bounds[r["bottleneck"]] = bounds.get(r["bottleneck"], 0) + 1
        print(f"  {n} cells; bottleneck distribution: {bounds}")
        for r in rroof["worst_roofline_fraction"]:
            print(f"  worst roofline fraction: {r['arch']} {r['shape']} {r['mesh']} "
                  f"-> {r['roofline_fraction']:.3f}")
        save_json("roofline", rroof)
        summary["roofline_cells"] = n

    banner(f"benchmarks.run complete in {time.time() - t0:.0f}s")
    save_json("summary", summary)
    print("  artifacts in experiments/bench/*.json")


if __name__ == "__main__":
    main()
