"""Plane codec compression — reprogramming transitions + weight traffic.

The codec layer (``core/planes.py``) stores the canonical packed planes in a
re-encoded physical form: ``col_perm`` re-aligns each section's bit columns
against its reprogramming predecessor (fewer cell transitions for the same
logical planes), ``const_rle`` elides constant 16-byte tiles (less payload to
move), and ``col_perm_rle`` composes both.  This benchmark quantifies both
wins on the paper's model set, through the *real* pipeline (per-layer
quantize -> SWS sort -> packed sections -> stride-1 chains), plus the
serving-side twin: per-codec deployed-operand bytes and token parity on a
reduced LM.

Writes ``experiments/bench/BENCH_compress.json``.  ``--quick`` caps model
size for CI; ``--check`` exits non-zero unless (a) every model's ``col_perm``
transition reduction is >= 1.0x vs raw (structural: identity fallback) and
(b) every codec's served token stream matches dense bit for bit.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, model_weights, save_json, weights_per_section
from repro.core import bitslice, planes, schedule, sws

COLS = 10
L_CROSSBARS = 16


def model_packed_planes(
    name: str, *, cols: int = COLS, max_elems: int = 2_000_000, seed: int = 0
) -> jax.Array:
    """Packed section planes for a whole model via the deployment pipeline
    (per-layer scale + SWS sort, layer streams concatenated in order)."""
    w_per = weights_per_section(cols)
    chunks = []
    for _, w in model_weights(name, max_elems=max_elems, seed=seed):
        w = w[sws.sws_permutation(w)]
        qt = bitslice.quantize(w, cols)
        q = jnp.pad(qt.q, (0, (-w.shape[0]) % w_per))
        chunks.append(bitslice.section_planes_packed(q, w_per, cols))
    return jnp.concatenate(chunks, axis=0)


def _transitions(phys: jax.Array, chains) -> int:
    costs = schedule.schedule_job_costs(phys, chains, include_initial=True)
    return int(np.sum(np.asarray(costs), dtype=np.int64))


def _walk_operands(tree, out: list) -> None:
    if isinstance(tree, dict):
        if "planes_packed" in tree:
            out.append(tree)
            return
        for v in tree.values():
            _walk_operands(v, out)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            _walk_operands(v, out)


def serving_traffic(codecs, *, gen: int = 4) -> dict:
    """Deployed-operand weight bytes + token parity per codec (reduced LM)."""
    from repro.configs import get_arch
    from repro.core.planner import (
        CrossbarSpec, PlannerConfig, build_deployment, deploy_params,
    )
    from repro.launch.serve import generate
    from repro.models import api

    cfg = get_arch("gemma-2b", reduced=True)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, 2, 12)
    plan = build_deployment(
        params, CrossbarSpec(rows=128, cols=COLS),
        PlannerConfig(p_stuck=1.0, min_size=1024),
    )
    toks_dense, _ = generate(cfg, deploy_params(params, plan), batch, gen_len=gen)
    out = {"arch": "gemma-2b(reduced)", "codecs": {}}
    for codec in codecs:
        p = deploy_params(params, plan, materialize="packed", codec=codec)
        ops: list = []
        _walk_operands(p, ops)
        total = {"plane_bytes": 0, "sign_bytes": 0, "meta_bytes": 0, "total_bytes": 0}
        n_weights = 0
        for op in ops:
            b = planes.operand_payload_bytes(op)
            for k in total:
                total[k] += b[k]
            pp = op["planes_packed"]
            lead = int(np.prod(pp.shape[:-3])) if pp.ndim > 3 else 1
            n_weights += lead * op["kdim"].shape[-2] * pp.shape[-1]
        toks, _ = generate(cfg, p, batch, gen_len=gen)
        out["codecs"][codec] = {
            **total,
            "n_weights": n_weights,
            "bytes_per_weight": total["total_bytes"] / max(n_weights, 1),
            "tokens_match_dense": bool(np.array_equal(toks_dense, toks)),
        }
    raw_b = out["codecs"].get("raw", {}).get("total_bytes")
    if raw_b:
        for codec, r in out["codecs"].items():
            r["traffic_reduction_vs_raw"] = raw_b / max(r["total_bytes"], 1)
    return out


def run(
    models=None,
    codecs=None,
    *,
    max_elems: int = 2_000_000,
    l_crossbars: int = L_CROSSBARS,
    seed: int = 0,
    serve: bool = True,
    gen: int = 4,
) -> dict:
    models = models or ["resnet50", "vit-base"]
    codecs = list(codecs or planes.CODECS)
    out = {
        "config": {
            "cols": COLS, "l_crossbars": l_crossbars, "schedule": "stride1",
            "max_elems": max_elems, "codecs": codecs,
        },
        "models": {},
    }
    for m in models:
        packed = model_packed_planes(m, max_elems=max_elems, seed=seed)
        chains = schedule.make_chains(packed.shape[0], l_crossbars, "stride1")
        raw_t = _transitions(packed, chains)
        entry = {"sections": int(packed.shape[0]), "codecs": {}}
        for codec in codecs:
            ps = planes.encode(packed, codec, chains=chains)
            t = _transitions(ps.physical(), chains)
            stats = ps.compression_stats()
            entry["codecs"][codec] = {
                "transitions": t,
                "transition_reduction_vs_raw": raw_t / max(t, 1),
                "payload_bytes": int(stats["payload_bytes"]),
                "meta_bytes": int(stats["meta_bytes"]),
                "total_bytes": int(stats["total_bytes"]),
                "compression_vs_raw": float(stats["ratio_vs_raw"]),
            }
        out["models"][m] = entry
    if serve:
        out["serving"] = serving_traffic(codecs, gen=gen)
    best = max(
        (r["codecs"][c]["transition_reduction_vs_raw"]
         for r in out["models"].values() for c in codecs),
        default=1.0,
    )
    out["best_transition_reduction"] = best
    return out


def check(res: dict) -> list[str]:
    """CI gate: structural floor + exact serve parity.  Returns failures."""
    fails = []
    for m, r in res["models"].items():
        for codec, c in r["codecs"].items():
            if codec.startswith("col_perm") and c["transition_reduction_vs_raw"] < 1.0:
                fails.append(
                    f"{m}/{codec}: transition reduction "
                    f"{c['transition_reduction_vs_raw']:.3f}x < 1.0x vs raw"
                )
    for codec, r in res.get("serving", {}).get("codecs", {}).items():
        if not r["tokens_match_dense"]:
            fails.append(f"serving/{codec}: token stream diverged from dense")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true", help="small CI configuration")
    ap.add_argument("--check", action="store_true", help="exit 1 on gate failure")
    args = ap.parse_args()
    if args.quick:
        kwargs = dict(models=["resnet50"], max_elems=250_000, gen=4)
    else:
        kwargs = dict(max_elems=0 if args.full else 2_000_000, gen=8)

    banner("Plane codecs — reprogramming transitions + weight traffic")
    res = run(**kwargs)
    for m, r in res["models"].items():
        for codec, c in r["codecs"].items():
            print(f"  {m:10s} {codec:12s} transitions {c['transitions']:>10,} "
                  f"({c['transition_reduction_vs_raw']:.2f}x vs raw)  "
                  f"bytes {c['total_bytes']:>9,} ({c['compression_vs_raw']:.2f}x)")
    srv = res.get("serving")
    if srv:
        for codec, r in srv["codecs"].items():
            print(f"  serve {codec:12s} {r['total_bytes']:>9,} B "
                  f"({r['bytes_per_weight']:.3f} B/weight, "
                  f"{r.get('traffic_reduction_vs_raw', 1.0):.2f}x vs raw packed)  "
                  f"tokens_match={r['tokens_match_dense']}")
    save_json("BENCH_compress", res)

    if args.check:
        fails = check(res)
        for f in fails:
            print(f"  GATE FAIL: {f}")
        if fails:
            sys.exit(1)
        print("  gates passed: col_perm reduction >= 1.0x, serve token parity")


if __name__ == "__main__":
    main()
