"""End-to-end accuracy preservation: train -> deploy -> measure (<1% drop).

The paper's bottom-line constraint at its headline operating point
(SWS stride-1, p=0.5, 128x10 crossbars): deployment must cost <1% accuracy.
Evaluated on the trained LM (exact task accuracy) plus fidelity probes.
"""
from __future__ import annotations

import argparse

from benchmarks.common import banner, save_json
from benchmarks.trained_lm import eval_accuracy, get_trained_lm
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment, deploy_params
from repro.core.simulator import logit_kl, top1_agreement
from repro.models import api


def run(*, p=0.5, rows=128, cols=10, seed=0) -> dict:
    cfg, params, batch_fn = get_trained_lm(seed=seed)
    acc_fp = eval_accuracy(cfg, params, batch_fn)

    plan = build_deployment(
        params, CrossbarSpec(rows=rows, cols=cols),
        PlannerConfig(p_stuck=p, min_size=1024, seed=seed),
    )
    params_hat = deploy_params(params, plan)
    acc_cim = eval_accuracy(cfg, params_hat, batch_fn)

    f = lambda pp, b: api.forward(pp, cfg, b)[0]
    batch = batch_fn(0)
    t = plan.totals()
    return {
        "operating_point": {"p": p, "rows": rows, "cols": cols, "schedule": "stride1"},
        "accuracy_fp": acc_fp,
        "accuracy_cim": acc_cim,
        "accuracy_drop_pct": 100.0 * (acc_fp - acc_cim),
        "top1_agreement": float(top1_agreement(f, params, params_hat, batch)),
        "logit_kl": float(logit_kl(f, params, params_hat, batch)),
        "sws_speedup": t["sws_speedup"],
        "total_speedup": t["total_speedup"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--p", type=float, default=0.5)
    ap.add_argument("--cols", type=int, default=10)
    args = ap.parse_args()

    banner("Accuracy preservation (train -> deploy -> eval)")
    res = run(p=args.p, cols=args.cols)
    print(f"  fp accuracy   : {res['accuracy_fp']:.4f}")
    print(f"  CIM accuracy  : {res['accuracy_cim']:.4f}  (drop {res['accuracy_drop_pct']:+.2f}%)")
    print(f"  top1 agreement: {res['top1_agreement']:.4f}   logit KL: {res['logit_kl']:.2e}")
    print(f"  reprog speedup: {res['total_speedup']:.2f}x (sws {res['sws_speedup']:.2f}x)")
    ok = res["accuracy_drop_pct"] < 1.0
    print(f"  [paper check] <1% accuracy drop: {'PASS' if ok else 'FAIL'}")
    save_json("accuracy_e2e", res)


if __name__ == "__main__":
    main()
