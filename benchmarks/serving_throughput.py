"""Serving throughput: fp dense vs cim-dense vs cim int8-planes vs cim-packed.

Serves one reduced LM four times through ``launch.serve.generate`` (scan
decode loop, donated KV cache):

  * ``fp``          — float weights, the framework baseline;
  * ``cim_dense``   — crossbar-achieved weights materialized dense f32;
  * ``cim_planes_int8`` — achieved weights served as signed int8 bit planes
    through ``cim_linear`` (one byte of weight traffic per bit cell);
  * ``cim_packed``  — achieved weights served straight from the canonical
    bit-packed plane words (one *bit* per bit cell, the pool's own
    representation) through the packed kernel/reference.

Alongside tok/s it emits the weight-traffic roofline for one decode step
(``roofline.cim_weight_bytes``): bytes of deployed weights a decode step must
read under each representation, and the int8-plane/packed ratio (~8x).

Timing: every variant compiles once (``serve.make_generator``), then the
timed passes are INTERLEAVED across variants and each variant keeps its
best pass.  A single timed run of the reduced model is ~20 ms, and one-shot
samples swing tens of percent with scheduler/allocator noise — enough to
make ``fp`` appear 1.5x slower than ``cim_dense`` even though both lower to
identical f32 matmul graphs (the cause of the historical BENCH_serve
anomaly).  Sequential best-of-N is not enough when background load drifts
over the suite: the variant measured first samples a different machine than
the one measured last, so passes must interleave.

  PYTHONPATH=src python -m benchmarks.serving_throughput [--quick]

Writes experiments/bench/BENCH_serve.json (used by benchmarks.roofline and
uploaded as a CI artifact).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, banner, save_json
from benchmarks.roofline import cim_weight_bytes
from repro.configs import get_arch
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment, deploy_params
from repro.core.pool import CrossbarPool
from repro.launch.serve import make_generator
from repro.models import api


def weight_traffic(plan) -> dict:
    """Deployed-weight bytes one decode step reads, per representation.

    Tensors the planner forces dense under every materialization
    (``planner.MATERIALIZE_DENSE_ONLY`` — non-matmul consumers) are priced
    as dense f32 in all three columns, matching what ``deploy_params``
    actually serves.
    """
    from repro.core.planner import _dense_only

    out = {rep: 0 for rep in ("dense_f32", "planes_int8", "packed")}
    for name, r in plan.reports.items():
        for rep in out:
            eff = "dense_f32" if _dense_only(name) else rep
            out[rep] += cim_weight_bytes(r.shape, plan.spec.cols, eff)
    out["int8_over_packed"] = out["planes_int8"] / max(out["packed"], 1)
    out["dense_over_packed"] = out["dense_f32"] / max(out["packed"], 1)
    return out


def run(
    arch: str = "gemma-2b",
    *,
    reduced: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    p_stuck: float = 0.5,
    min_size: int = 1024,
    seed: int = 0,
    repeats: int = 5,
) -> dict:
    cfg = get_arch(arch, reduced=reduced)
    key = jax.random.PRNGKey(seed)
    params = api.init(key, cfg)
    bt = api.make_batch(cfg, key, batch, prompt_len)

    spec = CrossbarSpec(rows=128, cols=10)
    pcfg = PlannerConfig(p_stuck=p_stuck, min_size=min_size)
    pool = CrossbarPool(spec, pcfg.crossbars)
    plan = build_deployment(params, spec, pcfg, pool=pool)

    # all four generators stay alive so timed passes can interleave (the
    # reduced model makes the simultaneous-residency cost negligible; a
    # full-size run that must bound memory can fall back to sequential
    # generate(repeats=...) per variant)
    variants = {
        "fp": params,
        "cim_dense": deploy_params(params, plan),
        "cim_planes_int8": deploy_params(params, plan, materialize="planes_int8"),
        "cim_packed": deploy_params(params, plan, materialize="packed"),
    }
    gens = {
        name: make_generator(cfg, p, bt, gen_len=gen, seed=seed)
        for name, p in variants.items()
    }
    best: dict[str, float] = {name: float("inf") for name in gens}
    tokens: dict[str, jax.Array] = {}
    with Timer():
        for _ in range(max(1, repeats)):
            for name, g in gens.items():
                toks, dt = g()
                best[name] = min(best[name], dt)
                tokens[name] = toks
    tok_s = {name: batch * gen / dt for name, dt in best.items()}

    agree = {
        name: float(jnp.mean((tokens["cim_dense"] == tokens[name]).astype(jnp.float32)))
        for name in ("cim_planes_int8", "cim_packed")
    }
    traffic = weight_traffic(plan)
    return {
        "arch": arch,
        "reduced": reduced,
        "batch": batch,
        "prompt_len": prompt_len,
        "gen": gen,
        "p_stuck": p_stuck,
        "backend": jax.default_backend(),
        "timing": f"best-of-{repeats}, passes interleaved across variants (post-warmup)",
        "tok_s": tok_s,
        "packed_over_int8_tok_s": tok_s["cim_packed"] / max(tok_s["cim_planes_int8"], 1e-9),
        "token_agreement_vs_dense": agree,
        "weight_bytes_per_decode_step": traffic,
        "n_deployed_tensors": len(plan.reports),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--full-size", action="store_true", help="no --reduced config")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--p-stuck", type=float, default=0.5)
    ap.add_argument(
        "--quick", action="store_true",
        help="CI smoke shapes: batch 2, prompt 8, gen 4",
    )
    args = ap.parse_args()
    if args.quick:
        args.batch, args.prompt_len, args.gen = 2, 8, 4

    banner("Serving throughput — fp vs cim-dense vs int8-planes vs packed")
    res = run(
        args.arch,
        reduced=not args.full_size,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen=args.gen,
        p_stuck=args.p_stuck,
    )
    for name, tps in res["tok_s"].items():
        print(f"  {name:16s} {tps:10.1f} tok/s")
    t = res["weight_bytes_per_decode_step"]
    print(f"  weight bytes/step: dense {t['dense_f32']:,}  int8-planes {t['planes_int8']:,}  "
          f"packed {t['packed']:,}  (int8/packed = {t['int8_over_packed']:.2f}x)")
    print(f"  token agreement vs cim-dense: {res['token_agreement_vs_dense']}")
    save_json("BENCH_serve", res)


if __name__ == "__main__":
    main()
