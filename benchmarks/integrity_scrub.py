"""Online integrity: scrub/detect/repair economics and serving overhead.

Four experiments close the self-repair loop around the serving stack
(core/integrity.py):

  * **Storm and repair** — deploy a checkpoint through an integrity-enabled
    pool, unleash a mid-trace fault storm (stored-bit corruption + new hard
    stuck-at cells), and drive the scrubber to convergence.  Reported: the
    storm is *detected* (checksum tiles flag it), repair restores
    bit-identical token parity versus solo generation on the clean
    deployment, and the priced repair cost (in-place rewrites + spare-column
    remaps + migrations, all via ``price_pairs``) lands far below a full
    reprogram of the affected tensors — the reprogramming-cost argument of
    the paper applied to maintenance instead of checkpoint swaps.
  * **Engine-integrated scrub** — an engine serves a live trace while its
    between-dispatch scrub hook finds the storm, repairs it, and atomically
    ``hot_swap``s the repaired planes in; requests admitted after the
    refresh are bit-identical to solo generation on the clean deployment
    (in-flight requests keep their epoch, per the hot-redeploy contract).
  * **Scrub overhead** — steady-state serving throughput with the scrubber
    scanning its per-round tile budget on a *clean* pool versus scrubbing
    disabled, interleaved best-of-N: the detection tax on tok/s.
  * **Tolerated-fault accuracy** — with ``tolerate_cols=1`` the repair
    policy leaves lowest-order faulty columns un-repaired (the bit-stucking
    insight); shadow-batch logit KL versus the clean fp model across storm
    rates prices that tolerance.

  PYTHONPATH=src python -m benchmarks.integrity_scrub [--quick] [--check]

Writes experiments/bench/BENCH_integrity.json (schema: docs/benchmarks.md).
``--check`` exits non-zero if the storm goes undetected, post-repair token
parity breaks, repair costs more than half a full reprogram of the affected
tensors, or scrubbing costs more than 5% of serving throughput.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, save_json
from repro.configs import get_arch
from repro.core import simulator
from repro.core.integrity import IntegrityConfig
from repro.core.planner import (
    CrossbarSpec,
    PlannerConfig,
    build_deployment,
    deploy_params,
)
from repro.core.pool import CrossbarPool
from repro.launch.engine import Engine, EngineConfig, Request
from repro.launch.serve import generate
from repro.models import api

SPEC = CrossbarSpec(rows=128, cols=10)
STORM_KEY = jax.random.PRNGKey(1729)
ECFG = EngineConfig(
    max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=8, decode_quantum=4
)


def _integrity_deploy(params, pcfg, icfg):
    """Deploy ``params`` through a fresh integrity-enabled pool; returns
    (pool, manager, plan, dense served params)."""
    pool = CrossbarPool(SPEC, 2 * pcfg.crossbars, leveling="lpt")
    mgr = pool.enable_integrity(icfg)
    plan = build_deployment(params, SPEC, pcfg, pool=pool)
    return pool, mgr, plan, deploy_params(params, plan, materialize="dense")


def _mk_reqs(cfg, n, *, seed=0, rid0=0):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=rid0 + i,
            prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(6, 14))).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 9)), greedy=True, seed=rid0 + i,
        )
        for i in range(n)
    ]


def _solo(cfg, params, req):
    toks, _ = generate(
        cfg, params, {"tokens": jnp.asarray(req.prompt)[None]},
        gen_len=req.max_new_tokens, greedy=req.greedy, seed=req.seed,
    )
    return [int(t) for t in np.asarray(toks[0])]


def run_storm_repair(cfg, params, *, pcfg, corrupt=2e-3, stuck=2e-4,
                     n_requests=4, seed=0) -> dict:
    """Storm -> scrub to convergence -> rebuilt deployment must serve token
    streams bit-identical to the pre-storm one."""
    icfg = IntegrityConfig(spare_cols=2, tolerate_cols=0)
    pool, mgr, plan, served = _integrity_deploy(params, pcfg, icfg)
    reqs = _mk_reqs(cfg, n_requests, seed=seed)
    clean_streams = [_solo(cfg, served, r) for r in reqs]

    st = mgr.storm(STORM_KEY, corrupt_rate=corrupt, stuck_rate=stuck)
    corrupted = deploy_params(params, mgr.rebuild_plan(plan), materialize="dense")
    storm_streams = [_solo(cfg, corrupted, r) for r in reqs]
    degraded = sum(a != b for a, b in zip(storm_streams, clean_streams))

    rep = mgr.scrub_until_clean()
    full = mgr.transitions_full_affected()
    repaired = deploy_params(params, mgr.rebuild_plan(plan), materialize="dense")
    repaired_streams = [_solo(cfg, repaired, r) for r in reqs]
    parity = repaired_streams == clean_streams
    return {
        "corrupt_rate": corrupt, "stuck_rate": stuck,
        "corrupted_bits": st["corrupted_bits"],
        "new_stuck_cells": st["new_stuck_cells"],
        "streams_degraded_by_storm": degraded,
        "detections": rep.detections,
        "transients": rep.transients,
        "rewrites": rep.rewrites,
        "remaps": rep.remaps,
        "migrations": rep.migrations,
        "tolerated": rep.tolerated,
        "repair_transitions": rep.repair_transitions,
        "transitions_full_reprogram": full,
        "repair_cost_ratio": rep.repair_transitions / max(full, 1),
        "post_repair_parity": bool(parity),
        "pool_verified": bool(mgr.verify_all()),
        "spare_writes": mgr.spare_writes,
    }


def run_engine_scrub(cfg, params, *, pcfg, corrupt=2e-3, stuck=2e-4,
                     n_requests=4, seed=0) -> dict:
    """Mid-trace storm under a live engine: the between-dispatch scrub hook
    detects, repairs, and hot-swaps the repaired planes; post-refresh
    admissions are bit-identical to solo generation on the clean params."""
    icfg = IntegrityConfig(spare_cols=2, scrub_tiles=1_000_000)
    pool, mgr, plan, served = _integrity_deploy(params, pcfg, icfg)
    eng = Engine(cfg, served, ECFG)
    eng.attach_scrub(
        mgr,
        refresh=lambda: deploy_params(
            params, mgr.rebuild_plan(plan), materialize="dense"
        ),
    )
    mgr.storm(STORM_KEY, corrupt_rate=corrupt, stuck_rate=stuck)
    # what an un-refreshed engine would keep serving
    eng.hot_swap(deploy_params(params, mgr.rebuild_plan(plan), materialize="dense"))
    eng.run(_mk_reqs(cfg, n_requests, seed=seed))

    post = _mk_reqs(cfg, 2, seed=seed + 1, rid0=100)
    results = eng.run(post)
    parity = all(
        res.tokens == _solo(cfg, served, req) for req, res in zip(post, results)
    )
    return {
        "scrub_rounds": eng.stats["scrub_rounds"],
        "scrub_tiles": eng.stats["scrub_tiles"],
        "scrub_detections": eng.stats["scrub_detections"],
        "scrub_repairs": eng.stats["scrub_repairs"],
        "scrub_refreshes": eng.stats["scrub_refreshes"],
        "pool_verified": bool(mgr.verify_all()),
        "post_refresh_parity": bool(parity),
    }


def run_scrub_overhead(cfg, params, *, pcfg, n_requests=4, trials=3,
                       scrub_tiles=64, every=8, seed=0) -> dict:
    """Steady-state serving tok/s with/without the scrubber scanning its
    tile budget every ``every`` engine steps (clean pool: pure detection
    overhead at a realistic scrub duty cycle).  Interleaved best-of-N so
    one-off JIT/compile noise cancels."""
    icfg = IntegrityConfig(spare_cols=2, scrub_tiles=scrub_tiles)
    pool, mgr, plan, served = _integrity_deploy(params, pcfg, icfg)
    eng_off = Engine(cfg, served, ECFG)
    eng_on = Engine(cfg, served, ECFG)
    eng_on.attach_scrub(mgr, every=every)

    def _timed(eng, rid0):
        reqs = _mk_reqs(cfg, n_requests, seed=seed, rid0=rid0)
        t0 = time.perf_counter()
        results = eng.run(reqs)
        wall = time.perf_counter() - t0
        return sum(len(r.tokens) for r in results), wall

    _timed(eng_off, 10_000), _timed(eng_on, 20_000)  # warm-up both paths
    best = {"off": float("inf"), "on": float("inf")}
    tokens = 0
    for t in range(trials):
        tokens, w_off = _timed(eng_off, 30_000 + 100 * t)
        _, w_on = _timed(eng_on, 60_000 + 100 * t)
        best["off"] = min(best["off"], w_off)
        best["on"] = min(best["on"], w_on)
    tps_off = tokens / best["off"]
    tps_on = tokens / best["on"]
    return {
        "trials": trials,
        "scrub_every_steps": every,
        "scrub_tiles_per_round": scrub_tiles,
        "total_tiles": mgr.total_tiles,
        "tokens_per_trial": tokens,
        "tok_s_off": tps_off,
        "tok_s_on": tps_on,
        "throughput_ratio": tps_on / tps_off,
        "scrub_rounds": eng_on.stats["scrub_rounds"],
        "false_detections": eng_on.stats["scrub_detections"],
    }


def run_tolerated_kl(cfg, params, *, pcfg, rates, batch_size=2,
                     shadow_len=16, seed=0) -> list[dict]:
    """Shadow-batch logit KL (vs clean fp) after storm+repair with
    ``tolerate_cols=1``: low-order faulty columns stay un-repaired and the
    bounded LSB error is priced in accuracy instead of repair writes."""
    batch = api.make_batch(cfg, jax.random.PRNGKey(seed), batch_size, shadow_len)
    f = lambda p, b: api.forward(p, cfg, b)[0]  # noqa: E731
    out = []
    for rate in rates:
        icfg = IntegrityConfig(spare_cols=2, tolerate_cols=1)
        pool, mgr, plan, _ = _integrity_deploy(params, pcfg, icfg)
        rep_row = {"stuck_rate": rate, "tolerated": 0, "remaps": 0}
        if rate > 0.0:
            mgr.storm(STORM_KEY, stuck_rate=rate)
            rep = mgr.scrub_until_clean()
            rep_row.update(tolerated=rep.tolerated, remaps=rep.remaps)
        params_hat = deploy_params(params, mgr.rebuild_plan(plan),
                                   materialize="dense")
        rep_row["kl"] = float(simulator.logit_kl(f, params, params_hat, batch))
        out.append(rep_row)
        print(f"  stuck rate {rate:7.5f}   kl {rep_row['kl']:.5f}   "
              f"({rep_row['tolerated']} tolerated, {rep_row['remaps']} remapped)")
    return out


def run(
    arch: str = "gemma-2b",
    *,
    reduced: bool = True,
    corrupt: float = 2e-3,
    stuck: float = 2e-4,
    n_requests: int = 4,
    trials: int = 3,
    kl_rates=(0.0, 1e-3, 4e-3),
    seed: int = 0,
) -> dict:
    cfg = get_arch(arch, reduced=reduced)
    params = api.init(jax.random.PRNGKey(seed), cfg)
    pcfg = PlannerConfig(p_stuck=0.5, min_size=1024)

    banner("Storm and repair — detect, localize, price, restore parity")
    storm = run_storm_repair(cfg, params, pcfg=pcfg, corrupt=corrupt,
                             stuck=stuck, n_requests=n_requests, seed=seed)
    print(f"  {storm['corrupted_bits']} corrupted bits + "
          f"{storm['new_stuck_cells']} stuck cells -> "
          f"{storm['detections']} tiles detected, "
          f"{storm['rewrites']} rewrites / {storm['remaps']} remaps / "
          f"{storm['migrations']} migrations")
    print(f"  repair cost {storm['repair_transitions']} transitions = "
          f"{100 * storm['repair_cost_ratio']:.1f}% of a full reprogram "
          f"({storm['transitions_full_reprogram']}), "
          f"token parity {storm['post_repair_parity']}")

    banner("Engine-integrated scrub — repair + atomic refresh under load")
    esc = run_engine_scrub(cfg, params, pcfg=pcfg, corrupt=corrupt,
                           stuck=stuck, n_requests=n_requests, seed=seed)
    print(f"  {esc['scrub_rounds']} scrub rounds between dispatches: "
          f"{esc['scrub_detections']} detections, {esc['scrub_repairs']} repairs, "
          f"{esc['scrub_refreshes']} refreshes; "
          f"post-refresh parity {esc['post_refresh_parity']}")

    banner("Scrub overhead — steady-state tok/s, scrubber on vs off")
    ovh = run_scrub_overhead(cfg, params, pcfg=pcfg, n_requests=n_requests,
                             trials=trials, seed=seed)
    print(f"  {ovh['tok_s_off']:.1f} tok/s off vs {ovh['tok_s_on']:.1f} on "
          f"({100 * ovh['throughput_ratio']:.1f}%, "
          f"{ovh['scrub_tiles_per_round']}/{ovh['total_tiles']} tiles/round)")

    banner("Tolerated-fault accuracy — KL vs stuck rate at tolerate_cols=1")
    kl = run_tolerated_kl(cfg, params, pcfg=pcfg, rates=kl_rates, seed=seed)

    return {
        "arch": arch,
        "reduced": reduced,
        "backend": jax.default_backend(),
        "spec": {"rows": SPEC.rows, "cols": SPEC.cols},
        "planner": {"p_stuck": pcfg.p_stuck, "min_size": pcfg.min_size,
                    "crossbars": pcfg.crossbars, "spare_factor": 2},
        "storm_repair": storm,
        "engine_scrub": esc,
        "overhead": ovh,
        "tolerated_kl": kl,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--full-size", action="store_true", help="no --reduced config")
    ap.add_argument("--quick", action="store_true", help="CI smoke shapes")
    ap.add_argument(
        "--check", action="store_true",
        help="exit non-zero if the storm goes undetected, post-repair token "
             "parity breaks, repair transitions exceed half a full reprogram "
             "of the affected tensors, or scrubbing costs > 5% of serving "
             "tok/s (CI integrity gates)",
    )
    args = ap.parse_args()

    kw = {}
    if args.quick:
        kw = dict(n_requests=3, trials=2, kl_rates=(1e-3,))

    res = run(args.arch, reduced=not args.full_size, **kw)
    save_json("BENCH_integrity", res)
    if args.check:
        failures = []
        sr = res["storm_repair"]
        if sr["detections"] < 1:
            failures.append("fault storm went undetected by the scrubber")
        if not (sr["post_repair_parity"] and sr["pool_verified"]):
            failures.append(
                "post-repair token streams or pool reads are not bit-identical "
                "to the clean deployment"
            )
        if sr["repair_cost_ratio"] > 0.5:
            failures.append(
                f"repair cost {100 * sr['repair_cost_ratio']:.1f}% of a full "
                f"reprogram (gate: <= 50%)"
            )
        esc = res["engine_scrub"]
        if not (esc["scrub_refreshes"] >= 1 and esc["post_refresh_parity"]):
            failures.append(
                "engine scrub hook failed to refresh repaired planes with "
                "post-refresh stream parity"
            )
        if res["overhead"]["throughput_ratio"] < 0.95:
            failures.append(
                f"scrubbing costs {100 * (1 - res['overhead']['throughput_ratio']):.1f}% "
                f"of serving throughput (gate: <= 5%)"
            )
        if failures:
            for f in failures:
                print(f"  CHECK FAILED: {f}", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
