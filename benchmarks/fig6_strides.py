"""Paper Fig. 6 — stride-L vs stride-1 scheduling, 16 reprogrammable crossbars.

Total reprogramming speedup vs the unsorted baseline under both schedules,
sweeping the stride parameter L of the stride-L method.  Paper finding:
speedup decays with L; stride-1 is best (ViT-Base stride-1 ~3x better than
stride L=4).
"""
from __future__ import annotations

import argparse

from benchmarks.common import banner, model_planes, save_json
from repro.core import schedule

COLS = 10
L_CROSSBARS = 16


def run(models=None, *, strides=(1, 2, 4, 8, 16), max_elems=2_000_000, seed=0) -> dict:
    models = models or ["resnet50", "vit-base"]
    results = {}
    for m in models:
        planes_u = model_planes(m, cols=COLS, sort=False, max_elems=max_elems, seed=seed)
        planes_s = model_planes(m, cols=COLS, sort=True, max_elems=max_elems, seed=seed)
        s = planes_s.shape[0]
        base = int(
            schedule.schedule_transitions(planes_u, schedule.stride_1_chains(s, L_CROSSBARS))
        )
        entry = {"baseline_unsorted": base, "strideL": {}, "stride1": None}
        for l in strides:
            tl = int(schedule.schedule_transitions(planes_s, schedule.stride_l_chains(s, l)))
            entry["strideL"][str(l)] = {"transitions": tl, "speedup": base / max(tl, 1)}
        t1 = int(schedule.schedule_transitions(planes_s, schedule.stride_1_chains(s, L_CROSSBARS)))
        entry["stride1"] = {"transitions": t1, "speedup": base / max(t1, 1)}
        results[m] = entry
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    banner(f"Fig. 6 — stride-L vs stride-1 ({L_CROSSBARS} crossbars)")
    res = run(max_elems=0 if args.full else 2_000_000)
    for m, r in res.items():
        ls = "  ".join(f"L={l}:{v['speedup']:.2f}x" for l, v in r["strideL"].items())
        print(f"  {m:10s} strideL[{ls}]  stride1: {r['stride1']['speedup']:.2f}x")
    save_json("fig6_strides", res)


if __name__ == "__main__":
    main()
