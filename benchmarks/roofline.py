"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
the per-(arch x shape x mesh) three-term roofline table: compute / memory /
collective seconds, the dominant term, MODEL_FLOPS/HLO_FLOPS, and the
roofline fraction (useful FLOP/s at the roofline step time over peak).

Also owns the CIM *weight-traffic* accounting (``cim_weight_bytes``): the
bytes of deployed-weight HBM reads a decode step costs under each serving
representation.  The packed-plane operand stores one bit per bit cell
(``uint8[cols, ceil(K/8), N]`` planes + a ``ceil(K/8) x N`` sign-bit mask),
so its byte count is ~(cols+1)/8 per weight versus ``cols`` for the int8
plane operand — the ~8x traffic reduction the packed serving path exists
for.  When ``experiments/bench/BENCH_serve.json`` exists (written by
``benchmarks.serving_throughput``) its traffic table is folded into the
roofline report.
"""
from __future__ import annotations

import argparse
import json
import math
from pathlib import Path

from benchmarks.common import OUT_DIR, banner, save_json

DRYRUN_DIR = Path("experiments/dryrun")


def cim_weight_bytes(
    shape: tuple[int, ...], cols: int, repr: str, *, tile_density: float = 1.0
) -> int:
    """Weight bytes one matmul pass must read for a [..., K, N] tensor.

    * ``dense_f32``    — 4 bytes per weight (the dense-materialized baseline);
    * ``planes_int8``  — ``cols`` bytes per weight: one int8 per bit cell,
      the naive bit-sliced operand;
    * ``packed``       — bit-packed planes + sign mask: ``(cols+1) *
      ceil(K/8) * N`` bytes per [K, N] slab, i.e. ~(cols+1)/8 per weight;
    * ``packed_codec`` — codec-compressed packed planes
      (``core.planes.encode_operands``): ``tile_density`` is the fraction of
      16-byte plane tiles flagged nonzero (zero tiles are never read), plus
      the codec sideband — one zero-tile flag byte per plane tile and, for
      ``col_perm``, ``cols`` plane-id bytes per slab.  ``tile_density=1``
      degenerates to ``packed`` plus the sideband.
    """
    if len(shape) < 2:
        raise ValueError(f"weight shape {shape} has no (K, N) axes")
    n_elem = math.prod(shape)
    if repr == "dense_f32":
        return 4 * n_elem
    if repr == "planes_int8":
        return cols * n_elem
    if repr in ("packed", "packed_codec"):
        k, n = shape[-2], shape[-1]
        lead = math.prod(shape[:-2]) if len(shape) > 2 else 1
        kw = -(-k // 8)
        if repr == "packed":
            return lead * (cols + 1) * kw * n
        n_tiles = -(-kw // 16)  # core.planes.OPERAND_TILE_BYTES
        plane_b = round(cols * kw * n * min(max(tile_density, 0.0), 1.0))
        meta_b = cols * n_tiles + cols  # nz flags + plane ids
        return lead * (plane_b + kw * n + meta_b)
    raise ValueError(f"unknown representation {repr!r}")


def load_cells(dryrun_dir: Path = DRYRUN_DIR, variant: str = "") -> list[dict]:
    cells = []
    for p in sorted(dryrun_dir.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("status") != "ok":
            continue
        if (d.get("variant") or "") != variant:
            continue
        cells.append(d)
    return cells


def table_rows(cells: list[dict]) -> list[dict]:
    rows = []
    for d in cells:
        r = d["roofline"]
        rows.append(
            {
                "arch": d["arch"],
                "shape": d["shape"],
                "mesh": d["mesh"],
                "compute_s": r["compute_s"],
                "memory_s": r["memory_s"],
                "collective_s": r["collective_s"],
                "bottleneck": r["bottleneck"],
                "step_time_s": r["step_time_s"],
                "useful_flops_ratio": r["useful_flops_ratio"],
                "roofline_fraction": r["roofline_fraction"],
            }
        )
    return rows


def serving_weight_traffic() -> dict | None:
    """Fold the serving benchmark's weight-traffic roofline into the report."""
    path = OUT_DIR / "BENCH_serve.json"
    if not path.exists():
        return None
    d = json.loads(path.read_text())
    t = d.get("weight_bytes_per_decode_step")
    if not t:
        return None
    return {
        "arch": d.get("arch"),
        "bytes_per_decode_step": t,
        "tok_s": d.get("tok_s"),
    }


def codec_weight_traffic() -> dict | None:
    """Fold per-codec deployed-operand bytes (benchmarks.plane_compression)
    into the report: measured bytes/weight per plane codec."""
    path = OUT_DIR / "BENCH_compress.json"
    if not path.exists():
        return None
    d = json.loads(path.read_text())
    srv = d.get("serving")
    if not srv:
        return None
    return {
        "arch": srv.get("arch"),
        "bytes_per_weight": {
            c: r["bytes_per_weight"] for c, r in srv["codecs"].items()
        },
        "traffic_reduction_vs_raw": {
            c: r.get("traffic_reduction_vs_raw")
            for c, r in srv["codecs"].items()
        },
    }


def run(variant: str = "") -> dict:
    cells = load_cells(variant=variant)
    rows = table_rows(cells)
    worst = sorted(
        (r for r in rows if r["roofline_fraction"] is not None and r["mesh"] == "single"),
        key=lambda r: r["roofline_fraction"],
    )
    most_coll = sorted(
        (r for r in rows if r["mesh"] == "single"),
        key=lambda r: -(r["collective_s"] / max(r["step_time_s"], 1e-30)),
    )
    return {
        "rows": rows,
        "worst_roofline_fraction": worst[:3],
        "most_collective_bound": most_coll[:3],
        "serving_weight_traffic": serving_weight_traffic(),
        "codec_weight_traffic": codec_weight_traffic(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="")
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    args = ap.parse_args()

    banner("Roofline (from dry-run artifacts)")
    res = run(variant=args.variant)
    swt = res["serving_weight_traffic"]
    if swt:
        t = swt["bytes_per_decode_step"]
        print(f"  serving weight traffic ({swt['arch']}): dense {t['dense_f32']:,} B/step, "
              f"int8-planes {t['planes_int8']:,} B/step, packed {t['packed']:,} B/step "
              f"(int8/packed = {t['int8_over_packed']:.2f}x)")
    cwt = res["codec_weight_traffic"]
    if cwt:
        per = "  ".join(
            f"{c}:{b:.3f}" for c, b in cwt["bytes_per_weight"].items()
        )
        print(f"  codec weight traffic ({cwt['arch']}): B/weight  {per}")
    rows = [r for r in res["rows"] if args.mesh in (None, r["mesh"])]
    if not rows:
        print("  no dry-run artifacts found — run: python -m repro.launch.dryrun --all --mesh both")
        return
    hdr = f"  {'arch':24s}{'shape':13s}{'mesh':7s}{'compute':>10s}{'memory':>10s}{'coll':>10s}  {'bound':10s}{'frac':>7s}"
    print(hdr)
    for r in rows:
        frac = f"{r['roofline_fraction']:.3f}" if r["roofline_fraction"] is not None else "-"
        print(
            f"  {r['arch']:24s}{r['shape']:13s}{r['mesh']:7s}"
            f"{r['compute_s']:10.2e}{r['memory_s']:10.2e}{r['collective_s']:10.2e}"
            f"  {r['bottleneck']:10s}{frac:>7s}"
        )
    save_json("roofline", res)


if __name__ == "__main__":
    main()
