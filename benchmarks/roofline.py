"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
the per-(arch x shape x mesh) three-term roofline table: compute / memory /
collective seconds, the dominant term, MODEL_FLOPS/HLO_FLOPS, and the
roofline fraction (useful FLOP/s at the roofline step time over peak).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import banner, save_json

DRYRUN_DIR = Path("experiments/dryrun")


def load_cells(dryrun_dir: Path = DRYRUN_DIR, variant: str = "") -> list[dict]:
    cells = []
    for p in sorted(dryrun_dir.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("status") != "ok":
            continue
        if (d.get("variant") or "") != variant:
            continue
        cells.append(d)
    return cells


def table_rows(cells: list[dict]) -> list[dict]:
    rows = []
    for d in cells:
        r = d["roofline"]
        rows.append(
            {
                "arch": d["arch"],
                "shape": d["shape"],
                "mesh": d["mesh"],
                "compute_s": r["compute_s"],
                "memory_s": r["memory_s"],
                "collective_s": r["collective_s"],
                "bottleneck": r["bottleneck"],
                "step_time_s": r["step_time_s"],
                "useful_flops_ratio": r["useful_flops_ratio"],
                "roofline_fraction": r["roofline_fraction"],
            }
        )
    return rows


def run(variant: str = "") -> dict:
    cells = load_cells(variant=variant)
    rows = table_rows(cells)
    worst = sorted(
        (r for r in rows if r["roofline_fraction"] is not None and r["mesh"] == "single"),
        key=lambda r: r["roofline_fraction"],
    )
    most_coll = sorted(
        (r for r in rows if r["mesh"] == "single"),
        key=lambda r: -(r["collective_s"] / max(r["step_time_s"], 1e-30)),
    )
    return {
        "rows": rows,
        "worst_roofline_fraction": worst[:3],
        "most_collective_bound": most_coll[:3],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="")
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    args = ap.parse_args()

    banner("Roofline (from dry-run artifacts)")
    res = run(variant=args.variant)
    rows = [r for r in res["rows"] if args.mesh in (None, r["mesh"])]
    if not rows:
        print("  no dry-run artifacts found — run: python -m repro.launch.dryrun --all --mesh both")
        return
    hdr = f"  {'arch':24s}{'shape':13s}{'mesh':7s}{'compute':>10s}{'memory':>10s}{'coll':>10s}  {'bound':10s}{'frac':>7s}"
    print(hdr)
    for r in rows:
        frac = f"{r['roofline_fraction']:.3f}" if r["roofline_fraction"] is not None else "-"
        print(
            f"  {r['arch']:24s}{r['shape']:13s}{r['mesh']:7s}"
            f"{r['compute_s']:10.2e}{r['memory_s']:10.2e}{r['collective_s']:10.2e}"
            f"  {r['bottleneck']:10s}{frac:>7s}"
        )
    save_json("roofline", res)


if __name__ == "__main__":
    main()
