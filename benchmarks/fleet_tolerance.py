"""Fleet fault tolerance: throughput scaling, chaos survival, admission.

Four experiments prove the fleet router (``launch/fleet.py``) turns replica
failures into routing events instead of outages:

  * **Scaling** — one trace served by 1/2/4-replica fleets; reported tok/s
    per replica count.  Meaningful scaling needs one emulated device per
    replica: pass ``--devices 4`` (sets
    ``--xla_force_host_platform_device_count`` *before* first jax
    initialization, like the dry-run's 512-chip override) — without it the
    replicas share one CPU device and scaling is flat by construction.
  * **TP scaling** — one replica's pipeline sharded {1, 2, 4}-way over the
    "model" axis (``parallel/tp.py``): per-replica tok/s vs shard count,
    with every shard count's token stream checked against solo generation
    (vmap-emulated on one device; native ``shard_map`` when ``--devices``
    provides a real mesh).
  * **Kill-one-of-4** — a deterministic :class:`FaultInjector` crash takes
    one replica down mid-trace; the survivors must complete 100% of
    admitted requests with every stream bit-identical to solo
    ``serve.generate`` (the failover parity contract).
  * **Stall trace** — one replica freezes for seconds; hedged re-dispatch
    must finish its in-flight requests on healthy replicas without waiting
    the stall out, again with full completion + parity.
  * **Admission** — a burst beyond the bounded queue: shed-vs-completed
    -vs-degraded counts, with the degraded (clamped) streams still exact.

  PYTHONPATH=src python -m benchmarks.fleet_tolerance [--devices N]
      [--quick] [--check]

Writes experiments/bench/BENCH_fleet.json (schema: docs/benchmarks.md).
``--check`` exits non-zero unless both chaos traces complete every admitted
request with >= 1 surviving replica and per-request token parity, and
deadline-expired requests (if any) retired as "timeout" — the CI fleet
gates.
"""
from __future__ import annotations

import argparse
import os
import sys


def _preparse_devices() -> int:
    """Apply ``--devices N`` before any jax initialization.

    XLA reads ``--xla_force_host_platform_device_count`` once, at backend
    init — mutating it later is a silent no-op — so this runs at import
    time, before the jax-touching imports below.
    """
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=0)
    args, _ = ap.parse_known_args()
    if args.devices > 0:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        ).strip()
    return args.devices


N_DEVICES = _preparse_devices()

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import banner, save_json  # noqa: E402
from repro.configs import get_arch  # noqa: E402
from repro.launch.engine import EngineConfig, Request  # noqa: E402
from repro.launch.fleet import FaultInjector, Fleet, FleetConfig  # noqa: E402
from repro.launch.serve import generate  # noqa: E402
from repro.models import api  # noqa: E402

ECFG = EngineConfig(
    max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=16, decode_quantum=4
)


def _trace(cfg, n, *, seed=0, gen_lo=6, gen_hi=12):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        prompt = rng.integers(
            0, cfg.vocab_size, int(rng.integers(4, 11))
        ).astype(np.int32)
        reqs.append(Request(
            rid=i, prompt=prompt,
            max_new_tokens=int(rng.integers(gen_lo, gen_hi + 1)),
            greedy=bool(i % 2), seed=i,
        ))
    return reqs


def _solo(cfg, params, req):
    toks, _ = generate(
        cfg, params, {"tokens": jnp.asarray(req.prompt)[None]},
        gen_len=req.max_new_tokens, greedy=req.greedy, seed=req.seed,
    )
    return [int(t) for t in np.asarray(toks[0])]


def _summarize(cfg, params, fleet, reqs, results, wall_s) -> dict:
    """Completion / parity / latency / shed accounting for one trace."""
    ok = [r for r in results if r.status == "ok"]
    parity = all(
        r.tokens == _solo(cfg, params, fleet.requests[r.rid]) for r in ok
    )
    lat = sorted(r.latency for r in ok)
    pct = lambda p: float(lat[min(int(p * len(lat)), len(lat) - 1)]) if lat else 0.0  # noqa: E731
    toks = sum(len(r.tokens) for r in ok)
    return {
        "n_requests": len(reqs),
        "admitted": fleet.stats["admitted"],
        "completed": len(ok),
        "timeouts": sum(r.status == "timeout" for r in results),
        "shed": sum(r.status == "shed" for r in results),
        "degraded": fleet.stats["degraded"],
        "stream_parity": bool(parity),
        "surviving_replicas": sum(r.state == "live" for r in fleet.replicas),
        "tok_s": toks / max(wall_s, 1e-9),
        "p50_latency_s": pct(0.50),
        "p99_latency_s": pct(0.99),
        "retries": fleet.stats["retries"],
        "failovers": fleet.stats["failovers"],
        "restarts": fleet.stats["restarts"],
        "hedges": fleet.stats["hedges"],
        "wall_s": wall_s,
    }


def _run_fleet(cfg, params, fcfg, reqs, injector=None):
    fleet = Fleet(cfg, params, fcfg, ECFG, injector=injector)
    t0 = time.perf_counter()
    results = fleet.run(reqs)
    wall = time.perf_counter() - t0
    return fleet, results, wall


def run_scaling(cfg, params, *, counts=(1, 2, 4), n_requests=12, seed=0) -> list[dict]:
    """One trace through fleets of increasing replica count (hedging off:
    pure placement throughput)."""
    rows = []
    for n in counts:
        reqs = _trace(cfg, n_requests, seed=seed)
        fcfg = FleetConfig(n_replicas=n, max_queue=4 * n_requests, hedge=False)
        fleet, results, wall = _run_fleet(cfg, params, fcfg, reqs)
        row = _summarize(cfg, params, fleet, reqs, results, wall)
        row["n_replicas"] = n
        rows.append(row)
        print(f"  {n} replica(s): {row['tok_s']:8.1f} tok/s   "
              f"{row['completed']}/{row['n_requests']} completed   "
              f"p50 {row['p50_latency_s'] * 1e3:.0f} ms")
    return rows


def run_tp_scaling(cfg, params, *, counts=(1, 2, 4), gen_len=16, seed=0) -> list[dict]:
    """Per-replica tok/s vs tensor-parallel shard count, parity-checked.

    Uses ``tp_generate`` (the lockstep serve.generate twin) so the numbers
    isolate the TP dispatch overhead from fleet scheduling.  Shards run
    under native ``shard_map`` when the (emulated) mesh is big enough,
    else vmap-emulated on one device — recorded per row, since emulated
    rows measure overhead only, not speedup."""
    from repro.parallel.tp import plan_tp, tp_generate

    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    )}
    ref, _ = generate(cfg, params, batch, gen_len=gen_len)
    ref = np.asarray(ref)
    rows = []
    for n in counts:
        plan = plan_tp(cfg, n)
        devs = list(jax.devices()[:n]) if jax.device_count() >= n > 1 else None
        toks, tok_s = tp_generate(cfg, params, batch, n=n, gen_len=gen_len,
                                  plan=plan, devices=devs, repeats=2)
        row = {
            "n_shards": n,
            "native_mesh": devs is not None,
            "attn_sharded": plan.attn,
            "mlp_sharded": plan.mlp,
            "tok_s_per_replica": float(tok_s),
            "token_parity": bool(np.array_equal(np.asarray(toks), ref)),
        }
        rows.append(row)
        print(f"  {n} shard(s) [{'mesh' if row['native_mesh'] else 'vmap'}]: "
              f"{row['tok_s_per_replica']:8.1f} tok/s   "
              f"attn={'TP' if plan.attn else 'rep'} "
              f"mlp={'TP' if plan.mlp else 'rep'}   "
              f"parity {row['token_parity']}")
    return rows


def run_kill_trace(cfg, params, *, n_replicas=4, n_requests=16, seed=1) -> dict:
    """Crash one replica mid-trace (host state lost on odd seeds): the
    survivors must complete everything admitted, streams exact."""
    reqs = _trace(cfg, n_requests, seed=seed, gen_lo=8, gen_hi=16)
    inj = FaultInjector()
    inj.crash(0, at_step=2, lose_state=bool(seed % 2))
    fcfg = FleetConfig(n_replicas=n_replicas, max_queue=4 * n_requests, hedge=False)
    fleet, results, wall = _run_fleet(cfg, params, fcfg, reqs, injector=inj)
    row = _summarize(cfg, params, fleet, reqs, results, wall)
    row.update(n_replicas=n_replicas, chaos=inj.log,
               crashes=fleet.stats["crashes"])
    print(f"  kill 1/{n_replicas}: {row['completed']}/{row['admitted']} "
          f"completed, parity {row['stream_parity']}, "
          f"{row['surviving_replicas']} survivors, "
          f"{row['failovers']} failovers + {row['restarts']} restarts")
    return row


def run_stall_trace(cfg, params, *, n_replicas=4, n_requests=16,
                    stall_s=2.0, seed=2) -> dict:
    """Freeze one replica mid-trace: hedged re-dispatch finishes its work
    on the others without waiting out the stall."""
    reqs = _trace(cfg, n_requests, seed=seed, gen_lo=8, gen_hi=16)
    inj = FaultInjector()
    inj.stall(0, at_step=2, duration_s=stall_s)
    fcfg = FleetConfig(n_replicas=n_replicas, max_queue=4 * n_requests,
                       hedge=True, hedge_stall_s=0.15)
    fleet, results, wall = _run_fleet(cfg, params, fcfg, reqs, injector=inj)
    row = _summarize(cfg, params, fleet, reqs, results, wall)
    row.update(n_replicas=n_replicas, stall_s=stall_s, chaos=inj.log,
               cancels=fleet.stats["cancels"])
    print(f"  stall {stall_s}s on 1/{n_replicas}: {row['completed']}/"
          f"{row['admitted']} completed, parity {row['stream_parity']}, "
          f"{row['hedges']} hedges, wall {row['wall_s']:.1f}s")
    return row


def run_admission(cfg, params, *, n_requests=10, seed=3) -> dict:
    """Burst a single replica past its bounded queue: shed vs completed vs
    degraded counts (the graceful-degradation ledger)."""
    reqs = _trace(cfg, n_requests, seed=seed)
    fcfg = FleetConfig(n_replicas=1, max_queue=max(4, n_requests // 2),
                       degrade_cap=4, hedge=False)
    fleet, results, wall = _run_fleet(cfg, params, fcfg, reqs)
    row = _summarize(cfg, params, fleet, reqs, results, wall)
    print(f"  burst {n_requests} -> queue {fcfg.max_queue}: "
          f"{row['completed']} completed / {row['shed']} shed / "
          f"{row['degraded']} degraded, parity {row['stream_parity']}")
    return row


def run(arch: str = "gemma-2b", *, reduced: bool = True,
        counts=(1, 2, 4), n_requests: int = 16, seed: int = 0) -> dict:
    cfg = get_arch(arch, reduced=reduced)
    params = api.init(jax.random.PRNGKey(seed), cfg)

    banner("Fleet scaling — tok/s vs replica count")
    scaling = run_scaling(cfg, params, counts=counts,
                          n_requests=max(8, n_requests // 2), seed=seed)

    banner("TP scaling — per-replica tok/s vs shard count")
    tp_scaling = run_tp_scaling(cfg, params, counts=counts, seed=seed)

    banner("Chaos: kill one replica mid-trace")
    kill = run_kill_trace(cfg, params, n_replicas=max(counts),
                          n_requests=n_requests, seed=seed + 1)

    banner("Chaos: stall one replica mid-trace (hedged re-dispatch)")
    stall = run_stall_trace(cfg, params, n_replicas=max(counts),
                            n_requests=n_requests, seed=seed + 2)

    banner("Admission: bounded queue, shed + degraded mode")
    admission = run_admission(cfg, params, n_requests=max(8, n_requests // 2),
                              seed=seed + 3)

    return {
        "arch": arch,
        "reduced": reduced,
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "engine": {"max_slots": ECFG.max_slots, "page_size": ECFG.page_size,
                   "max_seq_len": ECFG.max_seq_len, "fused": ECFG.fused},
        "scaling": scaling,
        "tp_scaling": tp_scaling,
        "kill_trace": kill,
        "stall_trace": stall,
        "admission": admission,
    }


def _gate_trace(name: str, row: dict, failures: list) -> None:
    """The fleet survival contract for one chaos trace."""
    if row["completed"] < row["admitted"]:
        failures.append(
            f"{name}: {row['completed']}/{row['admitted']} admitted "
            f"requests completed (gate: 100%)"
        )
    if not row["stream_parity"]:
        failures.append(f"{name}: token streams diverged from solo generation")
    if row["surviving_replicas"] < 1:
        failures.append(f"{name}: no surviving replicas")
    if row["timeouts"]:
        failures.append(
            f"{name}: {row['timeouts']} deadline timeouts in a trace with "
            f"no deadlines set"
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--full-size", action="store_true", help="no --reduced config")
    ap.add_argument("--devices", type=int, default=0,
                    help="emulate N host devices (must be first jax init; "
                         "consumed before imports)")
    ap.add_argument("--quick", action="store_true", help="CI smoke shapes")
    ap.add_argument(
        "--check", action="store_true",
        help="exit non-zero unless both chaos traces complete 100% of "
             "admitted requests with >= 1 surviving replica and per-request "
             "token parity (CI fleet gates)",
    )
    args = ap.parse_args()

    kw = {}
    if args.quick:
        kw = dict(n_requests=8, counts=(1, 2, 4) if jax.device_count() >= 4
                  else (1, 2))

    res = run(args.arch, reduced=not args.full_size, **kw)
    save_json("BENCH_fleet", res)
    if args.check:
        failures: list = []
        _gate_trace("kill trace", res["kill_trace"], failures)
        _gate_trace("stall trace", res["stall_trace"], failures)
        adm = res["admission"]
        if not adm["stream_parity"]:
            failures.append("admission: degraded streams diverged from solo")
        if adm["completed"] + adm["shed"] + adm["timeouts"] < adm["n_requests"]:
            failures.append(
                f"admission: {adm['completed']} completed + {adm['shed']} "
                f"shed + {adm['timeouts']} timeouts < {adm['n_requests']} "
                f"submitted (requests lost)"
            )
        if any(r["tok_s"] <= 0 for r in res["scaling"]):
            failures.append("scaling: non-positive tok/s recorded")
        for r in res["tp_scaling"]:
            if not r["token_parity"]:
                failures.append(
                    f"tp_scaling: {r['n_shards']}-shard token stream "
                    f"diverged from solo generation"
                )
            if r["tok_s_per_replica"] <= 0:
                failures.append(
                    f"tp_scaling: non-positive tok/s at {r['n_shards']} shards"
                )
        if failures:
            for f in failures:
                print(f"  CHECK FAILED: {f}", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
