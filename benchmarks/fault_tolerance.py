"""Device-realistic fault tolerance: accuracy vs stuck-cell rate, fault-aware
remapping recovery, hot redeploy under load, and the endurance horizon.

Three experiments close the robustness loop around the serving stack:

  * **Fault curve** — deploy one checkpoint through pools with increasing
    per-cell stuck-at rates (heterogeneous yield: a fraction of crossbars
    are 8x-rate hotspots) and measure shadow-batch logit KL against the
    clean fp model, once with ``leveling="none"`` (chains land on crossbars
    in index order, hotspots included) and once with ``leveling="fault"``
    (the X-CHANGR-style remap in ``core/nonideal``: chains are steered to
    the crossbars whose stuck cells flip the fewest — and lowest-order —
    of their actual bits).  The pool carries 2x spare capacity, which is
    what makes remapping *able* to avoid hotspots — exactly the spare-tile
    provisioning argument of the remapping literature.
  * **Hot redeploy under load** — an engine serves a live trace from a
    crossbar-deployed checkpoint; mid-trace, the *next* checkpoint is
    programmed into the same wear-leveled pool's spare capacity and
    ``Engine.hot_swap``-ped in.  Reported: the programming pause (the
    latency spike a real deployment hides behind spare capacity), that
    every in-flight request completed, and that every token stream is
    bit-identical to solo generation on its own epoch's params.
  * **Endurance horizon** — successive checkpoints re-programmed through
    one lpt-leveled pool, recording ``PoolStats.exhaustion_horizon`` after
    each: the wear signal ``HealthMonitor`` turns into a redeploy trigger.

  PYTHONPATH=src python -m benchmarks.fault_tolerance [--quick] [--check]

Writes experiments/bench/BENCH_fault.json (schema: docs/benchmarks.md).
``--check`` exits non-zero if (a) fault-aware remapping recovers less than
half the KL degradation at the reference fault rate, or (b) the redeploy
trace drops a request or breaks stream parity — the CI robustness gates.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, save_json
from repro.configs import get_arch
from repro.core import nonideal, simulator
from repro.core.planner import (
    CrossbarSpec,
    PlannerConfig,
    build_deployment,
    deploy_params,
)
from repro.core.pool import CrossbarPool
from repro.launch.engine import Engine, EngineConfig, Request
from repro.launch.serve import generate
from repro.models import api
from repro.runtime.fault import FaultPolicy

SPEC = CrossbarSpec(rows=128, cols=10)
FAULT_KEY = jax.random.PRNGKey(42)  # one fault map per rate, shared by levelings


def _model(rate: float) -> nonideal.FaultModel:
    """Stuck-at model at ``rate`` total stuck cells/cell (split evenly
    stuck-at-0/1), with a 25% hotspot population at 8x the rate."""
    return nonideal.FaultModel(
        stuck0=rate / 2, stuck1=rate / 2,
        hotspot_fraction=0.25, hotspot_mult=8.0,
    )


def _deploy_through(params, pcfg, *, leveling: str, rate: float):
    """Deploy ``params`` through a fresh 2x-spare-capacity pool with the
    rate's fault map injected; returns (dense params_hat, pool)."""
    pool = CrossbarPool(SPEC, 2 * pcfg.crossbars, leveling=leveling)
    if rate > 0.0:
        pool.inject_faults(_model(rate), FAULT_KEY)
    plan = build_deployment(params, SPEC, pcfg, pool=pool)
    return deploy_params(params, plan, materialize="dense"), pool


def run_fault_curve(
    cfg, params, *, rates, pcfg, batch_size=2, shadow_len=16, seed=0,
) -> list[dict]:
    """Shadow-batch logit KL (vs clean fp params) per fault rate, for the
    naive and the fault-aware chain->crossbar assignment."""
    batch = api.make_batch(cfg, jax.random.PRNGKey(seed), batch_size, shadow_len)
    f = lambda p, b: api.forward(p, cfg, b)[0]  # noqa: E731
    curve = []
    for rate in rates:
        row = {"rate": rate}
        for leveling in ("none", "fault"):
            params_hat, pool = _deploy_through(
                params, pcfg, leveling=leveling, rate=rate
            )
            kl = float(simulator.logit_kl(f, params, params_hat, batch))
            row[f"kl_{leveling}"] = kl
            if pool.faults is not None:
                row["stuck_cells"] = int(pool.faults.fault_cells().sum())
                row["hotspots"] = int(pool.faults.hot.sum())
        curve.append(row)
        print(f"  rate {rate:7.4f}   kl none {row['kl_none']:.5f}   "
              f"kl fault-aware {row['kl_fault']:.5f}"
              + (f"   ({row.get('stuck_cells', 0)} stuck cells)" if rate else ""))
    return curve


def recovery_fraction(curve: list[dict], ref_rate: float) -> float:
    """Fraction of the fault-induced KL degradation (above the zero-fault
    quantization floor) that fault-aware remapping removes at ``ref_rate``."""
    floor = next(r["kl_none"] for r in curve if r["rate"] == 0.0)
    ref = next(r for r in curve if r["rate"] == ref_rate)
    degradation = ref["kl_none"] - floor
    if degradation <= 0:
        return 1.0  # nothing to recover
    return (ref["kl_none"] - ref["kl_fault"]) / degradation


def run_hot_redeploy(
    cfg, params_a, params_b, *, pcfg, n_requests=6, seed=0,
) -> dict:
    """Serve a trace from checkpoint A (crossbar-deployed); mid-trace,
    program checkpoint B into the same pool's spare capacity and hot-swap.
    Every request must complete with a stream bit-identical to solo
    generation on its admission epoch's params."""
    pool = CrossbarPool(SPEC, 2 * pcfg.crossbars, leveling="lpt")
    plan_a = build_deployment(params_a, SPEC, pcfg, pool=pool)
    served_a = deploy_params(params_a, plan_a, materialize="dense")

    ecfg = EngineConfig(
        max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=8,
        decode_quantum=4,
    )
    eng = Engine(cfg, served_a, ecfg)
    rng = np.random.default_rng(seed)
    reqs = [
        Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(6, 14))).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 9)), greedy=True, seed=i,
        )
        for i in range(n_requests)
    ]
    pre, post = reqs[: n_requests // 2], reqs[n_requests // 2 :]
    for r in pre:
        eng.submit(r)

    now, step_walls = 0.0, []
    while not any(s is not None and s.generated for s in eng.slots):
        t0 = time.perf_counter()
        eng.step(now)
        step_walls.append(time.perf_counter() - t0)
        now += 1e-3

    def prepare_b():
        """Program checkpoint B through the pool (spare capacity) — the
        blocking work ``hot_swap`` prices; wear accumulates on the same
        physical cells the horizon tracks."""
        plan_b = build_deployment(params_b, SPEC, pcfg, pool=pool)
        return deploy_params(params_b, plan_b, materialize="dense")

    horizon_before = pool.stats().exhaustion_horizon()
    t0 = time.perf_counter()
    swapped = eng.hot_swap(prepare_b, policy=FaultPolicy(max_retries=1))
    swap_pause = time.perf_counter() - t0
    horizon_after = pool.stats().exhaustion_horizon()
    served_b = eng.params  # the prepared tree the swap installed

    for r in post:
        eng.submit(r)
    while eng.waiting or any(s is not None for s in eng.slots):
        t0 = time.perf_counter()
        eng.step(now)
        step_walls.append(time.perf_counter() - t0)
        now += 1e-3

    def _solo(params, req):
        toks, _ = generate(
            cfg, params, {"tokens": jnp.asarray(req.prompt)[None]},
            gen_len=req.max_new_tokens, greedy=req.greedy, seed=req.seed,
        )
        return [int(t) for t in np.asarray(toks[0])]

    parity = all(
        eng.results[r.rid].tokens == _solo(served_a, r) for r in pre
    ) and all(
        eng.results[r.rid].tokens == _solo(served_b, r) for r in post
    )
    return {
        "n_requests": n_requests,
        "completed": len(eng.results),
        "swapped": bool(swapped),
        "stream_parity": bool(parity),
        "swap_pause_s": swap_pause,
        "median_step_s": float(np.median(step_walls)),
        "pause_vs_step": swap_pause / max(float(np.median(step_walls)), 1e-9),
        "hot_swaps": eng.stats["hot_swaps"],
        "epochs_retired": eng.stats["epochs_retired"],
        "horizon_before": horizon_before,
        "horizon_after": horizon_after,
    }


def run_endurance(cfg, *, pcfg, n_deploys=3, endurance=1e4, seed=0) -> dict:
    """Successive checkpoints through ONE lpt pool: the horizon trajectory
    ``HealthMonitor`` watches (redeploy recommended once it crosses
    ``min_horizon``)."""
    pool = CrossbarPool(SPEC, pcfg.crossbars, leveling="lpt")
    horizons, max_writes = [], []
    for i in range(n_deploys):
        params_i = api.init(jax.random.PRNGKey(seed + i), cfg)
        build_deployment(params_i, SPEC, pcfg, pool=pool)
        stats = pool.stats()
        horizons.append(stats.exhaustion_horizon(endurance))
        max_writes.append(stats.max_cell_writes)
    return {
        "n_deploys": n_deploys,
        "endurance": endurance,
        "horizons": horizons,
        "max_cell_writes": max_writes,
    }


def run(
    arch: str = "gemma-2b",
    *,
    reduced: bool = True,
    rates=(0.0, 5e-4, 2e-3, 8e-3),
    ref_rate: float = 2e-3,
    n_requests: int = 6,
    n_deploys: int = 3,
    seed: int = 0,
) -> dict:
    cfg = get_arch(arch, reduced=reduced)
    params_a = api.init(jax.random.PRNGKey(seed), cfg)
    params_b = api.init(jax.random.PRNGKey(seed + 1), cfg)
    pcfg = PlannerConfig(p_stuck=0.5, min_size=1024)

    banner("Fault curve — logit KL vs stuck-cell rate, naive vs fault-aware")
    curve = run_fault_curve(cfg, params_a, rates=rates, pcfg=pcfg, seed=seed)
    recovery = recovery_fraction(curve, ref_rate)
    print(f"  remapping recovers {100 * recovery:.1f}% of the KL degradation "
          f"at rate {ref_rate} (2x spare capacity)")

    banner("Hot redeploy under load — program spare capacity, swap, drain")
    redeploy = run_hot_redeploy(
        cfg, params_a, params_b, pcfg=pcfg, n_requests=n_requests, seed=seed
    )
    print(f"  {redeploy['completed']}/{redeploy['n_requests']} completed, "
          f"stream parity {redeploy['stream_parity']}, "
          f"swap pause {redeploy['swap_pause_s'] * 1e3:.0f} ms "
          f"({redeploy['pause_vs_step']:.1f}x a median serve step)")

    banner("Endurance horizon — successive redeploys through one pool")
    endur = run_endurance(cfg, pcfg=pcfg, n_deploys=n_deploys, seed=seed)
    print("  horizon after each deploy: "
          + ", ".join(f"{h:.3g}" for h in endur["horizons"])
          + f"  (@ {endur['endurance']:.0e} writes/cell)")

    return {
        "arch": arch,
        "reduced": reduced,
        "backend": jax.default_backend(),
        "spec": {"rows": SPEC.rows, "cols": SPEC.cols},
        "planner": {"p_stuck": pcfg.p_stuck, "min_size": pcfg.min_size,
                    "crossbars": pcfg.crossbars, "spare_factor": 2},
        "fault_model": {"hotspot_fraction": 0.25, "hotspot_mult": 8.0,
                        "split": "stuck0/stuck1 even"},
        "fault_curve": curve,
        "ref_rate": ref_rate,
        "recovery_at_ref": recovery,
        "redeploy": redeploy,
        "endurance": endur,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--full-size", action="store_true", help="no --reduced config")
    ap.add_argument("--quick", action="store_true", help="CI smoke shapes")
    ap.add_argument(
        "--check", action="store_true",
        help="exit non-zero if remapping recovers < half the KL degradation "
             "at the reference rate, or the redeploy trace drops a request "
             "or breaks stream parity (CI robustness gates)",
    )
    args = ap.parse_args()

    kw = {}
    if args.quick:
        kw = dict(rates=(0.0, 2e-3), ref_rate=2e-3, n_requests=4, n_deploys=2)

    res = run(args.arch, reduced=not args.full_size, **kw)
    save_json("BENCH_fault", res)
    if args.check:
        failures = []
        if res["recovery_at_ref"] < 0.5:
            failures.append(
                f"fault-aware remapping recovered only "
                f"{100 * res['recovery_at_ref']:.1f}% of KL degradation at "
                f"rate {res['ref_rate']} (gate: >= 50%)"
            )
        rd = res["redeploy"]
        if rd["completed"] < rd["n_requests"] or not rd["swapped"]:
            failures.append(
                f"redeploy dropped requests: {rd['completed']}/"
                f"{rd['n_requests']} completed (swapped={rd['swapped']})"
            )
        if not rd["stream_parity"]:
            failures.append("token streams diverged from per-epoch solo generation")
        if failures:
            for f in failures:
                print(f"  CHECK FAILED: {f}", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
