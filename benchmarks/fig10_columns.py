"""Paper Fig. 10 — sweeping crossbar columns (bitwidth) at p=0.5.

Speedup (p=1 over p=0.5 on the SWS stride-1 schedule) stays ~constant with
the column count, while accuracy collapses below ~8-10 columns because the
stuck LSB is a large fraction of the weight at low bitwidths and quantization
itself bites.  Paper: accuracy plateaus at 10 columns (78.00% ViT-Base,
80.31% ResNet-50 — their ImageNet numbers; ours is the trained-LM analogue).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import banner, model_planes, save_json
from benchmarks.trained_lm import eval_accuracy, get_trained_lm
from repro.core import schedule, stucking
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment, deploy_params

ROWS = 128
L_CROSSBARS = 16
COLS_SWEEP = (4, 6, 8, 10, 12, 14, 16)
P = 0.5


def transitions_sweep(models=("vit-base", "resnet50"), *, max_elems=2_000_000, seed=0):
    # The exact stochastic stucking walk is sequential over sections; cap the
    # per-tensor sample harder than the other figures (transitions are a
    # per-element statistic, so a uniform subsample is unbiased; --full lifts).
    max_elems = min(max_elems, 500_000) if max_elems else 0
    out = {}
    key = jax.random.PRNGKey(seed)
    for m in models:
        entry = {}
        for cols in COLS_SWEEP:
            planes = model_planes(m, cols=cols, sort=True, max_elems=max_elems, seed=seed)
            chains = schedule.stride_1_chains(planes.shape[0], L_CROSSBARS)
            key, k1, k2 = jax.random.split(key, 3)
            t1, _ = stucking.stuck_schedule(planes, chains, 1.0, k1)
            tp, _ = stucking.stuck_schedule(planes, chains, P, k2)
            entry[str(cols)] = {
                "transitions_p1": int(t1),
                "transitions_p": int(tp),
                "speedup_p1_over_p": int(t1) / max(int(tp), 1),
            }
        out[m] = entry
    return out


def accuracy_sweep(seed=0):
    cfg, params, batch_fn = get_trained_lm(seed=seed)
    acc_fp = eval_accuracy(cfg, params, batch_fn)
    out = {"fp_accuracy": acc_fp, "per_cols": {}}
    for cols in COLS_SWEEP:
        plan = build_deployment(
            params, CrossbarSpec(rows=ROWS, cols=cols),
            PlannerConfig(p_stuck=P, min_size=1024, seed=seed),
        )
        acc = eval_accuracy(cfg, deploy_params(params, plan), batch_fn)
        out["per_cols"][str(cols)] = {
            "accuracy": acc,
            "drop_pct": 100.0 * (acc_fp - acc),
        }
    return out


def run(*, max_elems=2_000_000, seed=0) -> dict:
    return {
        "transitions": transitions_sweep(max_elems=max_elems, seed=seed),
        "accuracy": accuracy_sweep(seed=seed),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    banner(f"Fig. 10 — column sweep at p={P}")
    res = run(max_elems=0 if args.full else 2_000_000)
    for m, entry in res["transitions"].items():
        sp = "  ".join(f"{c}:{v['speedup_p1_over_p']:.2f}x" for c, v in entry.items())
        print(f"  {m:10s} {sp}")
    acc = res["accuracy"]
    print(f"  trained-LM fp accuracy: {acc['fp_accuracy']:.4f}")
    for c, r in acc["per_cols"].items():
        print(f"    cols={c:>2s}: acc={r['accuracy']:.4f} (drop {r['drop_pct']:+.2f}%)")
    save_json("fig10_columns", res)


if __name__ == "__main__":
    main()
