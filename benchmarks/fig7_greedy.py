"""Paper Fig. 7 — greedy similar-cost grouping with 64 programming threads.

Lockstep-rounds model (§III.C): each round programs one crossbar per thread
and lasts as long as its slowest job.  Unsorted arrival order mixes small
and large jobs per round (VGGs suffer most — disparate layer magnitudes);
the greedy sort groups similar costs and approaches the ideal 64x.
"""
from __future__ import annotations

import argparse

from benchmarks.common import PAPER_DEFAULT_MODELS, banner, model_planes, save_json
from repro.core import schedule

COLS = 10
THREADS = 64


def run(models=None, *, max_elems=2_000_000, seed=0) -> dict:
    models = models or PAPER_DEFAULT_MODELS
    results = {}
    for m in models:
        planes = model_planes(m, cols=COLS, sort=True, max_elems=max_elems, seed=seed)
        s = planes.shape[0]
        chains = schedule.stride_1_chains(s, THREADS)
        jobs = schedule.schedule_job_costs(planes, chains)
        sp_u = float(schedule.lockstep_speedup(jobs, THREADS, sort_jobs=False))
        sp_g = float(schedule.lockstep_speedup(jobs, THREADS, sort_jobs=True))
        results[m] = {
            "n_jobs": int(jobs.shape[0]),
            "speedup_unsorted": sp_u,
            "speedup_greedy": sp_g,
            "ideal": float(THREADS),
        }
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    banner(f"Fig. 7 — greedy thread balancing ({THREADS} threads)")
    res = run(max_elems=0 if args.full else 2_000_000)
    for m, r in res.items():
        print(
            f"  {m:12s} unsorted={r['speedup_unsorted']:5.1f}x  "
            f"greedy={r['speedup_greedy']:5.1f}x  (ideal {THREADS}x)"
        )
    save_json("fig7_greedy", res)


if __name__ == "__main__":
    main()
