"""Pool wear benchmark: persistent crossbar pool + wear-leveling assignment.

Streams a sequence of model deployments (checkpoints of the reduced gemma-2b
architecture, drifting between deployments) through ONE persistent
``CrossbarPool`` per leveling policy and reports physical per-cell wear:
max/mean cell writes, per-crossbar imbalance, and the endurance-budget
exhaustion horizon.  The headline number is how much the LPT wear-leveling
chain->crossbar assignment reduces *max-cell* wear versus the naive identity
assignment — max-cell wear is what kills a crossbar array first.

  PYTHONPATH=src python -m benchmarks.pool_wear [--deployments N]

Writes experiments/bench/BENCH_pool.json.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, banner, save_json
from repro.configs import get_arch
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment
from repro.core.pool import DEFAULT_ENDURANCE, LEVELINGS, CrossbarPool
from repro.models import api

ARCH = "gemma-2b"
DRIFT = 0.02  # relative weight drift between successive deployments


def _checkpoints(n: int, seed: int):
    """The same reduced-gemma param tree, drifting like training checkpoints."""
    cfg = get_arch(ARCH, reduced=True)
    params = api.init(jax.random.PRNGKey(seed), cfg)
    key = jax.random.PRNGKey(seed + 1)
    for _ in range(n):
        yield params
        key, sub = jax.random.split(key)
        leaves, treedef = jax.tree_util.tree_flatten(params)
        subs = jax.random.split(sub, len(leaves))
        leaves = [
            w + DRIFT * jnp.std(w) * jax.random.normal(k, w.shape)
            if hasattr(w, "shape") and w.ndim >= 2 else w
            for w, k in zip(leaves, subs)
        ]
        params = jax.tree_util.tree_unflatten(treedef, leaves)


def run(*, deployments: int = 3, p_stuck: float = 0.5, seed: int = 0) -> dict:
    spec = CrossbarSpec(rows=128, cols=10)
    results: dict[str, dict] = {}
    for leveling in LEVELINGS:
        cfg = PlannerConfig(
            p_stuck=p_stuck, min_size=1024, pool_leveling=leveling
        )
        pool = CrossbarPool(spec, cfg.crossbars, leveling=leveling)
        with Timer() as t:
            for params in _checkpoints(deployments, seed):
                build_deployment(params, spec, cfg, pool=pool)
        stats = pool.stats()
        per_xbar = pool.wear_totals()
        results[leveling] = {
            **stats.to_dict(DEFAULT_ENDURANCE),
            # exhaustion_horizon counts repeats of the whole observed history
            # (here: `deployments` deployments) — convert to deployments
            "exhaustion_horizon_deployments": stats.exhaustion_horizon(DEFAULT_ENDURANCE)
            * deployments,
            "crossbar_imbalance": float(per_xbar.max() / max(per_xbar.mean(), 1.0)),
            "seconds": t.seconds,
        }
    none_max = results["none"]["max_cell_writes"]
    lpt_max = results["lpt"]["max_cell_writes"]
    return {
        "arch": f"{ARCH} (reduced)",
        "backend": jax.default_backend(),
        "deployments": deployments,
        "drift": DRIFT,
        "p_stuck": p_stuck,
        "endurance": DEFAULT_ENDURANCE,
        "levelings": results,
        "max_wear_reduction_lpt_vs_none": none_max / max(lpt_max, 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--deployments", type=int, default=3)
    ap.add_argument("--p-stuck", type=float, default=0.5)
    args = ap.parse_args()

    banner("Pool wear — persistent crossbar pool + wear leveling")
    r = run(deployments=args.deployments, p_stuck=args.p_stuck)
    for lev, s in r["levelings"].items():
        print(
            f"  {lev:7s} max_cell={s['max_cell_writes']:8d}  "
            f"mean={s['mean_cell_writes']:8.1f}  imbalance={s['crossbar_imbalance']:.3f}  "
            f"horizon={s['exhaustion_horizon_deployments']:.3g} deployments"
        )
    print(f"  LPT leveling reduces max-cell wear {r['max_wear_reduction_lpt_vs_none']:.2f}x")
    save_json("BENCH_pool", r)


if __name__ == "__main__":
    main()
