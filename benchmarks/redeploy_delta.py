"""Beyond-paper: checkpoint-to-checkpoint redeploy pricing (core.redeploy).

Trains the shared reduced LM a further K steps past its cached state and
prices reprogramming the deployed crossbars from the old weights to the new
ones, in natural vs SWS layouts.  The paper prices streaming a *fixed*
model; this extends the same Eq.-1 accounting to training-time refresh.
"""
from __future__ import annotations

import jax

from benchmarks.common import banner, save_json
from benchmarks.trained_lm import get_trained_lm
from repro.core.redeploy import delta_cost
from repro.data import DataConfig, make_dataset
from repro.launch.steps import make_train_step
from repro.optim import AdamWConfig, adamw_init


def run(*, extra_steps: int = 20, seed: int = 0) -> dict:
    cfg, params_old, _ = get_trained_lm(seed=seed)
    ds = make_dataset(DataConfig(cfg.vocab_size, 64, 8, task="copy", seed=seed))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=extra_steps)))
    params, opt = params_old, adamw_init(params_old)
    for s in range(extra_steps):
        params, opt, _ = step(params, opt, ds.batch_at(20_000 + s))

    flat_old, _ = jax.tree_util.tree_flatten_with_path(params_old)
    flat_new, _ = jax.tree_util.tree_flatten_with_path(params)
    out = {}
    for (po, lo), (pn, ln) in zip(flat_old, flat_new):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in po)
        if lo.ndim < 2 or lo.size < 4096 or "embed" in name:
            continue
        rep = delta_cost(lo, ln, name=name)
        out[name] = {
            "inplace_natural": rep.transitions_natural,
            "inplace_sws": rep.transitions_sws,  # == natural (perm-invariant sanity)
            "chain_natural": rep.chain_natural,
            "chain_stale_sws": rep.chain_stale_sws,
            "chain_fresh_sws": rep.chain_fresh_sws,
            "stale_sort_speedup": rep.stale_sort_speedup,
            "fresh_sort_speedup": rep.fresh_sort_speedup,
            "n_bits": rep.n_bits,
        }
        if len(out) >= 4:
            break
    return {"extra_steps": extra_steps, "tensors": out}


def main() -> None:
    banner("Redeploy delta pricing (beyond-paper)")
    res = run()
    for k, v in res["tensors"].items():
        print(f"  {k}: stale-sort {v['stale_sort_speedup']:.2f}x vs fresh {v['fresh_sort_speedup']:.2f}x "
              f"(in-place rewrite invariant: {v['inplace_natural']}=={v['inplace_sws']})")
    save_json("redeploy_delta", res)


if __name__ == "__main__":
    main()
