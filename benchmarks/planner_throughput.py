"""Planner throughput: packed jitted fast path vs the seed bool path.

Prices a gemma-2b-scale weight pytree end-to-end with ``build_deployment``
twice — ``PlannerConfig(impl="packed")`` (canonical packed planes, batched
pair pricing, shape-bucketed jit) and ``PlannerConfig(impl="bool")`` (the
seed implementation: eager bool planes, per-chain Python loops) — verifies
the two plans are bit-exact, and reports the wall-clock speedup.

Tensor shapes are gemma-2b's per-layer matmuls (repeated across layers, so
the fast path's shape-bucketed jit cache is exercised the way a real LM
deployment exercises it); per-tensor elements are capped at ``max_elems``
like every other benchmark here (transitions are a per-element statistic, so
a uniform subsample is unbiased — see ``benchmarks.common``).

  PYTHONPATH=src python -m benchmarks.planner_throughput [--full] [--layers N]

Writes experiments/bench/BENCH_planner.json.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, _lm_layer_shapes, banner, save_json
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment

ARCH = "gemma-2b"


def gemma_scale_params(
    *, max_elems: int = 750_000, layers: int | None = None, seed: int = 0
) -> dict:
    """Weight pytree with gemma-2b layer shapes (rows truncated to the cap)."""
    from repro.configs import get_arch

    shapes = _lm_layer_shapes(ARCH)
    n_layers = layers if layers is not None else get_arch(ARCH).n_layers
    key = jax.random.PRNGKey(seed)
    params: dict = {}
    for i in range(n_layers):
        layer = {}
        for j, (d_out, d_in) in enumerate(shapes):
            rows = d_out if not max_elems else max(1, min(d_out, max_elems // d_in))
            key, sub = jax.random.split(key)
            layer[f"w{j}_{d_out}x{d_in}"] = (
                jax.random.normal(sub, (rows, d_in)) * (2.0 / d_in) ** 0.5
            )
        params[f"layer_{i:02d}"] = layer
    return params


def run(max_elems: int = 750_000, layers: int | None = 6, p_stuck: float = 0.5) -> dict:
    spec = CrossbarSpec(rows=128, cols=10)
    params = gemma_scale_params(max_elems=max_elems, layers=layers)
    n_elems = sum(int(w.size) for l in params.values() for w in l.values())

    results = {}
    for impl in ("packed", "bool"):
        cfg = PlannerConfig(p_stuck=p_stuck, min_size=1024, impl=impl)
        with Timer() as t:
            plan = build_deployment(params, spec, cfg)
        results[impl] = {"seconds": t.seconds, "plan": plan}

    pp, bp = results["packed"]["plan"], results["bool"]["plan"]
    bit_exact = set(pp.reports) == set(bp.reports) and all(
        pp.reports[k].transitions_baseline == bp.reports[k].transitions_baseline
        and pp.reports[k].transitions_sws == bp.reports[k].transitions_sws
        and pp.reports[k].transitions_final == bp.reports[k].transitions_final
        and pp.reports[k].lockstep_time_greedy == bp.reports[k].lockstep_time_greedy
        and pp.reports[k].lockstep_time_ideal == bp.reports[k].lockstep_time_ideal
        and bool(jnp.all(pp.deployed[k] == bp.deployed[k]))
        for k in pp.reports
    )

    t_packed = results["packed"]["seconds"]
    t_bool = results["bool"]["seconds"]
    return {
        "arch": ARCH,
        "backend": jax.default_backend(),
        "layers": len(params),
        "n_tensors": len(pp.reports),
        "n_elements": n_elems,
        "max_elems": max_elems,
        "p_stuck": p_stuck,
        "time_packed_s": t_packed,
        "time_bool_s": t_bool,
        "speedup": t_bool / max(t_packed, 1e-9),
        "bit_exact": bit_exact,
        "totals": pp.totals(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="all layers, 2M-element cap")
    ap.add_argument("--layers", type=int, default=None)
    args = ap.parse_args()
    layers = args.layers if args.layers is not None else (None if args.full else 6)
    max_elems = 2_000_000 if args.full else 750_000

    banner("Planner throughput — packed fast path vs seed bool path")
    r = run(max_elems=max_elems, layers=layers)
    print(
        f"  {r['arch']} x{r['layers']} layers ({r['n_tensors']} tensors, "
        f"{r['n_elements']/1e6:.1f}M weights) on {r['backend']}"
    )
    print(
        f"  packed {r['time_packed_s']:.2f}s  bool {r['time_bool_s']:.2f}s  "
        f"-> {r['speedup']:.2f}x  bit_exact={r['bit_exact']}"
    )
    save_json("BENCH_planner", r)


if __name__ == "__main__":
    main()
