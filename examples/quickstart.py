"""Quickstart: price a crossbar deployment of an LM in ~20 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_arch
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment
from repro.models import api

# 1. any model = any pytree of weights; here a reduced assigned architecture
cfg = get_arch("internlm2-1.8b", reduced=True)
params = api.init(jax.random.PRNGKey(0), cfg)

# 2. plan the deployment: quantize -> bit-slice -> SWS -> stride-1 schedule
#    across 16 crossbars -> 64-thread balancing -> bit stucking at p=0.5
plan = build_deployment(
    params,
    CrossbarSpec(rows=128, cols=10),
    PlannerConfig(schedule="stride1", crossbars=16, threads=64, p_stuck=0.5,
                  min_size=1024),
)

# 3. read the report
t = plan.totals()
print(f"tensors deployed       : {len(plan.reports)}")
print(f"baseline transitions   : {t['transitions_baseline']:,}")
print(f"after SWS              : {t['transitions_sws']:,}  ({t['sws_speedup']:.2f}x)")
print(f"after SWS + stucking   : {t['transitions_final']:,}  ({t['total_speedup']:.2f}x)")
print(f"64-thread greedy       : {t['lockstep_speedup_greedy']:.1f}x of ideal 64x")
for name, r in list(plan.reports.items())[:3]:
    print(f"  {name:32s} {r.shape!s:14s} sws={r.sws_speedup:.2f}x total={r.total_speedup:.2f}x")
