"""Full paper pipeline on one architecture, step by step.

Walks every §III/§IV mechanism explicitly — sorting, sectioning, schedule
choice, thread balancing, bit stucking — and prints the cost breakdown each
stage contributes, ending with the fidelity probes of the deployed model.

  PYTHONPATH=src python examples/deploy_crossbar.py [--arch gemma-2b] [--p 0.5]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import bitslice, cost, schedule, stucking, sws
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment, deploy_params
from repro.core.simulator import logit_kl, top1_agreement
from repro.models import api


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--p", type=float, default=0.5)
    ap.add_argument("--rows", type=int, default=128)
    ap.add_argument("--cols", type=int, default=10)
    ap.add_argument("--crossbars", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)

    # ---- stage 1: one tensor, unsorted vs SWS (paper Fig. 2/5) -------------
    flat = jnp.ravel(params["segments"][0]["mlp"]["wi_gate"][0])
    qt = bitslice.quantize(flat, args.cols)
    pad = (-flat.shape[0]) % args.rows
    q = jnp.pad(qt.q, (0, pad))
    planes_u = bitslice.bitplanes(q.reshape(-1, args.rows), args.cols)
    perm = sws.sws_permutation(jnp.pad(flat, (0, pad)))
    planes_s = bitslice.bitplanes(q[perm].reshape(-1, args.rows), args.cols)
    t_u, t_s = int(cost.chain_transitions(planes_u)), int(cost.chain_transitions(planes_s))
    print(f"[1] single tensor {flat.shape[0]} weights, single crossbar:")
    print(f"    unsorted={t_u:,}  SWS={t_s:,}  speedup={t_u / t_s:.2f}x")

    # ---- stage 2: schedules (paper Fig. 3/6) --------------------------------
    s = planes_s.shape[0]
    for kind in ("strideL", "stride1"):
        chains = schedule.make_chains(s, args.crossbars, kind)
        t = int(schedule.schedule_transitions(planes_s, chains))
        print(f"[2] {kind:8s} over {args.crossbars} crossbars: transitions={t:,} "
              f"({t_u / t:.2f}x vs unsorted)")

    # ---- stage 3: thread balancing (paper Fig. 4/7) -------------------------
    chains = schedule.stride_1_chains(s, args.crossbars)
    jobs = schedule.schedule_job_costs(planes_s, chains)
    for sort_jobs, label in ((False, "arrival order"), (True, "greedy sorted")):
        sp = float(schedule.lockstep_speedup(jobs, 64, sort_jobs=sort_jobs))
        print(f"[3] 64-thread lockstep, {label:13s}: {sp:.1f}x (ideal 64x)")

    # ---- stage 4: bit stucking (paper Fig. 8/9) ------------------------------
    for p in (1.0, args.p, 0.0):
        t, _ = stucking.stuck_schedule(planes_s, chains, p, key)
        print(f"[4] bit stucking p={p:4.2f}: transitions={int(t):,}")

    # ---- stage 5: whole-model deployment + fidelity --------------------------
    plan = build_deployment(
        params, CrossbarSpec(rows=args.rows, cols=args.cols),
        PlannerConfig(p_stuck=args.p, crossbars=args.crossbars, min_size=1024),
    )
    t = plan.totals()
    print(f"[5] whole model: {len(plan.reports)} tensors, "
          f"sws={t['sws_speedup']:.2f}x total={t['total_speedup']:.2f}x")

    params_hat = deploy_params(params, plan)
    batch = api.make_batch(cfg, key, 2, 32)
    f = lambda p, b: api.forward(p, cfg, b)[0]
    print(f"    top1 agreement={float(top1_agreement(f, params, params_hat, batch)):.4f}  "
          f"logit KL={float(logit_kl(f, params, params_hat, batch)):.2e}")


if __name__ == "__main__":
    main()
