"""End-to-end training example: train an LM for a few hundred steps with the
full production control plane (checkpoint/restart, retries, stragglers,
crossbar redeploy pricing), then deploy the trained weights to crossbars and
verify the paper's accuracy-preservation constraint.

  PYTHONPATH=src python examples/train_lm.py                  # reduced, CPU
  PYTHONPATH=src python examples/train_lm.py --arch yi-6b     # full config
                                                              # (TPU-scale)
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment, deploy_params
from repro.data import DataConfig, make_dataset
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import FaultPolicy, TrainLoop, TrainLoopConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (TPU-scale) config instead of reduced")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=not args.full_config)
    print(f"arch={cfg.name} reduced={not args.full_config} steps={args.steps}")

    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat="none"))
    ds = make_dataset(DataConfig(cfg.vocab_size, args.seq, args.batch, task="copy"))

    def init_state():
        params = api.init(jax.random.PRNGKey(0), cfg)
        return params, adamw_init(params)

    loop = TrainLoop(
        cfg,
        TrainLoopConfig(
            total_steps=args.steps, checkpoint_every=50,
            checkpoint_dir=args.ckpt_dir, log_every=20, redeploy_every=100,
        ),
        train_step=step_fn,
        init_state=init_state,
        dataset=ds,
        fault=FaultPolicy(max_retries=2),
    )
    result = loop.run()
    for rec in result["metrics_log"]:
        print(f"  step {rec['step']:5d}  loss {rec['loss']:.4f}  wall {rec['wall_s']:.3f}s")
    for rec in result["redeploy_log"]:
        print(f"  redeploy@{rec['step']}: {rec['tensor']} inplace={rec['transitions_natural']} "
              f"stale-sort streaming {rec['stale_sort_speedup']:.2f}x")

    # deploy the trained model to crossbars; check accuracy preservation
    params = loop.params
    plan = build_deployment(
        params, CrossbarSpec(rows=128, cols=10), PlannerConfig(p_stuck=0.5, min_size=1024)
    )
    params_hat = deploy_params(params, plan)
    batch = ds.batch_at(10_000)
    la, _ = api.forward(params, cfg, batch)
    lb, _ = api.forward(params_hat, cfg, batch)
    pred_a = jnp.argmax(la[:, :-1], -1) == batch["tokens"][:, 1:]
    pred_b = jnp.argmax(lb[:, :-1], -1) == batch["tokens"][:, 1:]
    acc_a, acc_b = float(jnp.mean(pred_a)), float(jnp.mean(pred_b))
    t = plan.totals()
    print(f"\ncrossbar deployment: {t['total_speedup']:.2f}x fewer transitions "
          f"(sws {t['sws_speedup']:.2f}x)")
    print(f"task accuracy fp={acc_a:.4f} cim={acc_b:.4f} (drop {100*(acc_a-acc_b):+.2f}%)"
          f" -> paper constraint (<1%): {'PASS' if acc_a - acc_b < 0.01 else 'FAIL'}")


if __name__ == "__main__":
    main()
