"""Serving example: batched prefill+decode with crossbar-deployed weights.

The end-to-end inference driver the paper's kind dictates: a small model
serves batched requests twice — once with fp weights, once with the
quantized + bit-stuck weights a CIM accelerator would actually hold — and
reports throughput, token agreement, and the reprogramming savings.

  PYTHONPATH=src python examples/serve_cim.py [--arch yi-6b] [--batch 8]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment, deploy_params
from repro.launch.serve import generate
from repro.models import api


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--p-stuck", type=float, default=0.5)
    ap.add_argument(
        "--materialize", default="packed",
        choices=["dense", "packed", "planes_int8"],
        help="serving representation of the deployed weights",
    )
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, args.batch, args.prompt_len)

    toks_fp, tps_fp = generate(cfg, params, batch, gen_len=args.gen)
    print(f"fp serve : {tps_fp:8.1f} tok/s")

    plan = build_deployment(
        params, CrossbarSpec(rows=128, cols=10),
        PlannerConfig(p_stuck=args.p_stuck, min_size=1024),
    )
    params_cim = deploy_params(params, plan, materialize=args.materialize)
    toks_cim, tps_cim = generate(cfg, params_cim, batch, gen_len=args.gen)
    agree = float(jnp.mean((toks_fp == toks_cim).astype(jnp.float32)))
    t = plan.totals()
    print(f"cim serve: {tps_cim:8.1f} tok/s ({args.materialize})   token agreement={agree:.3f}")
    print(f"reprogramming: sws={t['sws_speedup']:.2f}x total={t['total_speedup']:.2f}x "
          f"({t['transitions_baseline']:,} -> {t['transitions_final']:,} transitions)")


if __name__ == "__main__":
    main()
