"""Live hot redeploy: epoch-pinned serving params, atomic swap between
dispatches, retry/rollback via runtime.fault, and the health monitor that
closes the production loop (degradation / wear-horizon triggered).

The pinned contract: a request's entire token stream is computed under the
param epoch it was admitted with — a ``hot_swap`` mid-flight never changes
any in-flight request's tokens (bit-identical to solo generation on its
epoch's params), while requests admitted after the swap serve the new tree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.planner import CrossbarSpec
from repro.core.pool import CrossbarPool
from repro.launch.engine import (
    Engine,
    EngineConfig,
    HealthConfig,
    HealthMonitor,
    Request,
)
from repro.launch.serve import generate
from repro.models import api
from repro.runtime.fault import FaultPolicy

ECFG = EngineConfig(
    max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=8, decode_quantum=4
)


@pytest.fixture(scope="module")
def gemma():
    cfg = get_arch("gemma-2b", reduced=True)
    params0 = api.init(jax.random.PRNGKey(0), cfg)
    params1 = api.init(jax.random.PRNGKey(1), cfg)
    return cfg, params0, params1


def _reqs(cfg, specs, rid0=0):
    out = []
    for k, (plen, gen, greedy, seed) in enumerate(specs):
        rid = rid0 + k
        prompt = np.asarray(
            jax.random.randint(jax.random.PRNGKey(100 + rid), (plen,), 0, cfg.vocab_size)
        )
        out.append(Request(rid=rid, prompt=prompt, max_new_tokens=gen,
                           greedy=greedy, seed=seed))
    return out


def _solo(cfg, params, req):
    batch = {"tokens": jnp.asarray(req.prompt)[None]}
    toks, _ = generate(cfg, params, batch, gen_len=req.max_new_tokens,
                       greedy=req.greedy, seed=req.seed)
    return [int(t) for t in np.asarray(toks[0])]


def _drain(eng):
    t = 0.0
    while eng.waiting or any(s is not None for s in eng.slots):
        eng.step(t)
        t += 1e-3


def test_hot_swap_in_flight_streams_pinned(gemma):
    """Swap mid-flight: requests in the air finish bit-identical on the old
    params; requests admitted after the swap serve the new ones; the old
    epoch is garbage-collected once drained."""
    cfg, params0, params1 = gemma
    eng = Engine(cfg, params0, ECFG)
    old = _reqs(cfg, [(11, 6, True, 0), (7, 8, False, 3)])
    for r in old:
        eng.submit(r)
    t = 0.0
    while not any(s is not None and s.generated for s in eng.slots):
        eng.step(t)
        t += 1e-3
    assert eng.hot_swap(params1)
    assert eng.params_epoch == 1 and eng.stats["hot_swaps"] == 1
    new = _reqs(cfg, [(9, 5, True, 0), (5, 4, False, 2)], rid0=10)
    for r in new:
        eng.submit(r)
    _drain(eng)
    for req in old:
        assert eng.results[req.rid].tokens == _solo(cfg, params0, req), f"rid {req.rid}"
    for req in new:
        assert eng.results[req.rid].tokens == _solo(cfg, params1, req), f"rid {req.rid}"
    assert eng.stats["epochs_retired"] >= 1
    assert set(eng._params) == {1}  # old epoch drained and collected


def test_hot_swap_preempted_request_stays_on_its_epoch(gemma):
    """A request preempted under block pressure across a swap still resumes
    on the epoch it was admitted under."""
    cfg, params0, params1 = gemma
    # one request's true footprint: over-committed once two run (test_engine
    # overcommit recipe) — forces eviction + FIFO re-admission
    ecfg = EngineConfig(
        max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=8,
        decode_quantum=4, num_blocks=1 + 4,
    )
    eng = Engine(cfg, params0, ecfg)
    old = _reqs(cfg, [(12, 10, False, 1), (12, 10, True, 0)])
    for r in old:
        eng.submit(r)
    t = 0.0
    while not eng.stats["preemptions"]:
        eng.step(t)
        t += 1e-3
        assert t < 10.0, "expected a preemption on the starved pool"
    assert eng.hot_swap(params1)
    new = _reqs(cfg, [(6, 4, True, 0)], rid0=10)
    eng.submit(new[0])
    _drain(eng)
    for req in old:
        assert eng.results[req.rid].tokens == _solo(cfg, params0, req), f"rid {req.rid}"
    assert eng.results[new[0].rid].tokens == _solo(cfg, params1, new[0])


def test_hot_swap_rollback_on_failed_prepare(gemma):
    """A failing prepare callable rolls back: the old epoch keeps serving,
    the failure is counted, and retries via FaultPolicy recover."""
    cfg, params0, params1 = gemma
    eng = Engine(cfg, params0, ECFG)

    def broken():
        raise RuntimeError("checkpoint programming failed")

    assert eng.hot_swap(broken) is False
    assert eng.params_epoch == 0
    assert eng.stats["swap_rollbacks"] == 1 and eng.stats["hot_swaps"] == 0
    # the engine still serves on the old params after the rollback
    req = _reqs(cfg, [(8, 4, True, 0)])[0]
    eng.submit(req)
    _drain(eng)
    assert eng.results[req.rid].tokens == _solo(cfg, params0, req)

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return params1

    assert eng.hot_swap(flaky, policy=FaultPolicy(max_retries=2))
    assert calls["n"] == 3 and eng.params_epoch == 1


def test_health_monitor_kl_and_horizon_triggers(gemma):
    cfg, params0, params1 = gemma
    batch = api.make_batch(cfg, jax.random.PRNGKey(2), 2, 16)
    mon = HealthMonitor(cfg, params0, batch, HealthConfig(kl_threshold=0.01))
    ok, rec = mon.check(params0)  # self-KL: no degradation
    assert not ok and rec["kl"] < 1e-6
    # a drifted-beyond-recognition tree (different init) must trigger
    ok2, rec2 = mon.check(params1)
    assert ok2 and rec2["kl"] > mon.hcfg.kl_threshold
    assert [r["trigger"] for r in mon.history] == [False, True]

    # wear-horizon trigger fires even while accuracy is fine
    wmon = HealthMonitor(
        cfg, params0, batch,
        HealthConfig(kl_threshold=1e9, min_horizon=1.0, endurance=5.0),
    )
    pool = CrossbarPool(CrossbarSpec(rows=64, cols=8), 4)
    ok3, rec3 = wmon.check(params0, pool=pool)
    assert not ok3 and rec3["horizon"] == float("inf")  # pristine pool
    pool.wear[:] = 10  # horizon = 5/10 = 0.5 < 1.0
    ok4, rec4 = wmon.check(params0, pool=pool)
    assert ok4 and rec4["horizon"] == pytest.approx(0.5)


def test_health_monitor_requires_consecutive_breaches(gemma):
    """Regression: a single transient probe failure must not trigger the
    kill/redeploy path when ``consecutive_breaches`` > 1 — only K breaches
    in a row do, and one healthy probe resets the streak."""
    cfg, params0, params1 = gemma
    batch = api.make_batch(cfg, jax.random.PRNGKey(2), 2, 16)
    mon = HealthMonitor(
        cfg, params0, batch,
        HealthConfig(kl_threshold=0.01, consecutive_breaches=2),
    )
    ok1, rec1 = mon.check(params1)  # breach #1: transient — no trigger yet
    assert not ok1 and rec1["breach"] and rec1["breaches"] == 1
    ok2, rec2 = mon.check(params1)  # breach #2: consecutive — trigger
    assert ok2 and rec2["breaches"] == 2
    # a healthy probe resets the streak: the next breach is #1 again
    ok3, _ = mon.check(params0)
    assert not ok3 and mon.breaches == 0
    ok4, rec4 = mon.check(params1)
    assert not ok4 and rec4["breaches"] == 1
    assert [r["trigger"] for r in mon.history] == [False, True, False, False]

    with pytest.raises(ValueError):
        HealthConfig(consecutive_breaches=0)


def test_engine_config_validation():
    for bad in (
        dict(max_slots=0),
        dict(page_size=0),
        dict(max_seq_len=-1),
        dict(prefill_chunk=0),
        dict(decode_quantum=0),
        dict(num_blocks=1),
        dict(preempt="drop"),
    ):
        with pytest.raises(ValueError):
            EngineConfig(**bad)
    EngineConfig()  # defaults stay valid


def test_cross_epoch_snapshot_restore_on_fresh_replica(gemma):
    """The failover primitive, pinned directly: ``swap_out`` state from
    replica A restored via ``swap_in`` on a *fresh* replica B — different
    block layout (page size AND pool size differ), post-``hot_swap`` param
    epoch — lands byte-identical in B's pools, and the replayed stream is
    token-identical to solo generation."""
    cfg, params0, params1 = gemma
    req = _reqs(cfg, [(6, 12, False, 5)])[0]
    A = Engine(cfg, params0, ECFG)
    A.submit(req)
    now = 0.0
    while not (A.slots[0] is not None and len(A.slots[0].generated) >= 3):
        A.step(now)
        now += 0.01
        assert not A.results, "request finished before eviction"
    rec = A.evict(req.rid, snapshot=True)
    assert rec is not None and rec.snapshot is not None and rec.n_live > 0
    want = jax.tree.map(np.copy, rec.snapshot)

    # fresh replica B: different page size and pool, one hot_swap behind it
    B = Engine(
        cfg, params1,
        EngineConfig(max_slots=3, page_size=4, max_seq_len=64,
                     prefill_chunk=8, decode_quantum=4),
    )
    assert B.hot_swap(params0)  # epoch 1 now serves A's tree
    assert B.params_epoch == 1
    B.resume(rec)
    assert rec.epoch == 1  # re-pinned to B's current epoch
    B.step(now)  # admits: snapshot swaps into B's (different) blocks

    idx = next(i for i, s in enumerate(B.slots) if s and s.req.rid == req.rid)
    cells = B.kv.slot_cells(idx, rec.n_live)
    got = jax.tree.map(lambda p: np.asarray(p[:, cells]), B.pools)
    jax.tree.map(np.testing.assert_array_equal, got, want)
    assert B.stats["swap_ins"] == 1

    while req.rid not in B.results:
        B.step(now)
        now += 0.01
    assert B.results[req.rid].tokens == _solo(cfg, params0, req)
