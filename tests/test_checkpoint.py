"""Checkpoint atomicity, roundtrip, retention, elastic re-shard."""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "layer": {"w": jax.random.normal(k1, (16, 8)), "b": jnp.zeros((8,))},
        "step_scale": jnp.float32(0.5),
        "stack": jax.random.normal(k2, (3, 4, 4)),
    }


def test_save_restore_roundtrip(tmp_path, key):
    tree = _tree(key)
    save_checkpoint(tmp_path, 7, tree)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    restored = restore_checkpoint(tmp_path, 7, like)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), tree, restored)


def test_latest_and_retention(tmp_path, key):
    tree = _tree(key)
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.latest() == 4
    kept = sorted(p.name for p in Path(tmp_path).iterdir() if p.is_dir())
    assert kept == ["step_00000003", "step_00000004"]


def test_async_writer(tmp_path, key):
    tree = _tree(key)
    mgr = CheckpointManager(tmp_path, keep=3, async_write=True)
    mgr.save(10, tree)
    mgr.wait()
    assert latest_step(tmp_path) == 10


def test_atomicity_no_partial_checkpoints(tmp_path, key):
    """A .tmp directory is never visible as a checkpoint."""
    tree = _tree(key)
    save_checkpoint(tmp_path, 1, tree)
    # fabricate a crashed write
    crashed = Path(tmp_path) / "step_00000002.tmp"
    crashed.mkdir()
    (crashed / "garbage.npy").write_bytes(b"xx")
    assert latest_step(tmp_path) == 1  # the crashed write is invisible


def test_shape_mismatch_rejected(tmp_path, key):
    tree = _tree(key)
    save_checkpoint(tmp_path, 3, tree)
    bad = dict(tree, stack=jnp.zeros((2, 4, 4)))
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), bad)
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(tmp_path, 3, like)


def test_elastic_reshard_restore(tmp_path, key):
    """A checkpoint saved unsharded restores onto an explicit mesh sharding
    (the 1-device stand-in for the mesh-A -> mesh-B elastic path)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    tree = _tree(key)
    save_checkpoint(tmp_path, 5, tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shardings = jax.tree.map(lambda a: NamedSharding(mesh, P()), tree)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    restored = restore_checkpoint(tmp_path, 5, like, shardings=shardings)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), tree, restored)
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf.sharding, NamedSharding)
