"""Plane codec layer (core/planes.py): round trips, transition bound, threading.

The standing contract this file pins:
  * ``decode(encode(planes))`` is byte-identical to the raw packed planes for
    EVERY codec (ragged rows included);
  * ``col_perm`` physical transitions never exceed raw's (the per-chain
    identity fallback makes the CI >= 1.0x gate structural);
  * the pool programs a ``PlaneSet``'s physical bits with exact wear/seam
    accounting, and fault masks apply to the stored layout with logical
    decode after the read;
  * the planner's codec route deploys byte-identical ``w_hat`` to raw;
  * serving-side ``encode_operands`` is an exact re-encoding through both
    ``cim_linear`` and ``densify_operands``, and the kernel's zero-tile skip
    path matches the flag-less kernel bit for bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitslice, nonideal, planes, planner, schedule, simulator
from repro.core.pool import CrossbarPool
from repro.kernels.cim_matmul import ops as cm_ops


def _random_planes(seed, s=10, rows=128, cols=8, const_planes=()):
    rng = np.random.default_rng(seed)
    w = -(-rows // 8)
    packed = rng.integers(0, 256, size=(s, w, cols)).astype(np.uint8)
    for c, val in const_planes:
        packed[:, :, c] = val
    return jnp.asarray(packed)


def _transitions(phys, chains):
    costs = schedule.schedule_job_costs(phys, chains, include_initial=True)
    return int(np.sum(np.asarray(costs), dtype=np.int64))


# ---------------------------------------------------------------------------
# Round-trip byte identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", planes.CODECS)
@pytest.mark.parametrize("rows", [128, 100, 7])  # ragged: rows not /8
def test_decode_encode_byte_identity(codec, rows):
    packed = _random_planes(rows, s=9, rows=rows, const_planes=[(5, 0), (6, 255)])
    chains = schedule.make_chains(9, 3, "stride1")
    ps = planes.encode(packed, codec, chains=chains)
    dec = ps.decode()
    assert dec.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(packed))


def test_raw_codec_is_identity():
    packed = _random_planes(0)
    ps = planes.encode(packed, "raw")
    assert ps.physical() is ps.payload
    np.testing.assert_array_equal(np.asarray(ps.decode()), np.asarray(packed))


def test_unknown_codec_raises():
    packed = _random_planes(0)
    with pytest.raises(ValueError, match="unknown plane codec"):
        planes.encode(packed, "lz77")
    with pytest.raises(ValueError, match="chains"):
        planes.encode(packed, "col_perm")  # col_perm needs a schedule


def test_bitslice_encode_decode_entry_points():
    packed = _random_planes(3)
    chains = schedule.make_chains(10, 4, "strideL")
    ps = bitslice.encode_planes(packed, "col_perm_rle", chains=chains)
    np.testing.assert_array_equal(
        np.asarray(bitslice.decode_planes(ps)), np.asarray(packed)
    )
    # raw arrays pass through decode_planes untouched
    assert bitslice.decode_planes(packed) is packed


# ---------------------------------------------------------------------------
# const_rle tiles + compression accounting
# ---------------------------------------------------------------------------

def test_const_rle_detects_constant_tiles():
    packed = _random_planes(1, s=6, const_planes=[(2, 0), (7, 170)])
    ps = planes.encode(packed, "const_rle")
    mask = np.asarray(ps.const_mask)
    assert mask[:, 2].all() and mask[:, 7].all()
    np.testing.assert_array_equal(np.asarray(ps.const_val)[:, 7], 170)
    # elided tiles are zeroed in the payload; physical() reconstructs them
    assert not np.asarray(ps.payload)[:, :, 7].any()
    np.testing.assert_array_equal(np.asarray(ps.physical()), np.asarray(packed))
    stats = ps.compression_stats()
    assert stats["payload_bytes"] < stats["raw_bytes"]
    assert stats["ratio_vs_raw"] > 1.0


def test_compression_stats_raw_is_one():
    ps = planes.encode(_random_planes(2), "raw")
    stats = ps.compression_stats()
    assert stats["total_bytes"] == stats["raw_bytes"]
    assert stats["ratio_vs_raw"] == 1.0


# ---------------------------------------------------------------------------
# col_perm: transition bound + planned orders
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["stride1", "strideL"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_col_perm_transitions_never_exceed_raw(kind, seed):
    """The structural >= 1.0x guarantee: identity first sections + per-chain
    identity fallback mean the encoded physical stream is never costlier."""
    packed = _random_planes(seed, s=16)
    chains = schedule.make_chains(16, 4, kind)
    ps = planes.encode(packed, "col_perm", chains=chains)
    assert _transitions(ps.physical(), chains) <= _transitions(packed, chains)


def test_col_perm_first_sections_keep_identity():
    """A chain's first section reprograms unknown pool content — nothing to
    match against at plan time, so its stored order stays identity (which is
    also what makes seam pricing equal raw's)."""
    packed = _random_planes(4, s=12)
    chains = schedule.make_chains(12, 3, "stride1")
    order = planes.plan_col_order(packed, chains)
    cols = packed.shape[-1]
    for ch in chains:
        np.testing.assert_array_equal(order[int(ch[0])], np.arange(cols))
    # every row is a permutation
    for s in range(order.shape[0]):
        assert sorted(order[s].tolist()) == list(range(cols))


def test_col_perm_realigns_carry_boundary():
    """The physical win: an all-q=1 section followed by an all-q=2 section
    toggles every cell in planes 0 and 1 under identity storage, and zero
    cells once the two planes swap."""
    rows, cols = 128, 4
    q = jnp.concatenate([jnp.full((rows,), 1), jnp.full((rows,), 2)]).astype(jnp.int32)
    packed = bitslice.section_planes_packed(q, rows, cols)
    chains = [np.array([0, 1], np.int32)]
    raw_t = _transitions(packed, chains)
    ps = planes.encode(packed, "col_perm", chains=chains)
    enc_t = _transitions(ps.physical(), chains)
    assert enc_t < raw_t
    # section 1 stores logical plane 1 in physical column 0 (the swap)
    assert int(ps.col_order[1, 0]) == 1 and int(ps.col_order[1, 1]) == 0
    np.testing.assert_array_equal(np.asarray(ps.decode()), np.asarray(packed))


# ---------------------------------------------------------------------------
# Pool threading: physical programming, wear exactness, fault masks
# ---------------------------------------------------------------------------

def test_pool_accepts_plane_set_raw_parity():
    """A raw PlaneSet programs identically to the bare array."""
    spec = planner.CrossbarSpec(rows=128, cols=8)
    packed = _random_planes(5, s=8)
    chains = schedule.make_chains(8, 4, "stride1")
    pa = CrossbarPool(spec, 4)
    pb = CrossbarPool(spec, 4)
    ra = pa.program(packed, chains)
    rb = pb.program(planes.encode(packed, "raw"), chains)
    assert ra.transitions_full == rb.transitions_full
    np.testing.assert_array_equal(pa.wear, pb.wear)
    np.testing.assert_array_equal(np.asarray(ra.achieved), np.asarray(rb.achieved))


def test_pool_programs_physical_bits_wear_conservation():
    """Under col_perm the pool's wear counts the *stored* transitions (the
    physical writes), and they sum exactly to the priced totals — the codec
    keeps endurance accounting exact."""
    spec = planner.CrossbarSpec(rows=128, cols=8)
    packed = _random_planes(6, s=12)
    chains = schedule.make_chains(12, 4, "stride1")
    ps = planes.encode(packed, "col_perm", chains=chains)
    pool = CrossbarPool(spec, 4)
    rep = pool.program(ps, chains)
    assert rep.wear_increment_total == rep.transitions_full
    assert rep.transitions_full == _transitions(ps.physical(), chains)
    # achieved is the stored state; decode recovers the logical planes
    np.testing.assert_array_equal(
        np.asarray(planes.logical_from_physical(rep.achieved, ps.col_order)),
        np.asarray(packed),
    )


def test_fault_masks_apply_to_stored_layout():
    """Post-decode fault semantics: the pool's stuck masks bite physical
    columns; decoding the faulty read equals un-permuting the masked stored
    bits — NOT masking the logical planes directly."""
    spec = planner.CrossbarSpec(rows=128, cols=8)
    packed = _random_planes(7, s=8)
    chains = schedule.make_chains(8, 4, "stride1")
    ps = planes.encode(packed, "col_perm", chains=chains)
    pool = CrossbarPool(spec, 4)
    pool.inject_faults(
        nonideal.FaultModel(stuck0=0.05, stuck1=0.05), jax.random.PRNGKey(1)
    )
    rep = pool.program(ps, chains)
    logical = planes.logical_from_physical(rep.achieved_read, ps.col_order)
    # oracle: mask the stored bits by hand, then un-permute
    sec_xbar = np.zeros(8, np.int32)
    for j, c in enumerate(chains):
        sec_xbar[c] = rep.assignment[j]
    idx = jnp.asarray(sec_xbar)
    masked = nonideal.read_packed(
        ps.physical(), pool.faults.stuck0[idx], pool.faults.stuck1[idx]
    )
    np.testing.assert_array_equal(
        np.asarray(logical),
        np.asarray(planes.logical_from_physical(masked, ps.col_order)),
    )


# ---------------------------------------------------------------------------
# Planner threading
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def planner_inputs():
    w = jax.random.normal(jax.random.PRNGKey(2), (96, 170)) * 0.02
    spec = planner.CrossbarSpec(rows=128, cols=8)
    key = jax.random.PRNGKey(0)
    cfg = planner.PlannerConfig(crossbars=8)
    rep, wh = planner.analyze_tensor(w, spec, cfg, key)
    return w, spec, key, rep, wh


@pytest.mark.parametrize("codec", [c for c in planes.CODECS if c != "raw"])
def test_planner_codec_w_hat_byte_identical(planner_inputs, codec):
    """Codecs change the physical programming, never the deployed weights."""
    w, spec, key, rep_raw, wh_raw = planner_inputs
    cfg = planner.PlannerConfig(crossbars=8, codec=codec)
    rep, wh = planner.analyze_tensor(w, spec, cfg, key)
    np.testing.assert_array_equal(np.asarray(wh), np.asarray(wh_raw))
    if codec.startswith("col_perm"):
        assert rep.transitions_sws <= rep_raw.transitions_sws


def test_planner_codec_validation(planner_inputs):
    w, spec, key, *_ = planner_inputs
    with pytest.raises(ValueError, match="unknown plane codec"):
        planner.analyze_tensor(w, spec, planner.PlannerConfig(codec="zip"), key)
    with pytest.raises(ValueError, match="impl"):
        planner.analyze_tensor(
            w, spec, planner.PlannerConfig(codec="col_perm", impl="bool"), key
        )


def test_planner_codec_through_pool_stucked(planner_inputs):
    """Codec + p_stuck < 1 through a persistent pool: the stucked walk runs
    on stored bits and the decoded weights stay exactly representable."""
    w, spec, key, *_ = planner_inputs
    cfg = planner.PlannerConfig(crossbars=8, codec="col_perm_rle", p_stuck=0.5)
    pool = CrossbarPool(spec, 8)
    rep, wh = planner.analyze_tensor(w, spec, cfg, key, pool=pool)
    assert rep.transitions_final <= rep.transitions_sws
    # w_hat is exactly representable: re-encoding it is lossless
    op = simulator.operands_from_dense(
        wh, rep.scale, rep.offset, spec.encoding, spec.cols
    )
    np.testing.assert_allclose(
        np.asarray(simulator.densify_operands(op)), np.asarray(wh), rtol=0, atol=0
    )


def test_planner_codec_stucked_w_hat_byte_identical(planner_inputs):
    """Under bit stucking the planner pins the stored lowest-order columns
    (``stuck_cols``) at identity, so the under-programmed cells hold exactly
    the bits raw storage would — deployed weights stay byte-identical to the
    raw codec at ANY p_stuck, not just p=1.  Without the pin, a permutation
    parking a high-order plane in the stucked column turns the bounded LSB
    error into a high-order one (~60x the RMSE)."""
    w, spec, key, *_ = planner_inputs
    for p in (0.5, 0.0):
        cfg_r = planner.PlannerConfig(crossbars=8, p_stuck=p)
        cfg_c = planner.PlannerConfig(crossbars=8, codec="col_perm", p_stuck=p)
        rep_r, wr = planner.analyze_tensor(w, spec, cfg_r, key)
        rep_c, wc = planner.analyze_tensor(w, spec, cfg_c, key)
        np.testing.assert_array_equal(np.asarray(wc), np.asarray(wr))
        assert rep_c.transitions_final <= rep_r.transitions_final


def test_plan_col_order_pin_cols():
    packed = _random_planes(9, s=12, cols=8)
    chains = schedule.make_chains(12, 3, "stride1")
    order = planes.plan_col_order(packed, chains, pin_cols=2)
    assert (np.asarray(order[:, :2]) == np.arange(2)).all()
    for s in range(order.shape[0]):
        assert sorted(order[s].tolist()) == list(range(8))
    # pinning everything degenerates to identity
    full = planes.plan_col_order(packed, chains, pin_cols=99)
    np.testing.assert_array_equal(full, np.tile(np.arange(8, dtype=np.int32), (12, 1)))


# ---------------------------------------------------------------------------
# Serving-operand twins
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving_w():
    rng = np.random.default_rng(11)
    w = rng.normal(0, 0.05, (200, 130)).astype(np.float32)
    w[np.abs(w) > 0.08] = 0.0
    w[0, 0] = 1.0  # amax outlier concentrates q low -> zero high-plane tiles
    x = rng.normal(0, 1.0, (5, 200)).astype(np.float32)
    return jnp.asarray(w), jnp.asarray(x)


@pytest.mark.parametrize("codec", [c for c in planes.CODECS if c != "raw"])
def test_encode_operands_exact_through_cim_linear_and_densify(serving_w, codec):
    w, x = serving_w
    spec = planner.CrossbarSpec(rows=128, cols=8)
    raw = simulator.prepare_linear(w, spec, materialize="packed")
    enc = simulator.prepare_linear(w, spec, materialize="packed", codec=codec)
    np.testing.assert_array_equal(
        np.asarray(simulator.cim_linear(x, enc)),
        np.asarray(simulator.cim_linear(x, raw)),
    )
    np.testing.assert_array_equal(
        np.asarray(simulator.densify_operands(enc)),
        np.asarray(simulator.densify_operands(raw)),
    )
    if codec.startswith("col_perm"):
        ids = np.asarray(enc["plane_ids"])
        assert sorted(ids.tolist()) == list(range(spec.cols))


def test_encode_operands_zero_tile_flags_honest(serving_w):
    """A 0 flag really means every byte of that (plane, 128-row) tile is 0."""
    w, _ = serving_w
    spec = planner.CrossbarSpec(rows=128, cols=8)
    enc = simulator.prepare_linear(w, spec, materialize="packed", codec="const_rle")
    flags = np.asarray(enc["plane_tile_nz"])
    assert (flags == 0).any(), "config should produce at least one zero tile"
    pp = np.asarray(enc["planes_packed"])
    t = planes.OPERAND_TILE_BYTES
    for b in range(flags.shape[0]):
        for kk in range(flags.shape[1]):
            tile = pp[b, kk * t : (kk + 1) * t, :]
            assert bool(tile.any()) == bool(flags[b, kk])


def test_kernel_tile_skip_bit_exact(serving_w):
    """The PrefetchScalarGridSpec skip kernel == the flag-less kernel, bit for
    bit (interpret mode): skipped tiles contribute exact zeros."""
    w, x = serving_w
    spec = planner.CrossbarSpec(rows=128, cols=8)
    enc = simulator.prepare_linear(w, spec, materialize="packed", codec="const_rle")
    with_skip = cm_ops.cim_matmul_packed(
        x, enc["planes_packed"], enc["sign_packed"], enc["scale"],
        tile_nz=enc["plane_tile_nz"], interpret=True,
    )
    without = cm_ops.cim_matmul_packed(
        x, enc["planes_packed"], enc["sign_packed"], enc["scale"], interpret=True
    )
    np.testing.assert_array_equal(np.asarray(with_skip), np.asarray(without))


def test_encode_operands_validation(serving_w):
    w, _ = serving_w
    spec = planner.CrossbarSpec(rows=128, cols=8)
    with pytest.raises(ValueError, match="stored-plane layout"):
        simulator.prepare_linear(w, spec, materialize="int8", codec="col_perm")
    op8 = simulator.prepare_linear(w, spec, materialize="int8")
    with pytest.raises(ValueError, match="packed serving operands"):
        planes.encode_operands(op8, "col_perm")


def test_operand_payload_bytes_accounting(serving_w):
    w, _ = serving_w
    spec = planner.CrossbarSpec(rows=128, cols=8)
    raw = simulator.prepare_linear(w, spec, materialize="packed")
    enc = simulator.prepare_linear(w, spec, materialize="packed", codec="col_perm_rle")
    b_raw = planes.operand_payload_bytes(raw)
    b_enc = planes.operand_payload_bytes(enc)
    assert b_raw["plane_bytes"] == int(np.prod(raw["planes_packed"].shape))
    assert b_enc["plane_bytes"] < b_raw["plane_bytes"]  # zero tiles elided
    assert b_enc["meta_bytes"] > 0


def test_perturbed_encoded_operands_densify_vs_cim_linear(serving_w):
    """Fault masks attach to the stored layout (perturb AFTER encoding) and
    both consumers decode the same faulty weights."""
    w, x = serving_w
    spec = planner.CrossbarSpec(rows=128, cols=8)
    enc = simulator.prepare_linear(w, spec, materialize="packed", codec="col_perm")
    model = nonideal.FaultModel(stuck0=0.02, stuck1=0.02, drift_sigma=0.05)
    pert = nonideal.perturb_operands(enc, model, jax.random.PRNGKey(3))
    y = simulator.cim_linear(x, pert)
    w_read = simulator.densify_operands(pert)
    y_dense = (x @ w_read) * 1.0 + jnp.sum(x, axis=-1, keepdims=True) * pert["offset"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_dense), rtol=1e-4, atol=1e-4)
