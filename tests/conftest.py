"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs exclusively to repro.launch.dryrun)."""
from __future__ import annotations

import jax
import pytest

try:
    from hypothesis import settings
except ModuleNotFoundError:  # container has no hypothesis; gate, don't install
    import _hypothesis_shim  # noqa: F401  (registers sys.modules["hypothesis"])

    from hypothesis import settings

# keep hypothesis fast on the single-core container
settings.register_profile("ci", max_examples=25, deadline=None, derandomize=True)
settings.load_profile("ci")


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def bell_weights(key, n: int, std: float = 0.02):
    """Gaussian (bell-shaped) weights — the distribution SWS exploits."""
    return jax.random.normal(key, (n,)) * std
