"""Tests for the deployment planner (params -> crossbar plan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import redeploy
from repro.core.planner import (
    CrossbarSpec,
    PlannerConfig,
    analyze_tensor,
    build_deployment,
    deploy_params,
    iter_weights,
)


def test_analyze_tensor_invariants(key):
    w = jax.random.normal(key, (256, 384)) * 0.02
    spec = CrossbarSpec(rows=128, cols=10)
    rep, w_hat = analyze_tensor(w, spec, PlannerConfig(p_stuck=0.5), key)
    assert rep.n_weights == w.size
    assert rep.n_sections == -(-w.size // spec.rows)
    assert rep.transitions_sws < rep.transitions_baseline  # SWS helps
    assert rep.transitions_final <= rep.transitions_sws  # stucking helps more
    assert rep.sws_speedup > 1.0
    assert rep.total_speedup >= rep.sws_speedup
    # lockstep: greedy (on SWS costs) beats unsorted arrival order
    assert rep.lockstep_time_greedy <= rep.lockstep_time_unsorted
    assert rep.lockstep_time_greedy >= rep.lockstep_time_ideal - 1e-6
    # deployed weights stay close to originals (quant + LSB error only)
    assert rep.quant_mse < (2.0 * float(jnp.max(jnp.abs(w))) / 2**10) ** 2
    assert w_hat.shape == w.shape and w_hat.dtype == w.dtype


def test_p1_no_weight_error_beyond_quantization(key):
    w = jax.random.normal(key, (128, 128)) * 0.05
    spec = CrossbarSpec(rows=128, cols=10)
    rep, w_hat = analyze_tensor(w, spec, PlannerConfig(p_stuck=1.0), key)
    # pure quantization error bound: half a step
    amax = float(jnp.max(jnp.abs(w)))
    step = amax / (2**10 - 1)
    assert float(jnp.max(jnp.abs(w - w_hat))) <= 0.5 * step + 1e-7


def test_sws_off_baseline_equals_sws_transitions(key):
    w = jax.random.normal(key, (64, 64)) * 0.02
    cfg = PlannerConfig(sws=False, p_stuck=1.0)
    rep, _ = analyze_tensor(w, CrossbarSpec(rows=64, cols=8), cfg, key)
    assert rep.transitions_sws == rep.transitions_baseline


def test_offset_binary_encoding_roundtrip(key):
    w = jax.random.normal(key, (128, 64)) * 0.02 + 0.01
    spec = CrossbarSpec(rows=128, cols=10, encoding="offset_binary")
    rep, w_hat = analyze_tensor(w, spec, PlannerConfig(p_stuck=1.0), key)
    amax = float(jnp.max(w) - jnp.min(w))
    step = amax / (2**10 - 1)
    assert float(jnp.max(jnp.abs(w - w_hat))) <= 0.5 * step + 1e-7
    assert rep.sws_speedup > 1.0


def test_iter_weights_filters(key):
    params = {
        "embed": {"table": jnp.zeros((1000, 64))},  # excluded by name
        "layer": {"w": jnp.zeros((128, 64))},  # kept
        "bias": jnp.zeros((64,)),  # excluded: ndim < 2
        "tiny": jnp.zeros((4, 4)),  # excluded: size < min_size
    }
    names = [n for n, _ in iter_weights(params, PlannerConfig(min_size=1024))]
    assert names == ["layer/w"]


def test_iter_weights_exclude_escapes_regex_metacharacters():
    """Regression: exclude patterns were joined into one regex unescaped, so
    "w.bias" silently over-matched ("wxbias") and "head[" raised."""
    params = {
        "w.bias": jnp.zeros((64, 64)),
        "wxbias": jnp.zeros((64, 64)),
        "head[0]": jnp.zeros((64, 64)),
        "keep": jnp.zeros((64, 64)),
    }
    cfg = PlannerConfig(min_size=1, exclude=("w.bias", "head["))
    names = sorted(n for n, _ in iter_weights(params, cfg))
    assert names == ["keep", "wxbias"]


def test_build_and_deploy_roundtrip(key):
    params = {
        "a": {"w": jax.random.normal(key, (128, 64)) * 0.02},
        "b": {"w": jax.random.normal(jax.random.PRNGKey(1), (64, 128)) * 0.02},
        "embed": {"table": jnp.ones((512, 16))},
    }
    plan = build_deployment(params, CrossbarSpec(rows=64, cols=8),
                            PlannerConfig(p_stuck=0.5, min_size=1024))
    assert set(plan.reports) == {"a/w", "b/w"}
    totals = plan.totals()
    assert totals["total_speedup"] >= totals["sws_speedup"] > 1.0

    deployed = deploy_params(params, plan)
    # embed untouched; others replaced but close
    np.testing.assert_array_equal(deployed["embed"]["table"], params["embed"]["table"])
    assert not np.array_equal(deployed["a"]["w"], params["a"]["w"])
    assert float(jnp.max(jnp.abs(deployed["a"]["w"] - params["a"]["w"]))) < 0.01


def test_tsp_section_order_not_worse(key):
    w = jax.random.normal(key, (64, 64)) * 0.02
    spec = CrossbarSpec(rows=64, cols=8)
    r_mag, _ = analyze_tensor(w, spec, PlannerConfig(p_stuck=1.0), key)
    r_tsp, _ = analyze_tensor(
        w, spec, PlannerConfig(p_stuck=1.0, section_order="tsp"), key
    )
    assert r_tsp.transitions_sws <= r_mag.transitions_sws * 1.02


def test_redeploy_delta_cost(key):
    w_old = jax.random.normal(key, (128, 64)) * 0.02
    # same weights -> zero transitions in both layouts
    rep0 = redeploy.delta_cost(w_old, w_old)
    assert rep0.transitions_natural == 0 and rep0.transitions_sws == 0
    # small drift -> SWS layout concentrates deltas in low-order bits
    w_new = w_old + jax.random.normal(jax.random.PRNGKey(1), w_old.shape) * 0.0005
    rep = redeploy.delta_cost(w_old, w_new)
    assert 0 < rep.transitions_sws <= rep.n_bits
    assert 0 < rep.transitions_natural <= rep.n_bits
