"""CIM forward simulation + fidelity probes + end-to-end accuracy preservation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import simulator
from repro.core.bitslice import dequantize, quantize
from repro.core.planner import CrossbarSpec, PlannerConfig
from repro.models import api


def test_cim_linear_equals_dense_quantized(key):
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (8, 96))
    w = jax.random.normal(kw, (96, 48)) * 0.1
    ops = simulator.prepare_linear(w, CrossbarSpec(rows=128, cols=10))
    y = simulator.cim_linear(x, ops)
    w_hat = dequantize(quantize(w, 10)).reshape(w.shape)
    np.testing.assert_allclose(y, x @ w_hat, rtol=1e-4, atol=1e-5)


def test_cim_linear_offset_binary_correction(key):
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (4, 64))
    w = jax.random.normal(kw, (64, 32)) * 0.1 + 0.05  # asymmetric
    spec = CrossbarSpec(rows=128, cols=10, encoding="offset_binary")
    ops = simulator.prepare_linear(w, spec)
    y = simulator.cim_linear(x, ops)
    w_hat = dequantize(quantize(w, 10, "offset_binary")).reshape(w.shape)
    np.testing.assert_allclose(y, x @ w_hat, rtol=1e-4, atol=1e-4)


def test_probes_zero_for_identical_params(key):
    f = lambda p, b: (b @ p["w"])
    params = {"w": jax.random.normal(key, (16, 8))}
    batch = jax.random.normal(key, (4, 16))
    assert float(simulator.output_mse(f, params, params, batch)) == 0.0
    logits_f = lambda p, b: b @ p["w"]
    assert float(simulator.logit_kl(logits_f, params, params, batch)) < 1e-6
    assert float(simulator.top1_agreement(logits_f, params, params, batch)) == 1.0


@pytest.mark.slow  # full reduced-LM deploy + forward probes per p value
@pytest.mark.parametrize("p_stuck", [1.0, 0.5, 0.0])
def test_deploy_and_probe_accuracy_preserved(key, p_stuck):
    """The paper's headline constraint on a real LM: crossbar deployment with
    bit stucking keeps top-1 predictions within ~1% of the fp model."""
    cfg = get_arch("internlm2-1.8b", reduced=True)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, 2, 32)

    f = lambda p, b: api.forward(p, cfg, b)[0]
    plan, probes = simulator.deploy_and_probe(
        f, params, batch,
        CrossbarSpec(rows=128, cols=10),
        PlannerConfig(p_stuck=p_stuck, min_size=1024),
    )
    assert plan.totals()["sws_speedup"] > 1.0
    assert probes["top1_agreement"] >= 0.99  # the <1% accuracy-drop margin
    assert probes["logit_kl"] < 0.05
