"""Minimal stand-in for ``hypothesis`` used when the real package is absent.

The container image does not ship hypothesis and installing packages is not
an option, so ``conftest.py`` falls back to this shim.  It implements exactly
the surface this test suite uses — ``given``, ``settings`` profiles, and the
``integers`` / ``sampled_from`` / ``lists`` / ``booleans`` strategies — with
deterministic example generation (seeded per test name, mirroring the CI
profile's ``derandomize=True``).  It is NOT a property-testing engine: no
shrinking, no coverage-guided search, just a fixed number of random draws.
"""
from __future__ import annotations

import functools
import random
import sys
import types
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    seq = list(elements)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def booleans() -> _Strategy:
    return sampled_from([False, True])


def lists(elements: _Strategy, *, min_size: int = 0, max_size: int = 10) -> _Strategy:
    return _Strategy(
        lambda rng: [elements.draw(rng) for _ in range(rng.randint(min_size, max_size))]
    )


class settings:
    """Profile registry; only ``max_examples`` is honoured."""

    _profiles: dict[str, dict] = {}
    _current: dict = {"max_examples": 25}

    def __init__(self, **kwargs):
        self.kwargs = kwargs

    def __call__(self, fn):  # @settings(...) decorator form: no-op wrapper
        return fn

    @classmethod
    def register_profile(cls, name: str, **kwargs) -> None:
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name: str) -> None:
        cls._current = {**cls._current, **cls._profiles.get(name, {})}


def given(**param_strategies):
    def decorator(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(int(settings._current.get("max_examples", 25))):
                drawn = {k: s.draw(rng) for k, s in param_strategies.items()}
                fn(*args, **drawn, **kwargs)

        # pytest resolves fixtures through __wrapped__'s signature; the drawn
        # parameters must not look like fixture requests.
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper

    return decorator


# Register as an importable ``hypothesis`` (+ strategies submodule) so plain
# ``from hypothesis import given, strategies as st`` works in test modules.
strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "sampled_from", "booleans", "lists"):
    setattr(strategies, _name, globals()[_name])
sys.modules.setdefault("hypothesis", sys.modules[__name__])
sys.modules.setdefault("hypothesis.strategies", strategies)
