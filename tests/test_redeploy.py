"""Tests for checkpoint-to-checkpoint redeploy pricing (core.redeploy).

Covers the ``delta_cost`` invariants — permutation-invariance of the
in-place rewrite cost, stale-vs-fresh chain ordering, tightness of the
``n_bits`` bound on padded tails — and the persistent-pool refresh path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.planner import CrossbarSpec, PlannerConfig
from repro.core.pool import CrossbarPool
from repro.core.redeploy import delta_cost


def _drifted(w, scale, seed=1):
    return w + scale * jax.random.normal(jax.random.PRNGKey(seed), w.shape)


def test_inplace_rewrite_is_permutation_invariant(key):
    """Summed per-element Hamming distance does not depend on layout, so the
    SWS in-place rewrite cost equals the natural one and the speedup is
    exactly 1.0 — a sanity check that index-matching bookkeeping is exact."""
    w_old = jax.random.normal(key, (128, 64)) * 0.02
    rep = delta_cost(w_old, _drifted(w_old, 0.001))
    assert rep.transitions_natural == rep.transitions_sws > 0
    assert rep.sws_delta_speedup == 1.0


def test_zero_drift_zero_transitions(key):
    w = jax.random.normal(key, (128, 64)) * 0.02
    rep = delta_cost(w, w)
    assert rep.transitions_natural == 0 and rep.transitions_sws == 0
    # streaming the (identical) new checkpoint still costs programs
    assert rep.chain_natural > 0


def test_stale_vs_fresh_chain_ordering(key):
    """After modest drift the stale sort is still near-sorted: fresh re-sort
    is at least as good as stale, and stale still beats the natural layout."""
    w_old = jax.random.normal(key, (128, 64)) * 0.02
    rep = delta_cost(w_old, _drifted(w_old, 0.002))
    assert 0 < rep.chain_fresh_sws <= rep.chain_stale_sws
    assert rep.chain_stale_sws < rep.chain_natural
    assert rep.fresh_sort_speedup >= rep.stale_sort_speedup > 1.0


def test_n_bits_counts_only_real_memristors(key):
    """Regression: padded-tail elements used to be counted as physical cells,
    slackening the 'upper bound on transitions' claim."""
    spec = CrossbarSpec(rows=128, cols=10)
    w_old = jax.random.normal(key, (100, 7)) * 0.02  # 700 % 128 != 0
    rep = delta_cost(w_old, _drifted(w_old, 0.05), spec)
    assert rep.n_bits == 700 * spec.cols
    assert 0 < rep.transitions_natural <= rep.n_bits
    assert rep.transitions_sws <= rep.n_bits


def test_pool_refresh_seeds_old_checkpoint_then_accumulates(key):
    """A pristine pool is first seated with w_old (its deployment writes are
    part of the cells' lifetime), then the refresh reprograms the resident
    old checkpoint; later refreshes never re-seed, and wear accumulates
    exactly (p=1 full reprogramming: wear == priced transitions)."""
    spec = CrossbarSpec(rows=64, cols=8)
    cfg = PlannerConfig(crossbars=1)
    w_old = jax.random.normal(key, (64, 48)) * 0.02
    w_new = _drifted(w_old, 0.001)
    pool = CrossbarPool(spec, 1)
    rep = delta_cost(w_old, w_new, spec, cfg, pool=pool)
    assert pool.tensors_seen == 2  # w_old seated, then refreshed to w_new
    assert rep.chain_pool > 0
    assert pool.total_writes > rep.chain_pool  # includes w_old's deployment

    w_new2 = _drifted(w_new, 0.001, seed=2)
    before = pool.total_writes
    rep2 = delta_cost(w_new, w_new2, spec, cfg, pool=pool)
    assert pool.tensors_seen == 3  # no re-seed on a warm pool
    assert rep2.chain_pool > 0
    assert pool.total_writes == before + rep2.chain_pool  # wear conservation


def test_pool_refresh_default_report_has_no_pool_cost(key):
    w_old = jax.random.normal(key, (64, 64)) * 0.02
    rep = delta_cost(w_old, _drifted(w_old, 0.001), CrossbarSpec(rows=64, cols=8))
    assert rep.chain_pool == 0
