"""Continuous-batching engine: paged KV cache + ragged decode parity.

The acceptance contract: every request served by the engine — mixed prompt
lengths, EOS at different steps, mid-flight admission into freed slots,
chunked prefill, fused prefill+decode dispatches, preemption and
re-admission under block pressure, greedy and sampled — emits a token
stream bit-identical to running that request alone through
``launch.serve.generate`` with the same PRNG seed, for all three serving
materializations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment, deploy_params
from repro.launch import paged_cache, steps
from repro.launch.engine import Engine, EngineConfig, Request
from repro.launch.paged_cache import DUMMY_BLOCK, BlockAllocator, PagedCacheConfig, PagedKVCache
from repro.launch.serve import generate
from repro.models import api
from repro.models.attention import decode_attention
from repro.models.blocks import attention_step, init_attn_cache


# ---------------------------------------------------------------------------
# Paged cache bookkeeping
# ---------------------------------------------------------------------------

def test_allocator_lifecycle():
    a = BlockAllocator(num_blocks=5)  # blocks 1..4 usable
    assert a.free_blocks == 4
    got = a.alloc(3)
    assert sorted(got) == [1, 2, 3]
    assert a.alloc(2) is None  # all-or-nothing
    assert a.free_blocks == 1
    a.free(got)
    assert a.free_blocks == 4
    with pytest.raises(ValueError):
        a.free([DUMMY_BLOCK])


def test_paged_cache_tables_and_write_routing():
    kv = PagedKVCache(PagedCacheConfig(page_size=4, num_blocks=6, max_slots=2, max_pages=4))
    assert kv.ensure_capacity(0, 6)  # 2 pages
    assert kv.ensure_capacity(1, 9)  # 3 pages
    assert int(kv.n_pages[0]) == 2 and int(kv.n_pages[1]) == 3
    assert kv.ensure_capacity(0, 7)  # already covered: no new pages
    assert int(kv.n_pages[0]) == 2
    # slots own disjoint non-dummy blocks
    own0 = set(kv.tables[0, :2].tolist())
    own1 = set(kv.tables[1, :3].tolist())
    assert DUMMY_BLOCK not in own0 | own1 and not own0 & own1
    # flat_idx walks pages in order; unallocated positions hit the dummy page
    blk = int(kv.tables[1, 1])
    assert kv.flat_idx(1, 5) == blk * 4 + 1
    assert kv.flat_idx(0, 12) < 4  # past slot 0's 2 pages -> dummy cells
    # exhaustion: 5 usable blocks all allocated -> growing slot 0 fails
    assert not kv.ensure_capacity(0, 12)
    assert int(kv.n_pages[0]) == 2
    kv.release(1)
    assert kv.allocator.free_blocks == 3
    assert int(kv.n_pages[1]) == 0 and set(kv.tables[1].tolist()) == {DUMMY_BLOCK}
    assert kv.ensure_capacity(0, 12)  # freed blocks admit the growth


def test_engine_rejects_oversized_and_unsupported():
    cfg = get_arch("gemma-2b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, EngineConfig(max_slots=2, max_seq_len=32))
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.arange(20), max_new_tokens=20))
    xl = get_arch("xlstm-350m", reduced=True)
    with pytest.raises(NotImplementedError):
        Engine(xl, api.init(jax.random.PRNGKey(0), xl), EngineConfig())


# ---------------------------------------------------------------------------
# Ragged attention primitives
# ---------------------------------------------------------------------------

def test_decode_attention_vector_valid_len(key):
    """A (B,) per-row valid_len equals per-row scalar calls bit for bit."""
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (3, 4, 1, 16))
    kc = jax.random.normal(kk, (3, 2, 24, 16))
    vc = jax.random.normal(kv_, (3, 2, 24, 16))
    lens = jnp.asarray([5, 24, 13])
    got = decode_attention(q, kc, vc, lens)
    for b in range(3):
        want = decode_attention(q[b : b + 1], kc[b : b + 1], vc[b : b + 1], lens[b])
        np.testing.assert_array_equal(np.asarray(got[b : b + 1]), np.asarray(want))


def test_attention_step_vector_pos(key):
    """Vector-pos attention_step == per-row scalar-pos steps, bit for bit."""
    from repro.models.blocks import init_attention

    cfg = get_arch("gemma-2b", reduced=True)
    kp, kx = jax.random.split(key)
    p = init_attention(kp, cfg)
    b, s = 3, 16
    x = jax.random.normal(kx, (b, 1, cfg.d_model))
    cache = init_attn_cache(cfg, b, s, jnp.float32)
    cache = jax.tree.map(lambda a: a + jax.random.normal(key, a.shape), cache)
    pos = jnp.asarray([2, 9, 0], jnp.int32)
    got, got_cache = attention_step(p, cfg, x, cache, pos)
    for i in range(b):
        sub = jax.tree.map(lambda a: a[i : i + 1], cache)
        want, want_cache = attention_step(p, cfg, x[i : i + 1], sub, pos[i])
        np.testing.assert_array_equal(np.asarray(got[i : i + 1]), np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(got_cache["k"][i]), np.asarray(want_cache["k"][0])
        )


# ---------------------------------------------------------------------------
# Paged prefill/decode vs the static contiguous-cache path
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gemma():
    cfg = get_arch("gemma-2b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_paged_chunked_prefill_and_decode_bit_exact(gemma):
    """Chunked prefill + paged decode against garbage-filled, out-of-order
    physical pages reproduces the static path's logits bit for bit.

    NOTE the view here is 4 pages (the engine always buckets page counts to
    powers of two): XLA's softmax-denominator reduce may associate valid
    terms differently for *other* axis extents (one-ulp logit wobble, e.g. a
    5-page view) — which is why the engine's pinned contract is bit-identical
    TOKEN streams, not logits; argmax/gumbel gaps sit ~7 orders of magnitude
    above that wobble.  Logit equality at the bucketed extents is asserted
    because it's what the engine actually dispatches."""
    cfg, params = gemma
    prompt_len, gen, page = 11, 4, 4
    batch = api.make_batch(cfg, jax.random.PRNGKey(1), 1, prompt_len)

    logits_pf, pf_cache = api.prefill(params, cfg, batch)
    cache = api.merge_prefill_cache(
        cfg, api.init_cache(cfg, 1, prompt_len + gen), pf_cache
    )
    tok = jnp.argmax(logits_pf[:, -1:], axis=-1).astype(jnp.int32)
    want_logits = []
    for i in range(gen - 1):
        lg, cache = api.decode_step(params, cfg, cache, tok, jnp.int32(prompt_len + i))
        want_logits.append(np.asarray(lg))
        tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)

    pools = api.init_paged_pools(cfg, 16 * page)
    pools = jax.tree.map(lambda a: a + 777.0, pools)  # stale-pool garbage
    table = np.asarray([9, 3, 11, 5], np.int32)  # out-of-order pages
    table_j = jnp.asarray(table[None])  # (1, P)
    chunk = 4
    start = 0
    while start < prompt_len:
        c = min(chunk, prompt_len - start)
        tk = np.zeros((1, chunk), np.int32)
        tk[0, :c] = np.asarray(batch["tokens"][0, start : start + c])
        lg, pools = api.prefill_chunk(
            params, cfg, pools, table_j, jnp.asarray(tk),
            jnp.int32(start), jnp.int32(start + c), jnp.int32(c - 1), page,
        )
        start += c
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(logits_pf))

    tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
    for i in range(gen - 1):
        pos = prompt_len + i
        lg, pools = api.decode_step_paged(
            params, cfg, pools, table_j, tok, jnp.asarray([pos], jnp.int32), page
        )
        np.testing.assert_array_equal(np.asarray(lg), want_logits[i])
        tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Engine end to end: ragged parity vs solo generation
# ---------------------------------------------------------------------------

def _mk_requests(cfg, specs):
    reqs = []
    for rid, (plen, gen, greedy, seed) in enumerate(specs):
        prompt = np.asarray(
            jax.random.randint(jax.random.PRNGKey(100 + rid), (plen,), 0, cfg.vocab_size)
        )
        reqs.append(
            Request(rid=rid, prompt=prompt, max_new_tokens=gen, greedy=greedy, seed=seed)
        )
    return reqs


def _solo(cfg, params, req, gen_len=None):
    batch = {"tokens": jnp.asarray(req.prompt)[None]}
    toks, _ = generate(
        cfg, params, batch, gen_len=gen_len or req.max_new_tokens,
        greedy=req.greedy, seed=req.seed,
    )
    return [int(t) for t in np.asarray(toks[0])]


def test_engine_parity_mixed_ragged_requests(gemma):
    """Mixed prompt lengths, greedy + sampled, more requests than slots
    (mid-flight admission), chunked prefill — token streams bit-identical
    to solo generation."""
    cfg, params = gemma
    specs = [(11, 5, True, 0), (7, 8, False, 3), (19, 3, True, 1), (4, 1, True, 0)]
    reqs = _mk_requests(cfg, specs)
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=8,
                     decode_quantum=4),
    )
    results = eng.run(reqs)
    for req, res in zip(reqs, results):
        assert res.tokens == _solo(cfg, params, req), f"rid {req.rid}"
    # 4 requests through 2 slots: continuous batching actually reused slots
    assert eng.stats["decode_dispatches"] + eng.stats["fused_dispatches"] >= 2
    assert eng.stats["tokens_emitted"] == sum(g for _, g, _, _ in specs)
    assert eng.stats["compiled_variants"] <= 8  # bucketing bounds variants


def test_engine_eos_retires_midstream(gemma):
    """EOS at different steps truncates streams exactly where solo
    generation emits the EOS token, and frees the slot for queued work."""
    cfg, params = gemma
    specs = [(11, 8, True, 0), (7, 8, False, 3), (9, 8, True, 5)]
    reqs = _mk_requests(cfg, specs)
    solos = [_solo(cfg, params, r) for r in reqs]
    # choose per-request EOS = the token solo emits at steps 4 / 2 / never
    reqs[0].eos_id = solos[0][4]
    cut0 = solos[0].index(reqs[0].eos_id) + 1  # EOS may appear earlier
    reqs[1].eos_id = solos[1][2]
    cut1 = solos[1].index(reqs[1].eos_id) + 1
    reqs[2].eos_id = -1  # never fires
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=1, page_size=8, max_seq_len=64, prefill_chunk=16,
                     decode_quantum=4),
    )
    r0, r1, r2 = eng.run(reqs)
    assert r0.tokens == solos[0][:cut0]
    assert r1.tokens == solos[1][:cut1]
    assert r2.tokens == solos[2]


def test_engine_unsorted_arrival_times(gemma):
    """run() accepts requests in any submission order — admission is FIFO
    in *arrival* order (an unsorted head used to wedge the queue and raise
    a spurious capacity error)."""
    cfg, params = gemma
    specs = [(6, 3, True, 0), (9, 2, True, 1)]
    reqs = _mk_requests(cfg, specs)
    reqs[0].arrival_time = 0.15  # later arrival submitted first
    reqs[1].arrival_time = 0.0
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=1, page_size=8, max_seq_len=32, prefill_chunk=8,
                     decode_quantum=2),
    )
    for req, res in zip(reqs, eng.run(reqs)):
        assert res.tokens == _solo(cfg, params, req)
    assert eng.results[reqs[1].rid].t_done <= eng.results[reqs[0].rid].t_done


def test_engine_single_slot_serializes_with_parity(gemma):
    """max_slots=1 degenerates to sequential serving — still exact."""
    cfg, params = gemma
    specs = [(5, 4, False, 9), (13, 3, True, 0)]
    reqs = _mk_requests(cfg, specs)
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=1, page_size=4, max_seq_len=32, prefill_chunk=4,
                     decode_quantum=2),
    )
    for req, res in zip(reqs, eng.run(reqs)):
        assert res.tokens == _solo(cfg, params, req)


@pytest.fixture(scope="module")
def deployed(gemma):
    cfg, params = gemma
    plan = build_deployment(
        params, CrossbarSpec(rows=128, cols=10), PlannerConfig(p_stuck=0.5, min_size=1024)
    )
    return cfg, params, plan


@pytest.mark.parametrize("materialize", ["dense", "packed", "planes_int8"])
def test_engine_parity_all_materializations(deployed, materialize):
    """The acceptance pin: engine streams == solo streams for every serving
    materialization (packed/int8 operands flow through models.layers.linear
    inside the paged dispatches unchanged)."""
    cfg, params, plan = deployed
    p_hat = deploy_params(params, plan, materialize=materialize)
    specs = [(9, 4, True, 0), (5, 6, False, 2)]
    reqs = _mk_requests(cfg, specs)
    eng = Engine(
        cfg, p_hat,
        EngineConfig(max_slots=2, page_size=8, max_seq_len=32, prefill_chunk=8,
                     decode_quantum=3),
    )
    for req, res in zip(reqs, eng.run(reqs)):
        assert res.tokens == _solo(cfg, p_hat, req), f"rid {req.rid} ({materialize})"


# ---------------------------------------------------------------------------
# Fused prefill+decode dispatch
# ---------------------------------------------------------------------------

def test_fused_and_split_engines_emit_identical_streams(gemma):
    """The fused dispatch is a scheduling change, not a numerics change:
    the same trace served fused and split produces identical per-request
    token streams (and the fused engine actually used fused dispatches)."""
    cfg, params = gemma
    specs = [(11, 6, True, 0), (7, 7, False, 3), (14, 4, True, 1), (5, 5, False, 2)]
    kw = dict(max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=8,
              decode_quantum=4)
    fused = Engine(cfg, params, EngineConfig(fused=True, **kw))
    rf = fused.run(_mk_requests(cfg, specs))
    split = Engine(cfg, params, EngineConfig(fused=False, **kw))
    rs = split.run(_mk_requests(cfg, specs))
    for a, b in zip(rf, rs):
        assert a.tokens == b.tokens, f"rid {a.rid}"
    assert fused.stats["fused_dispatches"] >= 1
    assert split.stats["fused_dispatches"] == 0
    assert split.stats["decode_dispatches"] >= 1


def test_fused_mid_batch_prompt_finish_rolls_into_decode(gemma):
    """A row whose prompt finishes inside a fused dispatch samples its first
    token in-graph and decodes the rest of the quantum in the same dispatch
    — stream still bit-identical to solo, with fewer total dispatches than
    one-per-phase scheduling would need."""
    cfg, params = gemma
    # one long decoder occupying the batch + one late arrival whose prefill
    # finishes mid-flight while the other row decodes
    specs = [(6, 12, False, 7), (9, 6, True, 0)]
    reqs = _mk_requests(cfg, specs)
    reqs[1].arrival_time = 0.01
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=16,
                     decode_quantum=4, fused=True),
    )
    results = eng.run(reqs)
    for req, res in zip(reqs, results):
        assert res.tokens == _solo(cfg, params, req), f"rid {req.rid}"
    assert eng.stats["fused_dispatches"] >= 1


# ---------------------------------------------------------------------------
# Preemption under block pressure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preempt", ["swap", "recompute"])
@pytest.mark.parametrize("fused", [True, False])
def test_preemption_parity_under_block_pressure(gemma, preempt, fused):
    """A pool too small for the concurrent working set forces preemption;
    every stream — including the preempted + re-admitted request — stays
    bit-identical to solo generation for both victim-KV policies."""
    cfg, params = gemma
    specs = [(9, 8, True, 0), (11, 10, False, 3), (8, 12, True, 1)]
    reqs = _mk_requests(cfg, specs)
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=3, page_size=4, max_seq_len=32, prefill_chunk=4,
                     decode_quantum=4, num_blocks=9, fused=fused, preempt=preempt),
    )
    # 8 usable blocks vs ceil(16/4)+ceil(20/4)+ceil(19/4) = 14 blocks of
    # concurrent worst-case demand
    results = eng.run(reqs)
    for req, res in zip(reqs, results):
        assert res.tokens == _solo(cfg, params, req), f"rid {req.rid} ({preempt})"
    assert eng.stats["preemptions"] >= 1
    assert eng.stats["readmissions"] == eng.stats["preemptions"]
    if preempt == "swap":
        assert eng.stats["swap_ins"] >= 1
    else:
        assert eng.stats["swap_ins"] == 0


def test_free_list_exhaustion_mid_prefill_preempts_decode(gemma):
    """A higher-priority prompt running out of blocks *mid-prefill* swaps
    out the lowest-priority decode slot rather than stalling; both streams
    stay exact."""
    cfg, params = gemma
    # rid 0 (higher priority): long prompt prefilling in small chunks;
    # rid 1: short prompt, long generation — decodes ahead, eats blocks
    specs = [(24, 2, True, 0), (4, 16, True, 1)]
    reqs = _mk_requests(cfg, specs)
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=2, page_size=4, max_seq_len=32, prefill_chunk=4,
                     decode_quantum=4, num_blocks=9, fused=True, preempt="swap"),
    )
    results = eng.run(reqs)
    for req, res in zip(reqs, results):
        assert res.tokens == _solo(cfg, params, req), f"rid {req.rid}"
    assert eng.stats["preemptions"] >= 1


def test_overcommitted_trace_completes(gemma):
    """More concurrent requests than the pool has blocks for: lazy
    allocation admits them all and preemption keeps every stream exact —
    the reserve-up-front policy could not even have admitted this mix."""
    cfg, params = gemma
    specs = [(6, 10, True, s) for s in range(5)]
    specs[2] = (6, 10, False, 2)  # one sampled row rides along
    reqs = _mk_requests(cfg, specs)
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=4, page_size=4, max_seq_len=32, prefill_chunk=4,
                     decode_quantum=4, num_blocks=7, fused=True, preempt="swap"),
    )
    # 6 usable blocks; each request's footprint is ceil(15/4) = 4 blocks, so
    # even two concurrent requests over-commit the pool
    results = eng.run(reqs)
    for req, res in zip(reqs, results):
        assert res.tokens == _solo(cfg, params, req), f"rid {req.rid}"
    assert len(results) == len(reqs)
    assert eng.stats["preemptions"] >= 1


def test_submit_rejects_requests_larger_than_pool():
    cfg = get_arch("gemma-2b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=1, page_size=4, max_seq_len=32, num_blocks=3),
    )
    with pytest.raises(ValueError, match="usable blocks"):
        eng.submit(Request(rid=0, prompt=np.arange(6), max_new_tokens=4))


# ---------------------------------------------------------------------------
# Swap-out / swap-in at the paged-cache level
# ---------------------------------------------------------------------------

def test_swap_roundtrip_restores_bytes_into_different_blocks():
    """swap_out -> release -> re-allocate -> swap_in restores every live
    cell byte-identical even though the physical blocks differ; the dummy
    block is never allocated, snapshotted, or written by the restore."""
    kv = PagedKVCache(PagedCacheConfig(page_size=4, num_blocks=9, max_slots=2, max_pages=6))
    # two pools mimicking one segment's k/v in the engine's token-major
    # (count, T, Hkv, hd) layout (cell axis -3): distinct cell fingerprints
    t = kv.cfg.num_tokens
    pools = {
        "k": jnp.arange(2 * t * 3, dtype=jnp.float32).reshape(2, t, 1, 3),
        "v": -jnp.arange(2 * t * 3, dtype=jnp.float32).reshape(2, t, 1, 3),
    }
    assert kv.ensure_capacity(0, 11)  # 3 pages
    assert kv.ensure_capacity(1, 5)  # 2 pages (forces slot 0 to move later)
    cells_before = kv.slot_cells(0, 11)
    want = {k: np.asarray(v[:, cells_before]) for k, v in pools.items()}

    snap = paged_cache.swap_out(pools, kv, 0, 11)
    for leaf in jax.tree.leaves(snap):
        assert isinstance(leaf, np.ndarray) and leaf.shape[1] == 11
    kv.release(0)
    # churn the free list so slot 0 lands on different physical blocks
    assert kv.ensure_capacity(1, 17)  # slot 1 grabs freed blocks
    assert kv.ensure_capacity(0, 11)
    cells_after = kv.slot_cells(0, 11)
    assert set(cells_after.tolist()) != set(cells_before.tolist())
    assert not np.any(cells_after // 4 == DUMMY_BLOCK)

    cells_other = kv.slot_cells(1, 17)
    other_before = np.asarray(pools["k"][:, cells_other])
    pools = paged_cache.swap_in(pools, kv, 0, snap)
    got = {k: np.asarray(v[:, cells_after]) for k, v in pools.items()}
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])
    # the other slot's live cells are untouched by the restore (pad cells of
    # the bucketed scatter land in the dummy page, never in live blocks)
    np.testing.assert_array_equal(np.asarray(pools["k"][:, cells_other]), other_before)


def test_slot_cells_rejects_unallocated_range():
    kv = PagedKVCache(PagedCacheConfig(page_size=4, num_blocks=4, max_slots=1, max_pages=3))
    assert kv.ensure_capacity(0, 4)
    with pytest.raises(ValueError, match="allocation"):
        kv.slot_cells(0, 9)


def test_allocator_never_hands_out_dummy_block():
    a = BlockAllocator(num_blocks=6)
    got = a.alloc(5)
    assert DUMMY_BLOCK not in got and sorted(got) == [1, 2, 3, 4, 5]
    assert a.alloc(1) is None  # exhausted without ever touching block 0


def test_prepare_serving_params_densifies_once_off_tpu(deployed):
    """On non-TPU backends preparation decompresses packed operands to dense
    host-side, once — the prepared tree has no operand dicts left, and a
    second preparation is a structural no-op."""
    cfg, params, plan = deployed
    from repro.core import simulator
    from repro.kernels._util import on_tpu

    packed = deploy_params(params, plan, materialize="packed")
    prepared = steps.prepare_serving_params(packed)
    if on_tpu():
        pytest.skip("TPU serves packed operands natively")
    has_ops = any(
        isinstance(x, dict) and "planes_packed" in x
        for x in jax.tree.leaves(
            prepared, is_leaf=lambda t: isinstance(t, dict) and "planes_packed" in t
        )
    )
    assert not has_ops
    again = steps.prepare_serving_params(prepared)
    assert jax.tree.structure(again) == jax.tree.structure(prepared)
    # and the dense weights are the achieved weights
    dense = deploy_params(params, plan)
    for a, b in zip(jax.tree.leaves(prepared), jax.tree.leaves(dense)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Deadlines, cancellation, pluggable preemption victim keys
# ---------------------------------------------------------------------------

def test_deadline_timeout_retires_partial_and_frees_blocks(gemma):
    """A slot past its deadline retires with status="timeout": the tokens
    emitted in time are returned (a strict prefix of the solo stream), its
    blocks go back to the pool, and the engine keeps serving."""
    cfg, params = gemma
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=1, page_size=8, max_seq_len=64,
                     prefill_chunk=16, decode_quantum=4),
    )
    free0 = eng.kv.allocator.free_blocks
    req = Request(rid=0, prompt=np.arange(5) % cfg.vocab_size,
                  max_new_tokens=40, greedy=True, seed=0, deadline_s=1.0)
    eng.submit(req)
    now = 0.0
    while 0 not in eng.results:
        eng.step(now)
        now += 0.4  # virtual clock: deadline crossed after ~3 cycles
    res = eng.results[0]
    assert res.status == "timeout"
    assert 0 < len(res.tokens) < 40  # partial: decoded a few quanta, not all
    assert res.tokens == _solo(cfg, params, req)[: len(res.tokens)]
    assert eng.kv.allocator.free_blocks == free0  # blocks freed on retire
    assert eng.stats["timeouts"] == 1


def test_deadline_expires_in_waiting_queue(gemma):
    """A request whose deadline passes while still queued (slots full)
    times out with zero tokens instead of waiting forever."""
    cfg, params = gemma
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=1, page_size=8, max_seq_len=64,
                     prefill_chunk=16, decode_quantum=4),
    )
    hog = Request(rid=0, prompt=np.arange(4) % cfg.vocab_size,
                  max_new_tokens=30, greedy=True, seed=0)
    queued = Request(rid=1, prompt=np.arange(6) % cfg.vocab_size,
                     max_new_tokens=4, greedy=True, seed=1, deadline_s=0.5)
    eng.submit(hog)
    eng.submit(queued)
    now = 0.0
    while 1 not in eng.results:
        eng.step(now)
        now += 0.4
    assert eng.results[1].status == "timeout"
    assert eng.results[1].tokens == []
    # the hog is unaffected: runs to completion, exact
    while 0 not in eng.results:
        eng.step(now)
        now += 0.4
    assert eng.results[0].status == "ok"
    assert eng.results[0].tokens == _solo(cfg, params, hog)


def test_cancel_running_and_waiting(gemma):
    """cancel() retires a running slot with its partial tokens (blocks
    freed) and drops a waiting request; unknown/finished rids return
    False.  The surviving request's stream stays exact."""
    cfg, params = gemma
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=1, page_size=8, max_seq_len=64,
                     prefill_chunk=16, decode_quantum=4),
    )
    free0 = eng.kv.allocator.free_blocks
    running = Request(rid=0, prompt=np.arange(5) % cfg.vocab_size,
                      max_new_tokens=30, greedy=True, seed=0)
    waiting = Request(rid=1, prompt=np.arange(4) % cfg.vocab_size,
                      max_new_tokens=4, greedy=True, seed=1)
    eng.submit(running)
    eng.submit(waiting)
    eng.step(0.0)
    eng.step(0.1)
    assert eng.cancel(0, now=0.2)
    res = eng.results[0]
    assert res.status == "cancelled" and 0 < len(res.tokens) < 30
    assert res.tokens == _solo(cfg, params, running)[: len(res.tokens)]
    assert not eng.cancel(0, now=0.2)  # already finished
    assert not eng.cancel(99, now=0.2)  # unknown
    assert eng.cancel(1, now=0.2)  # still waiting: dropped with no tokens
    assert eng.results[1].status == "cancelled" and eng.results[1].tokens == []
    assert eng.stats["cancels"] == 2
    assert eng.kv.allocator.free_blocks == free0


def test_victim_key_policies_ordering():
    """fcfs: protection is strict arrival order.  priority_class: class
    outranks arrival (a later high-priority arrival is protected over an
    earlier batch-tier one); decode preferred among candidates in both."""
    from repro.launch.engine import SlotView, fcfs_victim_key, priority_class_victim_key

    early_batch = SlotView(rid=0, arrival_time=0.0, priority_class=2,
                           decoding=True, generated=3, deadline_s=None)
    late_urgent = SlotView(rid=1, arrival_time=1.0, priority_class=0,
                           decoding=False, generated=0, deadline_s=None)
    # FCFS: the late arrival is the less-protected (evicted-first) slot
    assert fcfs_victim_key(late_urgent)[0] > fcfs_victim_key(early_batch)[0]
    # priority classes invert that: the batch-tier slot is evicted first
    assert priority_class_victim_key(early_batch)[0] > priority_class_victim_key(late_urgent)[0]
    # preference part: decode slots win ties among candidates
    assert fcfs_victim_key(early_batch)[1] > fcfs_victim_key(late_urgent)[1]


def test_engine_config_rejects_uncallable_victim_key():
    with pytest.raises(ValueError, match="victim_key"):
        EngineConfig(victim_key=42)


def test_priority_class_preemption_parity(gemma):
    """Overcommitted pool with the priority-class victim key: the earliest
    arrival — which plain FCFS would protect above everyone — is the batch
    tier and absorbs the preemptions; every stream (including its own,
    bounced and re-admitted) stays exact, and the interactive tier
    finishes first."""
    from repro.launch.engine import priority_class_victim_key

    cfg, params = gemma
    specs = [(6, 10, True, s) for s in range(4)]
    reqs = _mk_requests(cfg, specs)
    reqs[0].priority_class = 2  # earliest arrival, lowest tier
    eng = Engine(
        cfg, params,
        EngineConfig(max_slots=4, page_size=4, max_seq_len=32, prefill_chunk=4,
                     decode_quantum=4, num_blocks=7, fused=True, preempt="swap",
                     victim_key=priority_class_victim_key),
    )
    results = eng.run(reqs)
    for req, res in zip(reqs, results):
        assert res.tokens == _solo(cfg, params, req), f"rid {req.rid}"
    assert eng.stats["preemptions"] >= 1
    # the batch-tier request took the evictions: it retires last
    batch_done = eng.results[0].t_done
    assert all(eng.results[r.rid].t_done <= batch_done for r in reqs)
