"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitslice
from repro.kernels.bitslice import ops as bs_ops, ref as bs_ref
from repro.kernels.cim_matmul import ops as cm_ops, ref as cm_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.hamming import ops as hm_ops, ref as hm_ref


# ---------------------------------------------------------------------------
# hamming
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,w,c", [(1, 1, 1), (7, 16, 10), (256, 16, 16), (300, 5, 3)])
def test_hamming_shapes(t, w, c):
    rng = np.random.default_rng(t * 1000 + w * 10 + c)
    a = jnp.asarray(rng.integers(0, 256, (t, w, c)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 256, (t, w, c)), jnp.uint8)
    np.testing.assert_array_equal(hm_ops.hamming_pairs(a, b), hm_ref.hamming_pairs(a, b))


def test_hamming_chain_costs(key):
    planes = jax.random.bernoulli(key, 0.5, (10, 32, 8))
    packed = bitslice.pack_rows(planes)
    got = hm_ops.chain_costs(packed)
    from repro.core import cost

    want = cost.consecutive_costs(planes, include_initial=False)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# bitslice
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,n,cols", [(8, 128, 4), (100, 60, 10), (256, 256, 8), (1, 1, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bitslice_shapes_dtypes(k, n, cols, dtype):
    rng = np.random.default_rng(k + n + cols)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.1, dtype)
    inv_scale = (2**cols - 1) / max(float(jnp.max(jnp.abs(w.astype(jnp.float32)))), 1e-9)
    got = bs_ops.bitslice_planes(w, inv_scale, cols)
    want = bs_ref.bitslice_planes(w, jnp.float32(inv_scale), cols)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# cim_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,cols", [(4, 32, 16, 4), (17, 100, 60, 8), (128, 128, 128, 10)])
@pytest.mark.parametrize("mode", ["fused_dequant", "planes"])
def test_cim_matmul_shapes_modes(m, k, n, cols, mode):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 7 + n))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n)) * 0.1
    inv_scale = (2**cols - 1) / float(jnp.max(jnp.abs(w)))
    sp = bs_ref.bitslice_planes(w, jnp.float32(inv_scale), cols)
    scale = 1.0 / inv_scale
    got = cm_ops.cim_matmul(x, sp, scale, mode=mode)
    want = cm_ref.cim_matmul(x, sp, jnp.float32(scale))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_cim_matmul_equals_dense_quantized(key):
    """The end-to-end contract: CIM output == x @ w_quantized."""
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (8, 64))
    w = jax.random.normal(kw, (64, 32)) * 0.1
    qt = bitslice.quantize(w, 10)
    sp = bs_ref.bitslice_planes(w, 1.0 / qt.scale, 10)
    y = cm_ops.cim_matmul(x, sp, qt.scale)
    w_hat = bitslice.dequantize(qt).reshape(w.shape)
    np.testing.assert_allclose(y, x @ w_hat, rtol=1e-4, atol=1e-5)


def test_cim_matmul_bf16_activations(key):
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (16, 32)).astype(jnp.bfloat16)
    w = jax.random.normal(kw, (32, 16)) * 0.1
    sp = bs_ref.bitslice_planes(w, 100.0, 8)
    got = cm_ops.cim_matmul(x, sp, 0.01)
    want = cm_ref.cim_matmul(x, sp, jnp.float32(0.01))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,d,kind,window,q_offset",
    [
        (2, 4, 2, 64, 64, 32, "causal", None, 0),
        (1, 4, 1, 48, 80, 16, "causal", None, 32),  # decode-continuation chunk
        (2, 2, 2, 64, 64, 32, "bidir", None, 0),
        (1, 4, 2, 96, 96, 32, "swa", 24, 0),
        (1, 1, 1, 8, 8, 8, "causal", None, 0),  # tiny
    ],
)
def test_flash_attention_vs_ref(b, hq, hkv, sq, sk, d, kind, window, q_offset):
    ks = jax.random.split(jax.random.PRNGKey(b * 100 + sq), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d))
    k = jax.random.normal(ks[1], (b, hkv, sk, d))
    v = jax.random.normal(ks[2], (b, hkv, sk, d))
    got = fa_ops.flash_attention(
        q, k, v, kind=kind, window=window, q_offset=q_offset, bq=32, bk=32
    )
    want = fa_ref.flash_attention(q, k, v, kind=kind, window=window, q_offset=q_offset)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_traced_kv_valid_len(key):
    """The traced cache-tail mask (paged engine prefill) agrees with the ref
    and with the blockwise path's kv_valid_len, without recompiling per
    length — the valid length is an SMEM operand, not a static arg."""
    from repro.models.attention import blockwise_attention

    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 4, 24, 16))
    k = jax.random.normal(ks[1], (1, 2, 64, 16))
    v = jax.random.normal(ks[2], (1, 2, 64, 16))
    for kvl in (7, 23, 40, 64):
        got = fa_ops.flash_attention(
            q, k, v, jnp.int32(kvl), kind="causal", q_offset=16, bq=8, bk=8
        )
        want = fa_ref.flash_attention(q, k, v, jnp.int32(kvl), kind="causal", q_offset=16)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
        bw = blockwise_attention(
            q, k, v, kind="causal", q_offset=16, block_k=8, kv_valid_len=jnp.int32(kvl)
        )
        np.testing.assert_allclose(got, bw, rtol=2e-5, atol=2e-5)


def test_flash_attention_per_row_offsets_and_valid_lens(key):
    """Per-row traced q_offset + kv_valid_len (fused prefill+decode rows at
    different prompt positions / live cache extents) equal per-row scalar
    calls and the ref — one compiled kernel, SMEM-indexed per batch row."""
    from repro.models.attention import blockwise_attention

    ks = jax.random.split(key, 3)
    b = 3
    q = jax.random.normal(ks[0], (b, 4, 16, 16))
    k = jax.random.normal(ks[1], (b, 2, 64, 16))
    v = jax.random.normal(ks[2], (b, 2, 64, 16))
    offs = jnp.asarray([0, 17, 40], jnp.int32)
    kvls = jnp.asarray([16, 33, 56], jnp.int32)
    got = fa_ops.flash_attention(
        q, k, v, kvls, kind="causal", q_offset=offs, bq=8, bk=8
    )
    want = fa_ref.flash_attention(q, k, v, kvls, kind="causal", q_offset=offs)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    bw = blockwise_attention(
        q, k, v, kind="causal", q_offset=offs, block_k=8, kv_valid_len=kvls
    )
    np.testing.assert_allclose(got, bw, rtol=2e-5, atol=2e-5)
    for r in range(b):
        solo = fa_ops.flash_attention(
            q[r : r + 1], k[r : r + 1], v[r : r + 1], kvls[r],
            kind="causal", q_offset=int(offs[r]), bq=8, bk=8,
        )
        np.testing.assert_array_equal(np.asarray(got[r : r + 1]), np.asarray(solo))


def test_flash_attention_matches_blockwise_module(key):
    """The pure-JAX blockwise attention (model default) and the Pallas kernel
    implement the same contract."""
    from repro.models.attention import blockwise_attention

    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 4, 64, 32))
    k = jax.random.normal(ks[1], (2, 2, 64, 32))
    v = jax.random.normal(ks[2], (2, 2, 64, 32))
    a = blockwise_attention(q, k, v, kind="causal", block_k=32)
    b = fa_ops.flash_attention(q, k, v, kind="causal", bq=32, bk=32)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
