"""Unit + property tests for core.bitslice (quantization / planes / packing)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import bitslice


@pytest.mark.parametrize("encoding", ["sign_magnitude", "offset_binary"])
@pytest.mark.parametrize("cols", [4, 8, 10, 16])
def test_quantize_roundtrip_error_bound(key, encoding, cols):
    w = jax.random.normal(key, (512,)) * 0.05
    qt = bitslice.quantize(w, cols, encoding)
    w_hat = bitslice.dequantize(qt)
    # max error is half a quantization step
    assert float(jnp.max(jnp.abs(w - w_hat))) <= float(qt.scale) * 0.5 + 1e-7


def test_quantize_zero_tensor(key):
    qt = bitslice.quantize(jnp.zeros((64,)), 8)
    assert int(jnp.sum(qt.q)) == 0
    np.testing.assert_allclose(bitslice.dequantize(qt), 0.0)


@given(
    q=st.lists(st.integers(0, 2**10 - 1), min_size=1, max_size=64),
    cols=st.sampled_from([10]),
)
def test_bitplanes_reconstruct(q, cols):
    qa = jnp.asarray(q, jnp.int32)
    planes = bitslice.bitplanes(qa, cols)
    weights = 2 ** jnp.arange(cols, dtype=jnp.int32)
    recon = jnp.sum(planes.astype(jnp.int32) * weights, axis=-1)
    np.testing.assert_array_equal(recon, qa)


def test_dequantize_from_planes_matches_dequantize(key):
    w = jax.random.normal(key, (300,)) * 0.1
    qt = bitslice.quantize(w, 10)
    planes = bitslice.bitplanes(qt.q, 10)
    w_hat = bitslice.dequantize_from_planes(planes, qt.sign, qt.scale, qt.offset)
    np.testing.assert_allclose(w_hat, bitslice.dequantize(qt), rtol=1e-6)


@given(rows=st.integers(1, 40), s=st.integers(1, 5), cols=st.integers(1, 12))
def test_pack_unpack_roundtrip(rows, s, cols):
    rng = np.random.default_rng(rows * 100 + s * 10 + cols)
    planes = jnp.asarray(rng.integers(0, 2, (s, rows, cols)), jnp.bool_)
    packed = bitslice.pack_rows(planes)
    assert packed.shape == (s, -(-rows // 8), cols)
    np.testing.assert_array_equal(bitslice.unpack_rows(packed, rows), planes)


@given(n=st.integers(1, 1000), rows=st.sampled_from([8, 32, 128]))
def test_section_unsection_roundtrip(n, rows):
    flat = jnp.arange(n, dtype=jnp.float32)
    sections, n_out = bitslice.section(flat, rows)
    assert n_out == n
    assert sections.shape[1] == rows
    assert sections.shape[0] == -(-n // rows)
    np.testing.assert_array_equal(bitslice.unsection(sections, n), flat)


def test_section_padding_is_zero(key):
    flat = jnp.ones((100,))
    sections, _ = bitslice.section(flat, 64)
    assert float(jnp.sum(sections)) == 100.0  # pad contributes nothing


# ---------------------------------------------------------------------------
# Serving-layout property tests (pack_linear_planes / pack_linear_sign)
# ---------------------------------------------------------------------------

@given(k=st.integers(1, 50), n=st.integers(1, 9), cols=st.integers(1, 10))
def test_pack_linear_planes_roundtrip_ragged_k(k, n, cols):
    """Serving-layout round trip at K not a multiple of 8: unpacking the
    plane bytes recovers exactly the bitplanes, and every K-padding bit is
    zero (pristine cells -- the kernel's zero-padded activations rely on it)."""
    rng = np.random.default_rng(k * 1000 + n * 10 + cols)
    q = jnp.asarray(rng.integers(0, 2**cols, (k, n)), jnp.int32)
    packed = bitslice.pack_linear_planes(q, cols)
    assert packed.shape == (cols, -(-k // 8), n)
    bits = jnp.unpackbits(packed, axis=-2)  # [cols, Wk*8, n]
    expect = jnp.moveaxis(bitslice.bitplanes(q, cols), -1, -3)
    np.testing.assert_array_equal(np.asarray(bits[:, :k, :]), np.asarray(expect))
    assert not np.asarray(bits[:, k:, :]).any()


@given(k=st.integers(1, 50), n=st.integers(1, 9))
def test_pack_linear_sign_roundtrip_ragged_k(k, n):
    rng = np.random.default_rng(k * 31 + n)
    sign = jnp.asarray(rng.choice([-1, 1], (k, n)), jnp.int8)
    packed = bitslice.pack_linear_sign(sign)
    bits = jnp.unpackbits(packed, axis=-2)
    np.testing.assert_array_equal(np.asarray(bits[:k, :]), np.asarray(sign) < 0)
    # padding sign bits are 0 = +1: they multiply only zero-magnitude cells
    assert not np.asarray(bits[k:, :]).any()


@given(rows=st.sampled_from([7, 9, 100, 128]), cols=st.integers(1, 12))
def test_section_planes_packed_padding_bits_zero(rows, cols):
    """Planner-layout twin of the K-padding invariant: row-padding bits in
    the canonical packed planes are zero for ragged ``rows``."""
    rng = np.random.default_rng(rows * 13 + cols)
    q = jnp.asarray(rng.integers(0, 2**cols, (3 * rows,)), jnp.int32)
    packed = bitslice.section_planes_packed(q, rows, cols)
    bits = jnp.unpackbits(packed, axis=1)  # [S, W*8, cols]
    assert not np.asarray(bits[:, rows:, :]).any()
    recon = np.asarray(bits[:, :rows, :]).reshape(-1, cols)
    w = 2 ** np.arange(cols)
    np.testing.assert_array_equal((recon * w).sum(axis=-1), np.asarray(q))


def test_negative_zero_sign_handling():
    """-0.0 quantizes as non-negative (``flat < 0`` is False), while
    ``operands_from_dense`` recovers stored signs via ``signbit`` so a
    densified -0.0 weight round-trips with its sign bit intact."""
    from repro.core import simulator
    from repro.core.planner import CrossbarSpec

    w = jnp.asarray([[-0.0, 0.5], [-0.25, 0.0]], jnp.float32)
    qt = bitslice.quantize(w.ravel(), 8)
    sgn = np.asarray(qt.sign).reshape(2, 2)
    assert sgn[0, 0] == 1  # -0.0 is NOT negative under the quantizer
    spec = CrossbarSpec(rows=128, cols=8)
    op = simulator.prepare_linear(w, spec, materialize="packed")
    w_hat = np.asarray(simulator.densify_operands(op))
    dq = np.asarray(bitslice.dequantize(qt)).reshape(2, 2)
    np.testing.assert_array_equal(w_hat, dq)
    # a dense w_hat that *does* carry -0.0 keeps its sign bit through the
    # packed round trip (sign plane read back via signbit, not `< 0`)
    w2 = jnp.asarray([[-0.0]], jnp.float32)
    op2 = simulator.operands_from_dense(
        w2, jnp.float32(1.0), jnp.float32(0.0), "sign_magnitude", 8
    )
    bit = np.asarray(jnp.unpackbits(op2["sign_packed"], axis=-2))[0, 0]
    assert bit == 1  # signbit(-0.0) is True
    back = np.asarray(simulator.densify_operands(op2))[0, 0]
    # densify's offset addition normalizes -0.0 to +0.0 (IEEE -0.0 + 0.0),
    # which is numerically identical -- the stored bit above is the contract
    assert back == 0.0
