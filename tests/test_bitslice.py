"""Unit + property tests for core.bitslice (quantization / planes / packing)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import bitslice


@pytest.mark.parametrize("encoding", ["sign_magnitude", "offset_binary"])
@pytest.mark.parametrize("cols", [4, 8, 10, 16])
def test_quantize_roundtrip_error_bound(key, encoding, cols):
    w = jax.random.normal(key, (512,)) * 0.05
    qt = bitslice.quantize(w, cols, encoding)
    w_hat = bitslice.dequantize(qt)
    # max error is half a quantization step
    assert float(jnp.max(jnp.abs(w - w_hat))) <= float(qt.scale) * 0.5 + 1e-7


def test_quantize_zero_tensor(key):
    qt = bitslice.quantize(jnp.zeros((64,)), 8)
    assert int(jnp.sum(qt.q)) == 0
    np.testing.assert_allclose(bitslice.dequantize(qt), 0.0)


@given(
    q=st.lists(st.integers(0, 2**10 - 1), min_size=1, max_size=64),
    cols=st.sampled_from([10]),
)
def test_bitplanes_reconstruct(q, cols):
    qa = jnp.asarray(q, jnp.int32)
    planes = bitslice.bitplanes(qa, cols)
    weights = 2 ** jnp.arange(cols, dtype=jnp.int32)
    recon = jnp.sum(planes.astype(jnp.int32) * weights, axis=-1)
    np.testing.assert_array_equal(recon, qa)


def test_dequantize_from_planes_matches_dequantize(key):
    w = jax.random.normal(key, (300,)) * 0.1
    qt = bitslice.quantize(w, 10)
    planes = bitslice.bitplanes(qt.q, 10)
    w_hat = bitslice.dequantize_from_planes(planes, qt.sign, qt.scale, qt.offset)
    np.testing.assert_allclose(w_hat, bitslice.dequantize(qt), rtol=1e-6)


@given(rows=st.integers(1, 40), s=st.integers(1, 5), cols=st.integers(1, 12))
def test_pack_unpack_roundtrip(rows, s, cols):
    rng = np.random.default_rng(rows * 100 + s * 10 + cols)
    planes = jnp.asarray(rng.integers(0, 2, (s, rows, cols)), jnp.bool_)
    packed = bitslice.pack_rows(planes)
    assert packed.shape == (s, -(-rows // 8), cols)
    np.testing.assert_array_equal(bitslice.unpack_rows(packed, rows), planes)


@given(n=st.integers(1, 1000), rows=st.sampled_from([8, 32, 128]))
def test_section_unsection_roundtrip(n, rows):
    flat = jnp.arange(n, dtype=jnp.float32)
    sections, n_out = bitslice.section(flat, rows)
    assert n_out == n
    assert sections.shape[1] == rows
    assert sections.shape[0] == -(-n // rows)
    np.testing.assert_array_equal(bitslice.unsection(sections, n), flat)


def test_section_padding_is_zero(key):
    flat = jnp.ones((100,))
    sections, _ = bitslice.section(flat, 64)
    assert float(jnp.sum(sections)) == 100.0  # pad contributes nothing
