"""Property tests for the sharding rule table (parallel/sharding.py).

The divisibility fallback law, driven across mesh sizes 1-16: whatever spec
``_resolve`` returns for a leaf, the per-device shard shapes multiply back to
the global shape exactly — a mesh axis is only ever assigned to a dim it
divides (the fallback chain — alternate axis, then replicate — absorbs every
ragged case rather than erroring), stacked-layer leaves always lead with
``None`` for the scan axis, and resolution is spec-length-safe for any rank.
These are the invariants ``parallel/tp.py`` builds its slice rules on.
"""
from __future__ import annotations

import numpy as np
from hypothesis import given, strategies as st

from repro.parallel.sharding import _RULES, _resolve

# Representative leaf paths: one per rule family, plus stacked twins and an
# unmatched name (resolves fully replicated).
_NAMES = [
    "embed/table",
    "head/w",
    "segments/0/attn/wq",
    "segments/0/attn/wk",
    "segments/0/attn/wo",
    "segments/3/mlp/wi_gate",
    "segments/3/mlp/wo",
    "encoder/self/wq",
    "decoder/cross/wo",
    "segments/1/moe/wi_gate",
    "segments/1/moe/wo",
    "segments/1/moe/router",
    "segments/2/mamba/in_proj",
    "segments/2/mamba/out_proj",
    "segments/0/norm/scale",  # no rule: replicated
]

_STACKED_PREFIXES = ("segments/", "encoder/", "decoder/")


def _shard_shape(shape, spec, axis_sizes):
    """Per-device shard shape under ``spec`` (the law asserts exact division
    first, so this is always an integer shape)."""
    out = []
    for dim, ax in zip(shape, tuple(spec)):
        if ax is None:
            out.append(dim)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        k = int(np.prod([axis_sizes[a] for a in axes]))
        assert dim % k == 0, f"spec {spec} assigns indivisible axis: {dim} % {k}"
        out.append(dim // k)
    return tuple(out)


@given(
    name=st.sampled_from(_NAMES),
    model=st.integers(min_value=1, max_value=16),
    data=st.integers(min_value=1, max_value=16),
    d0=st.sampled_from([1, 2, 3, 8, 24, 96, 32001]),
    d1=st.sampled_from([1, 2, 3, 8, 24, 96, 32001]),
    stacked_layers=st.integers(min_value=1, max_value=7),
    fsdp=st.booleans(),
)
def test_resolve_divisibility_fallback_law(
    name, model, data, d0, d1, stacked_layers, fsdp
):
    """Any leaf shape at any mesh size resolves (never raises) to a spec whose
    shard shapes multiply back to the global shape."""
    axis_sizes = {"data": data, "model": model}
    core = (d0, d1)
    stacked = name.startswith(_STACKED_PREFIXES)
    shape = (stacked_layers, *core) if stacked else core
    spec = _resolve(name, shape, axis_sizes, fsdp=fsdp, fsdp_min=2**10)
    entries = tuple(spec)
    assert len(entries) == len(shape), (name, shape, spec)
    if stacked:
        assert entries[0] is None, f"stacked leaf {name} shards its scan axis"
    local = _shard_shape(shape, spec, axis_sizes)
    mult = tuple(
        l * int(np.prod([
            axis_sizes[a]
            for a in ((ax,) if not isinstance(ax, tuple) else ax)
        ])) if ax is not None else l
        for l, ax in zip(local, entries)
    )
    assert mult == shape


@given(
    model=st.integers(min_value=1, max_value=16),
    e=st.sampled_from([2, 3, 6, 8, 60]),
    d_ff=st.sampled_from([16, 48, 64]),
)
def test_moe_fallback_chain_always_lands(model, e, d_ff):
    """Expert-parallel if E divides, TP-within-expert if d_ff does, else
    replicated — the chain never assigns an indivisible axis."""
    shape = (e, 32, d_ff)
    spec = _resolve(
        "segments/0/moe/wi_gate", (4, *shape), {"model": model},
        fsdp=False, fsdp_min=2**62,
    )
    entries = tuple(spec)
    assert entries[0] is None
    _shard_shape((4, *shape), spec, {"model": model})  # asserts divisibility
    if e % model == 0:
        assert entries[1] == "model"  # expert-parallel preferred


@given(n=st.integers(min_value=1, max_value=16))
def test_mesh_size_one_replicates_nothing_away(n):
    """At every mesh size the resolver covers every rule family; at n == 1
    the intended axis always fits (dividing by 1), so the primary rule wins."""
    for pat, rule in _RULES:
        ndim = len(rule)
        shape = tuple(16 for _ in range(ndim))
        name = "segments/0/" + pat.strip("$").replace("(attn|self|cross)", "attn") \
            .replace("(mlp|shared)", "mlp").replace("(wq|wk|wv)", "wq") \
            .replace("(^|/)", "").lstrip("/")
        spec = _resolve(name, (4, *shape), {"model": n}, fsdp=False, fsdp_min=2**62)
        entries = tuple(spec)
        assert len(entries) == ndim + 1
        _shard_shape((4, *shape), spec, {"model": n})
        if all(d % n == 0 for d in shape):
            # the intended axis fits every dim: the primary rule wins verbatim
            assert entries[1:] == rule, (name, rule, entries)
