"""Per-arch smoke tests + decode/forward parity (the serving-correctness test)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import AdamWConfig, adamw_init

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch, key):
    cfg = get_arch(arch, reduced=True)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, 2, 16)
    logits, aux = api.forward(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, key):
    cfg = get_arch(arch, reduced=True)
    params = api.init(key, cfg)
    opt_state = adamw_init(params)
    batch = api.make_batch(cfg, key, 2, 16)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), remat="none"))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert float(metrics["loss"]) > 0 and not np.isnan(float(metrics["loss"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_params)
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ARCHS)
def test_remat_matches_no_remat(arch, key):
    cfg = get_arch(arch, reduced=True)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, 2, 16)
    l0, _ = api.forward(params, cfg, batch, remat="none")
    l1, _ = api.forward(params, cfg, batch, remat="full")
    np.testing.assert_allclose(l0, l1, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, key):
    """prefill(prompt) + decode_step x G reproduces forward() logits.

    This is the fundamental serving-correctness invariant: the incremental
    path (KV caches, ring buffers, recurrent states, absorbed MLA matmuls)
    must match the parallel training path position by position.
    """
    cfg = get_arch(arch, reduced=True)
    if cfg.moe is not None:
        # capacity-style dispatch may drop tokens under load in the parallel
        # path but never in single-token decode; parity is only defined in
        # the drop-free regime, so give the test headroom.
        import dataclasses

        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    b, prompt, gen = 2, 12, 4
    total = prompt + gen
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, b, total)

    full_logits, _ = api.forward(params, cfg, batch)

    # prefill on the prompt prefix
    pf_batch = dict(batch, tokens=batch["tokens"][:, :prompt])
    if cfg.encdec:
        pf_batch["src_embeds"] = batch["src_embeds"][:, :prompt]
        # the encoder context differs between the two paths unless we feed the
        # same src length; re-run the full path with the prompt-length source
        full_logits, _ = api.forward(
            params, cfg, dict(batch, src_embeds=pf_batch["src_embeds"])
        )
    logits_pf, pf_cache = api.prefill(params, cfg, pf_batch)

    cache = api.init_cache(cfg, b, total, src_len=prompt if cfg.encdec else None)
    cache = api.merge_prefill_cache(cfg, cache, pf_cache)

    np.testing.assert_allclose(
        logits_pf[:, -1], full_logits[:, prompt - 1], rtol=2e-4, atol=2e-4
    )

    for i in range(gen):
        tok = batch["tokens"][:, prompt + i : prompt + i + 1]
        logits_i, cache = api.decode_step(params, cfg, cache, tok, jnp.int32(prompt + i))
        np.testing.assert_allclose(
            logits_i[:, 0], full_logits[:, prompt + i], rtol=2e-4, atol=2e-4,
            err_msg=f"{arch}: decode step {i} diverged from forward",
        )


def test_gqa_grouping_matches_repeated_kv(key):
    """blockwise_attention's query-grouping equals the repeat-KV formulation."""
    from repro.models.attention import blockwise_attention

    ks = jax.random.split(key, 3)
    b, hq, hkv, s, d = 2, 8, 2, 32, 16
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    grouped = blockwise_attention(q, k, v, kind="causal", block_k=16)
    rep = hq // hkv
    full = blockwise_attention(
        q, jnp.repeat(k, rep, axis=1), jnp.repeat(v, rep, axis=1), kind="causal", block_k=16
    )
    np.testing.assert_allclose(grouped, full, rtol=1e-5, atol=1e-5)


def test_param_counts_active_vs_total():
    cfg = get_arch("qwen2-moe-a2.7b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    total = api.param_count(params)
    active = api.active_param_count(params, cfg)
    assert active < total  # MoE: most experts inactive per token
