"""Online integrity subsystem (core/integrity.py): detection, localization,
classification, endurance-aware repair, and the engine scrub hook.

The contracts pinned here:

(1) registration parity — with integrity enabled the deployment's expected
    read is recorded at ``program()`` time and ``rebuild`` reproduces the
    deployed weights byte-for-byte;
(2) the scrub loop repairs every storm (corruption → in-place rewrite,
    hard stuck-at → spare-column remap or section migration) back to a
    bit-exact read, with every repair priced via ``price_pairs`` and
    charged to the pool's wear/write counters;
(3) transient read upsets are classified by re-read and never spend a
    repair write;
(4) the engine hook scrubs between dispatch rounds and atomically swaps
    repaired params in via ``hot_swap`` (epoch contract intact).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import integrity, nonideal
from repro.core.integrity import IntegrityConfig, tile_checksums
from repro.core.planner import (
    CrossbarSpec,
    PlannerConfig,
    _analyze_tensor_pool,
    build_deployment,
    deploy_params,
)
from repro.core.pool import CrossbarPool
from repro.launch.engine import Engine, EngineConfig, Request
from repro.launch.serve import generate
from repro.models import api

SPEC = CrossbarSpec(rows=64, cols=8)
PCFG = PlannerConfig(p_stuck=1.0, crossbars=4)


def _setup(icfg: IntegrityConfig | None = None, *, pcfg: PlannerConfig = PCFG,
           fault_model=None):
    """Fresh pool with integrity + one registered tensor; returns
    (pool, manager, deployed w_hat)."""
    pool = CrossbarPool(SPEC, 4, leveling="lpt")
    if fault_model is not None:
        pool.inject_faults(fault_model, jax.random.PRNGKey(5))
    mgr = pool.enable_integrity(icfg or IntegrityConfig())
    w = jax.random.normal(jax.random.PRNGKey(0), (40, 20)) * 0.05
    _, w_hat = _analyze_tensor_pool(w, SPEC, pcfg, jax.random.PRNGKey(1), pool, name="t0")
    return pool, mgr, w_hat


def test_integrity_config_validation():
    for bad in (
        dict(tile_bytes=0), dict(spare_cols=-1), dict(scrub_tiles=0),
        dict(repair_budget=0), dict(tolerate_cols=-1),
        dict(transient_rate=-0.1), dict(transient_rate=1.5),
    ):
        with pytest.raises(ValueError):
            IntegrityConfig(**bad)
    with pytest.raises(ValueError):
        _setup()[1].storm(jax.random.PRNGKey(0), corrupt_rate=2.0)


def test_register_clean_scrub_and_rebuild_parity():
    pool, mgr, w_hat = _setup()
    assert mgr.summary()["tensors"] == 1 and mgr.total_tiles > 0
    assert mgr.verify_all()
    rep = mgr.scrub_until_clean()
    assert rep.detections == 0 and rep.repair_transitions == 0 and mgr.clean
    np.testing.assert_array_equal(np.asarray(mgr.rebuild("t0")), np.asarray(w_hat))


def test_checksums_catch_single_byte_flip():
    planes = np.zeros((1, 16, 2), np.uint8)
    base = tile_checksums(planes, 16)
    for i in (0, 7, 15):
        mod = planes.copy()
        mod[0, i, 1] ^= 0x10
        assert (tile_checksums(mod, 16) != base).any(), f"byte {i} flip missed"


def test_corruption_localized_and_rewritten_in_place():
    """State corruption (writable cells) is localized exactly and repaired by
    in-place rewrites whose priced cost equals the corrupted bit count."""
    pool, mgr, w_hat = _setup()
    writes_before = pool.total_writes
    wear_before = pool.wear.sum()
    st = mgr.storm(jax.random.PRNGKey(7), corrupt_rate=5e-3)
    assert st["corrupted_bits"] > 0 and not mgr.verify_all()
    rep = mgr.scrub_until_clean()
    assert rep.detections > 0 and rep.rewrites > 0
    assert rep.remaps == 0 and rep.migrations == 0
    # exact localization + exact pricing: every corrupted bit found once,
    # every repair transition is one cell toggle charged to pool wear
    assert rep.localized_bits == st["corrupted_bits"]
    assert rep.repair_transitions == st["corrupted_bits"]
    assert pool.total_writes - writes_before == st["corrupted_bits"]
    assert pool.wear.sum() - wear_before == st["corrupted_bits"]
    assert mgr.verify_all() and mgr.clean
    np.testing.assert_array_equal(np.asarray(mgr.rebuild("t0")), np.asarray(w_hat))


def test_hard_stuck_remaps_to_spare_columns():
    pool, mgr, w_hat = _setup(IntegrityConfig(spare_cols=2))
    st = mgr.storm(jax.random.PRNGKey(9), stuck_rate=1e-3)
    assert st["new_stuck_cells"] > 0
    rep = mgr.scrub_until_clean()
    assert rep.remaps > 0
    rec = mgr.tensors["t0"]
    assert (rec.col_map >= SPEC.cols).sum() == rep.remaps
    assert mgr.verify_all() and mgr.clean
    np.testing.assert_array_equal(np.asarray(mgr.rebuild("t0")), np.asarray(w_hat))


def test_repair_far_cheaper_than_full_reprogram():
    pool, mgr, w_hat = _setup()
    mgr.storm(jax.random.PRNGKey(7), corrupt_rate=2e-3, stuck_rate=2e-4)
    rep = mgr.scrub_until_clean()
    full = mgr.transitions_full_affected()
    assert rep.detections > 0 and full > 0
    assert rep.repair_transitions <= 0.5 * full


def test_transient_flips_classified_not_repaired():
    pool, mgr, _ = _setup(IntegrityConfig(transient_rate=2e-3))
    before = mgr.tensors["t0"].stored.copy()
    rep = mgr.scrub_until_clean(max_rounds=50)
    assert rep.transients > 0
    assert rep.rewrites == 0 and rep.remaps == 0 and rep.repair_transitions == 0
    np.testing.assert_array_equal(mgr.tensors["t0"].stored, before)


def test_tolerate_cols_leaves_lsb_fault_unrepaired():
    """The bit-stucking insight: a hard fault in the lowest-order stored
    column is tolerated (no repair write) and folded into the contract."""
    pool, mgr, _ = _setup(IntegrityConfig(spare_cols=1, tolerate_cols=1))
    rec = mgr.tensors["t0"]
    rec.stuck1[0, 0, 0] |= 0x80  # stored column 0 == logical LSB (raw codec)
    rep = mgr.scrub_until_clean()
    assert rep.tolerated >= 1 and rep.remaps == 0 and rep.repair_transitions == 0
    assert mgr.verify_all() and mgr.clean  # contract re-anchored, reads stable


def test_spare_exhaustion_migrates_section():
    pool, mgr, w_hat = _setup(IntegrityConfig(spare_cols=1))
    rec = mgr.tensors["t0"]
    for c in (1, 2, 3):  # 3 hard-faulted columns, only 1 spare
        rec.stuck1[0, 0, c] |= 0x80
        for arr in (rec.expected, rec.reference, rec.stored):
            arr[0, 0, c] &= 0x7F  # ensure every fault conflicts
    rec.checksums[0] = tile_checksums(rec.expected[0:1], mgr.cfg.tile_bytes)[0]
    if rec.parity is not None:
        rec.parity[0] = np.bitwise_xor.reduce(rec.expected[0], axis=1)
    rep = mgr.scrub_until_clean()
    assert rep.migrations >= 1
    assert not rec.spare_used[0].any()  # migration frees the section's spares
    assert mgr.verify_all() and mgr.clean
    np.testing.assert_array_equal(np.asarray(mgr.rebuild("t0")), np.asarray(w_hat))


def test_repair_budget_defers_and_prioritizes_significance():
    """With a tiny per-round write budget only the highest-significance
    column is repaired first; the rest stays pending (fleet-visible) and
    converges over subsequent rounds."""
    pool, mgr, _ = _setup(IntegrityConfig(spare_cols=4, repair_budget=1))
    rec = mgr.tensors["t0"]
    for c in (0, 2):  # one low-order, one high-order hard fault, same tile
        rec.stuck1[0, 0, c] |= 0x80
        for arr in (rec.expected, rec.reference, rec.stored):
            arr[0, 0, c] &= 0x7F
    rec.checksums[0] = tile_checksums(rec.expected[0:1], mgr.cfg.tile_bytes)[0]
    if rec.parity is not None:
        rec.parity[0] = np.bitwise_xor.reduce(rec.expected[0], axis=1)
    rep1 = mgr.scrub_round()
    assert rep1.pending > 0 and mgr.pending_faults() > 0
    assert rec.col_map[0, 2] >= SPEC.cols  # MSB-side fault repaired first
    assert rec.col_map[0, 0] == 0  # LSB-side fault deferred past the budget
    mgr.scrub_until_clean()
    assert mgr.pending_faults() == 0 and mgr.verify_all() and mgr.clean


def test_registration_with_preexisting_faults_and_codec():
    """Pre-existing pool faults at program() time are the contract, not
    defects; under col_perm the stored layout round-trips through repair."""
    pool, mgr, w_hat = _setup(
        IntegrityConfig(spare_cols=2),
        pcfg=PlannerConfig(p_stuck=0.5, crossbars=4, codec="col_perm"),
        fault_model=nonideal.FaultModel(stuck0=0.01, stuck1=0.01),
    )
    assert mgr.tensors["t0"].col_order is not None
    assert mgr.verify_all()  # achieved_read IS the expectation
    assert mgr.scrub_until_clean().detections == 0
    mgr.storm(jax.random.PRNGKey(3), corrupt_rate=5e-3, stuck_rate=1e-3)
    mgr.scrub_until_clean()
    assert mgr.verify_all() and mgr.clean
    np.testing.assert_array_equal(np.asarray(mgr.rebuild("t0")), np.asarray(w_hat))


# ---------------------------------------------------------------------------
# engine integration: scrub between dispatches + atomic repaired refresh
# ---------------------------------------------------------------------------

LM_SPEC = CrossbarSpec(rows=128, cols=10)
LM_CFG = PlannerConfig(p_stuck=0.5, min_size=1024)
ECFG = EngineConfig(max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=8,
                    decode_quantum=4)


@pytest.fixture(scope="module")
def gemma():
    cfg = get_arch("gemma-2b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, specs, rid0=0):
    out = []
    for i, (plen, gen) in enumerate(specs):
        rid = rid0 + i
        prompt = np.asarray(
            jax.random.randint(jax.random.PRNGKey(100 + rid), (plen,), 0, cfg.vocab_size)
        )
        out.append(Request(rid=rid, prompt=prompt, max_new_tokens=gen, greedy=True))
    return out


def test_engine_scrub_hook_repairs_and_refreshes(gemma):
    """Mid-trace storm: the engine's between-dispatch scrubber detects and
    repairs it, then hot-swaps the repaired planes in; requests served after
    the refresh are bit-identical to solo generation on the clean deployment."""
    cfg, params = gemma
    pool = CrossbarPool(LM_SPEC, LM_CFG.crossbars, leveling="lpt")
    # scrub_tiles covers the whole tile population: one engine dispatch round
    # is enough for the scrubber to find and repair the entire storm
    mgr = pool.enable_integrity(IntegrityConfig(spare_cols=2, scrub_tiles=1_000_000))
    plan = build_deployment(params, LM_SPEC, LM_CFG, pool=pool)
    clean = deploy_params(params, plan, materialize="dense")

    eng = Engine(cfg, clean, ECFG)
    eng.attach_scrub(
        mgr,
        refresh=lambda: deploy_params(params, mgr.rebuild_plan(plan), materialize="dense"),
    )
    # the storm corrupts the modeled cells; serving params degrade with the
    # swap below (what an un-refreshed engine would keep serving)
    mgr.storm(jax.random.PRNGKey(11), corrupt_rate=2e-3, stuck_rate=2e-4)
    corrupted = deploy_params(params, mgr.rebuild_plan(plan), materialize="dense")
    assert eng.hot_swap(corrupted)
    eng.run(_reqs(cfg, [(11, 5), (7, 6)]))
    assert eng.stats["scrub_rounds"] > 0
    assert eng.stats["scrub_detections"] > 0
    assert eng.stats["scrub_repairs"] > 0
    assert eng.stats["scrub_refreshes"] >= 1
    assert mgr.verify_all()
    # post-refresh admissions read the repaired (== original) planes
    post = _reqs(cfg, [(9, 6)], rid0=10)
    res = eng.run(post)[0]
    batch = {"tokens": jnp.asarray(post[0].prompt)[None]}
    toks, _ = generate(cfg, clean, batch, gen_len=post[0].max_new_tokens)
    assert res.tokens == [int(t) for t in np.asarray(toks[0])]
