"""Tests for multi-crossbar schedules + thread balancing (§III.B-C)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import bitslice, cost, schedule, sws


@given(s=st.integers(1, 200), l=st.integers(1, 20), kind=st.sampled_from(["stride1", "strideL"]))
def test_chains_partition_sections(s, l, kind):
    chains = schedule.make_chains(s, l, kind)
    all_idx = np.sort(np.concatenate([np.asarray(c) for c in chains]))
    np.testing.assert_array_equal(all_idx, np.arange(s))
    assert len(chains) <= l


def _sorted_planes(key, s=128, rows=64, cols=8):
    w = jax.random.normal(key, (rows * s,)) * 0.02
    qt = bitslice.quantize(w, cols)
    perm = sws.sws_permutation(w)
    return bitslice.bitplanes(qt.q[perm].reshape(s, rows), cols)


def test_stride1_beats_strideL_on_sorted_planes(key):
    """Paper Fig. 6: stride-1 scheduling costs less than stride-L for L>1."""
    planes = _sorted_planes(key)
    l = 16
    t1 = int(schedule.schedule_transitions(planes, schedule.stride_1_chains(planes.shape[0], l)))
    tl = int(schedule.schedule_transitions(planes, schedule.stride_l_chains(planes.shape[0], l)))
    assert t1 < tl


def test_stride_equivalence_at_l1(key):
    planes = _sorted_planes(key, s=32)
    c1 = schedule.stride_1_chains(32, 1)
    cl = schedule.stride_l_chains(32, 1)
    assert int(schedule.schedule_transitions(planes, c1)) == int(
        schedule.schedule_transitions(planes, cl)
    )


def test_job_costs_sum_equals_schedule_total(key):
    planes = _sorted_planes(key, s=64)
    chains = schedule.stride_1_chains(64, 8)
    total = int(schedule.schedule_transitions(planes, chains))
    jobs = schedule.schedule_job_costs(planes, chains)
    assert total == int(jnp.sum(jobs))


@given(seed=st.integers(0, 50), threads=st.sampled_from([4, 16, 64]))
def test_lockstep_sorted_not_worse(seed, threads):
    """Paper Fig. 7: greedy similar-cost grouping beats arrival order."""
    rng = np.random.default_rng(seed)
    jobs = jnp.asarray(rng.integers(1, 1000, size=500), jnp.int32)
    t_sorted = int(schedule.lockstep_time(jobs, threads, sort_jobs=True))
    t_unsorted = int(schedule.lockstep_time(jobs, threads, sort_jobs=False))
    assert t_sorted <= t_unsorted
    # and both are lower-bounded by the ideal
    ideal = float(jnp.sum(jobs)) / threads
    assert t_sorted >= ideal - 1e-6


def test_lockstep_speedup_near_ideal_for_bell_jobs(key):
    """With many similar-cost jobs the greedy lockstep speedup approaches T."""
    jobs = (jax.random.normal(key, (4096,)) * 10 + 500).astype(jnp.int32)
    sp = float(schedule.lockstep_speedup(jobs, 64, sort_jobs=True))
    assert sp > 0.9 * 64


def test_lpt_loads_no_int32_overflow():
    """Regression: per-thread loads used an int32 scan accumulator and wrapped
    past 2^31 on large deployments; loads are now host int64."""
    jobs = jnp.full((64,), 2**27, jnp.int32)  # each job fits int32 comfortably
    tids, loads = schedule.lpt_assignment(jobs, 2)
    assert loads.dtype == np.int64
    assert int(np.sum(loads)) == 64 * 2**27  # 2^33: overflows int32
    assert int(np.max(loads)) == 32 * 2**27  # perfectly balanced split
    assert int(schedule.lpt_makespan(jobs, 2)) == 32 * 2**27
    assert np.min(tids) == 0 and np.max(tids) == 1


def test_lpt_initial_loads_and_capacity():
    """Wear-leveling contract: loads seeded with accumulated wear, capacity 1
    turns the greedy into a min-max matching on distinct crossbars."""
    jobs = jnp.asarray([10, 8, 5, 1], jnp.int32)
    init = np.asarray([100, 0, 50, 0], np.int64)
    tids, loads = schedule.lpt_assignment(jobs, 4, initial_loads=init, capacity=1)
    # heaviest job -> least-loaded thread (ties to lowest id), one job each
    np.testing.assert_array_equal(tids, [1, 3, 2, 0])
    np.testing.assert_array_equal(loads, [101, 10, 55, 8])
    with pytest.raises(ValueError):
        schedule.lpt_assignment(jobs, 2, capacity=1)  # 4 jobs, 2 slots
    with pytest.raises(ValueError):
        schedule.lpt_assignment(jobs, 4, initial_loads=np.zeros(3, np.int64))


@given(seed=st.integers(0, 50), threads=st.integers(1, 16))
def test_lpt_bounds(seed, threads):
    """LPT respects the classic (4/3 - 1/3m) * OPT bound via the trivial
    lower bounds max(job) and sum/threads."""
    rng = np.random.default_rng(seed)
    jobs = jnp.asarray(rng.integers(1, 100, size=64), jnp.int32)
    tids, loads = schedule.lpt_assignment(jobs, threads)
    assert int(jnp.sum(loads)) == int(jnp.sum(jobs))
    makespan = int(schedule.lpt_makespan(jobs, threads))
    opt_lb = max(float(jnp.max(jobs)), float(jnp.sum(jobs)) / threads)
    assert makespan <= (4 / 3) * opt_lb + float(jnp.max(jobs))
    # every job assigned to a valid thread
    assert int(jnp.min(tids)) >= 0 and int(jnp.max(tids)) < threads
